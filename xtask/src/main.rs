//! `cargo xtask` — repo automation (in the spirit of the cargo-xtask
//! pattern: a plain workspace binary, no extra tooling to install).
//!
//! Subcommands:
//!
//! * `doc-md` — render the public API of the core modules (`dct`,
//!   `codec`, `coordinator`, `faults`, `runtime`, `serve`) to
//!   `docs/api/*.md` so the docs are greppable offline (in the spirit
//!   of `cargo-doc-md`). The
//!   output is deterministic: fixed module order, files sorted by name,
//!   purely line-based extraction — so CI can diff it.
//! * `doc-md --check` — regenerate in memory and fail (exit 1) if any
//!   committed `docs/api/*.md` is stale. CI runs this on every PR.
//! * `bench-compare --baseline a.json --current b.json
//!   [--max-regress 15]` — compare the key hot-path rows of two
//!   `microbench_hotpath` JSON documents and fail (exit 1) when any
//!   key row's `cpu_ms` median regressed by more than the threshold,
//!   or when a key row is missing from either side. CI's perf-trend
//!   job runs this against the committed `bench-baselines/` snapshot.
//!
//! The extractor is deliberately line-based, not a parser: it takes the
//! leading `//!` paragraph of each file as the module summary and every
//! top-of-line `pub` signature (with its first `///` doc line) into a
//! fenced code block. Multi-line signatures contribute their first line
//! only. `#[cfg(test)]` blocks end extraction for the file.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// The modules rendered to docs/api/, in output order.
const MODULES: [&str; 6] =
    ["codec", "coordinator", "dct", "faults", "runtime", "serve"];

/// Signature prefixes that count as public API.
const PUB_PREFIXES: [&str; 8] = [
    "pub fn ",
    "pub struct ",
    "pub enum ",
    "pub trait ",
    "pub type ",
    "pub const ",
    "pub mod ",
    "pub use ",
];

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("doc-md") => {
            let check = args.iter().any(|a| a == "--check");
            doc_md(check)
        }
        Some("bench-compare") => bench_compare_cli(&args[1..]),
        _ => {
            eprintln!(
                "usage: cargo xtask doc-md [--check]\n\
                 \x20      cargo xtask bench-compare --baseline a.json \
                 --current b.json [--max-regress 15]\n\
                 \n\
                 doc-md          render docs/api/*.md from rust/src\n\
                 doc-md --check  fail if the rendered docs are stale\n\
                 bench-compare   fail if a key hot-path bench row \
                 regressed past the threshold"
            );
            2
        }
    };
    std::process::exit(code);
}

/// Repo root: xtask runs from anywhere inside the workspace, so walk up
/// from the manifest dir (CARGO_MANIFEST_DIR = <root>/xtask).
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives one level under the repo root")
        .to_path_buf()
}

fn doc_md(check: bool) -> i32 {
    let root = repo_root();
    let mut stale: Vec<String> = Vec::new();
    for module in MODULES {
        let src_dir = root.join("rust/src").join(module);
        let out_path = root.join("docs/api").join(format!("{module}.md"));
        let rendered = match render_module(module, &src_dir) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("doc-md: rendering {module}: {e}");
                return 1;
            }
        };
        if check {
            let on_disk = std::fs::read_to_string(&out_path)
                .unwrap_or_default();
            if on_disk != rendered {
                stale.push(format!("docs/api/{module}.md"));
            }
        } else {
            if let Some(dir) = out_path.parent() {
                let _ = std::fs::create_dir_all(dir);
            }
            if let Err(e) = std::fs::write(&out_path, &rendered) {
                eprintln!("doc-md: writing {}: {e}", out_path.display());
                return 1;
            }
            println!("wrote docs/api/{module}.md");
        }
    }
    if check && !stale.is_empty() {
        eprintln!(
            "doc-md --check: stale generated docs: {}\n\
             regenerate with `cargo xtask doc-md` and commit the result",
            stale.join(", ")
        );
        return 1;
    }
    if check {
        println!("doc-md --check: docs/api is up to date");
    }
    0
}

// -- bench-compare -----------------------------------------------------------

/// The `microbench_hotpath` rows the perf-trend gate watches: the
/// paper's batched cordic transform, the fused quantize→zigzag stage,
/// the entropy decoder, and the serve tier's response-cache hit path.
/// Informational rows (16-wide figures, PJRT splits) are deliberately
/// not gated.
const KEY_LABELS: [&str; 4] = [
    "fwd cordic-loeffler batched",
    "quantize+zigzag batched",
    "entropy decode image",
    "serve cache hit",
];

/// One gated row after comparison.
struct Comparison {
    label: String,
    baseline_ms: f64,
    current_ms: f64,
}

impl Comparison {
    fn ratio(&self) -> f64 {
        self.current_ms / self.baseline_ms
    }

    fn regressed(&self, max_regress_pct: f64) -> bool {
        self.ratio() > 1.0 + max_regress_pct / 100.0
    }
}

fn bench_compare_cli(args: &[String]) -> i32 {
    let mut baseline: Option<String> = None;
    let mut current: Option<String> = None;
    let mut max_regress = 15.0f64;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let mut take = |name: &str| {
            it.next()
                .cloned()
                .ok_or_else(|| format!("--{name} needs a value"))
        };
        let r = match a.as_str() {
            "--baseline" => take("baseline").map(|v| baseline = Some(v)),
            "--current" => take("current").map(|v| current = Some(v)),
            "--max-regress" => take("max-regress").and_then(|v| {
                v.parse::<f64>()
                    .map(|p| max_regress = p)
                    .map_err(|_| format!("bad --max-regress '{v}'"))
            }),
            other => Err(format!("unknown argument '{other}'")),
        };
        if let Err(e) = r {
            eprintln!("bench-compare: {e}");
            return 2;
        }
    }
    let (Some(baseline), Some(current)) = (baseline, current) else {
        eprintln!("bench-compare: --baseline and --current are required");
        return 2;
    };
    let read = |path: &str| {
        std::fs::read_to_string(path)
            .map_err(|e| format!("reading {path}: {e}"))
    };
    let (base_doc, cur_doc) = match (read(&baseline), read(&current)) {
        (Ok(b), Ok(c)) => (b, c),
        (Err(e), _) | (_, Err(e)) => {
            eprintln!("bench-compare: {e}");
            return 1;
        }
    };
    match compare_docs(&base_doc, &cur_doc, max_regress) {
        Err(e) => {
            eprintln!("bench-compare: {e}");
            1
        }
        Ok(rows) => {
            let mut failed = false;
            for c in &rows {
                let pct = (c.ratio() - 1.0) * 100.0;
                let regressed = c.regressed(max_regress);
                println!(
                    "{:<28} baseline {:>9.3} ms  current {:>9.3} ms  \
                     {pct:+6.1}%{}",
                    c.label,
                    c.baseline_ms,
                    c.current_ms,
                    if regressed { "  REGRESSED" } else { "" }
                );
                failed |= regressed;
            }
            if failed {
                eprintln!(
                    "bench-compare: key row(s) regressed more than \
                     {max_regress}% vs the committed baseline; if the \
                     slowdown is intentional, regenerate the baseline \
                     (see bench-baselines/microbench_hotpath.json)"
                );
                1
            } else {
                println!(
                    "bench-compare: all {} key rows within {max_regress}%",
                    rows.len()
                );
                0
            }
        }
    }
}

/// Compare every key label of two bench JSON documents. Errors when a
/// key row (or its `cpu_ms`) is missing from either side — a silently
/// vanished row must fail the gate, not pass it.
fn compare_docs(
    baseline: &str,
    current: &str,
    _max_regress: f64,
) -> Result<Vec<Comparison>, String> {
    let base = bench_rows(baseline);
    let cur = bench_rows(current);
    let find = |rows: &[(String, f64)], label: &str, side: &str| {
        rows.iter()
            .find(|(l, _)| l == label)
            .map(|&(_, ms)| ms)
            .ok_or_else(|| {
                format!("key row '{label}' missing from {side} document")
            })
    };
    KEY_LABELS
        .iter()
        .map(|&label| {
            let baseline_ms = find(&base, label, "baseline")?;
            let current_ms = find(&cur, label, "current")?;
            if baseline_ms <= 0.0 {
                return Err(format!(
                    "key row '{label}' has non-positive baseline \
                     ({baseline_ms} ms)"
                ));
            }
            Ok(Comparison {
                label: label.to_string(),
                baseline_ms,
                current_ms,
            })
        })
        .collect()
}

/// Extract `(label, cpu_ms)` pairs from a bench JSON document in the
/// `rows_to_json` shape. Deliberately a scanner, not a JSON parser
/// (xtask stays dependency-free): each `"label"` string opens a row,
/// and the first `"cpu_ms"` number before the next `"label"` belongs
/// to it. Labels produced by the benches contain no escapes.
fn bench_rows(json: &str) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    let mut rest = json;
    while let Some(pos) = rest.find("\"label\"") {
        let after = &rest[pos + "\"label\"".len()..];
        let label = match json_string_value(after) {
            Some(l) => l,
            None => break,
        };
        let scope_end = after.find("\"label\"").unwrap_or(after.len());
        let scope = &after[..scope_end];
        if let Some(cpos) = scope.find("\"cpu_ms\"") {
            if let Some(ms) =
                json_number_value(&scope[cpos + "\"cpu_ms\"".len()..])
            {
                out.push((label, ms));
            }
        }
        rest = &after[scope_end..];
    }
    out
}

/// `: "value"` after a key — skip the colon/whitespace, read to the
/// closing quote.
fn json_string_value(s: &str) -> Option<String> {
    let s = s.trim_start().strip_prefix(':')?.trim_start();
    let s = s.strip_prefix('"')?;
    s.find('"').map(|end| s[..end].to_string())
}

/// `: 12.5` after a key — skip the colon/whitespace, parse the number
/// token.
fn json_number_value(s: &str) -> Option<f64> {
    let s = s.trim_start().strip_prefix(':')?.trim_start();
    let end = s
        .find(|c: char| !(c.is_ascii_digit() || "+-.eE".contains(c)))
        .unwrap_or(s.len());
    s[..end].parse().ok()
}

/// Render one module directory to its markdown document.
fn render_module(module: &str, src_dir: &Path) -> std::io::Result<String> {
    let mut files: Vec<String> = std::fs::read_dir(src_dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".rs"))
        .collect();
    files.sort();
    let mut out = String::new();
    let _ = writeln!(out, "# `cordic_dct::{module}` API");
    out.push('\n');
    let _ = writeln!(
        out,
        "Generated by `cargo xtask doc-md` from `rust/src/{module}/*.rs` \
         — do not edit by hand; regenerate with `cargo xtask doc-md` \
         (CI fails when this file is stale)."
    );
    for file in &files {
        let text = std::fs::read_to_string(src_dir.join(file))?;
        out.push('\n');
        let _ = writeln!(out, "## `{module}/{file}`");
        let summary = module_summary(&text);
        if !summary.is_empty() {
            out.push('\n');
            for line in &summary {
                let _ = writeln!(out, "{line}");
            }
        }
        let items = pub_items(&text);
        if !items.is_empty() {
            out.push('\n');
            out.push_str("```rust\n");
            for line in &items {
                let _ = writeln!(out, "{line}");
            }
            out.push_str("```\n");
        }
    }
    Ok(out)
}

/// Leading `//!` paragraph of a file: contiguous `//! ` lines from the
/// top, stopped by the first blank `//!` or non-doc line.
fn module_summary(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("//! ") {
            out.push(rest.to_string());
        } else {
            break;
        }
    }
    out
}

/// Public signature lines (first physical line each), each preceded by
/// its first `///` doc line when present. Extraction stops at the first
/// `#[cfg(test)]`.
fn pub_items(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut pending_doc: Option<String> = None;
    for line in text.lines() {
        let trimmed = line.trim_start();
        if trimmed.starts_with("#[cfg(test)]") {
            break;
        }
        if let Some(rest) = trimmed.strip_prefix("/// ") {
            if pending_doc.is_none() {
                pending_doc = Some(rest.to_string());
            }
            continue;
        }
        if trimmed.starts_with("///") || trimmed.starts_with("#[") {
            // blank doc line or attribute: keep the pending doc
            continue;
        }
        if PUB_PREFIXES.iter().any(|p| trimmed.starts_with(p)) {
            let indent_len = line.len() - trimmed.len();
            let indent = &line[..indent_len];
            let mut sig = line.trim_end();
            if let Some(stripped) = sig.strip_suffix('{') {
                sig = stripped.trim_end();
            }
            if let Some(doc) = pending_doc.take() {
                out.push(format!("{indent}/// {doc}"));
            }
            out.push(sig.to_string());
        }
        pending_doc = None;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal doc in the `rows_to_json` shape with all key rows at
    /// the given medians (ms).
    fn doc(cordic: f64, quant: f64, decode: f64) -> String {
        format!(
            r#"{{"table":"microbench_hotpath","rows":[
  {{"label":"extract all blocks","cpu_ms":0.5,"cpu_mean_ms":0.6}},
  {{"label":"fwd cordic-loeffler batched","cpu_ms":{cordic},"unit":"block"}},
  {{"label":"quantize+zigzag batched","cpu_ms":{quant}}},
  {{"label":"entropy decode image","cpu_ms":{decode},"mb_per_s":100}},
  {{"label":"serve cache hit","cpu_ms":0.2,"unit":"req"}}
]}}"#
        )
    }

    #[test]
    fn scanner_extracts_labels_and_medians() {
        let rows = bench_rows(&doc(1.25, 0.08, 2.5));
        assert_eq!(rows.len(), 5);
        assert_eq!(rows[1].0, "fwd cordic-loeffler batched");
        assert!((rows[1].1 - 1.25).abs() < 1e-12);
        assert_eq!(rows[3].0, "entropy decode image");
        assert!((rows[3].1 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn scanner_skips_rows_without_cpu_ms() {
        let json = r#"{"rows":[{"label":"a"},{"label":"b","cpu_ms":2}]}"#;
        let rows = bench_rows(json);
        assert_eq!(rows, vec![("b".to_string(), 2.0)]);
    }

    #[test]
    fn identical_docs_pass_the_gate() {
        let d = doc(1.0, 0.1, 2.0);
        let rows = compare_docs(&d, &d, 15.0).unwrap();
        assert_eq!(rows.len(), KEY_LABELS.len());
        assert!(rows.iter().all(|c| !c.regressed(15.0)));
    }

    #[test]
    fn regression_within_threshold_passes() {
        let rows =
            compare_docs(&doc(1.0, 0.1, 2.0), &doc(1.1, 0.11, 2.2), 15.0)
                .unwrap();
        assert!(rows.iter().all(|c| !c.regressed(15.0)));
    }

    #[test]
    fn slowed_key_row_fails_the_gate() {
        // entropy decode 30% slower than baseline: over a 15% threshold
        let rows =
            compare_docs(&doc(1.0, 0.1, 2.0), &doc(1.0, 0.1, 2.6), 15.0)
                .unwrap();
        let slow: Vec<&str> = rows
            .iter()
            .filter(|c| c.regressed(15.0))
            .map(|c| c.label.as_str())
            .collect();
        assert_eq!(slow, vec!["entropy decode image"]);
    }

    #[test]
    fn faster_current_never_fails() {
        let rows =
            compare_docs(&doc(1.0, 0.1, 2.0), &doc(0.2, 0.02, 0.4), 15.0)
                .unwrap();
        assert!(rows.iter().all(|c| !c.regressed(15.0)));
    }

    #[test]
    fn missing_key_row_is_an_error() {
        let partial = r#"{"rows":[
            {"label":"fwd cordic-loeffler batched","cpu_ms":1.0},
            {"label":"quantize+zigzag batched","cpu_ms":0.1}]}"#;
        let err = compare_docs(&doc(1.0, 0.1, 2.0), partial, 15.0)
            .unwrap_err();
        assert!(err.contains("entropy decode image"), "{err}");
        assert!(err.contains("current"), "{err}");
    }

    #[test]
    fn non_positive_baseline_is_an_error() {
        let err = compare_docs(&doc(0.0, 0.1, 2.0), &doc(1.0, 0.1, 2.0), 15.0)
            .unwrap_err();
        assert!(err.contains("non-positive"), "{err}");
    }
}
