//! Offline stand-in for the `anyhow` crate: the API subset this workspace
//! uses — `Error`, `Result`, the `anyhow!` / `bail!` / `ensure!` macros and
//! the `Context` extension trait over `Result` and `Option`.
//!
//! `Error` is a flat context chain (outermost first). `Display` prints the
//! outermost message; the alternate form (`{:#}`) prints the whole chain
//! joined with `": "`, matching how callers here format errors.

use std::fmt;

/// Error type: a chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

/// `anyhow::Result` with the usual default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from a single message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Push an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root (innermost) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for msg in &self.chain[1..] {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

// Any std error converts into Error (capturing its source chain). Error
// itself deliberately does NOT implement std::error::Error, so this blanket
// impl cannot overlap the reflexive `From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Private extension machinery mirroring anyhow's coherence trick: a local
/// `StdError` trait implemented both for every `std::error::Error` type and
/// for `Error` itself (which does not implement the std trait, so the impls
/// cannot overlap).
mod ext {
    use super::Error;
    use std::fmt;

    pub trait StdError {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error;
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error {
            Error::from(self).context(context)
        }
    }

    impl StdError for Error {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// Attach context to failures, on both `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::StdError,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::Error::msg(format!($($arg)*)))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr) => {
        if !($cond) {
            return ::std::result::Result::Err(
                $crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading file")
            .unwrap_err();
        assert_eq!(e.to_string(), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: gone");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            let _n: u32 = "x".parse()?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_anyhow_error_and_option() {
        let e: Error = Err::<(), _>(anyhow!("inner"))
            .context("outer")
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
        let o: Result<u8> = None.context("missing");
        assert_eq!(o.unwrap_err().to_string(), "missing");
    }

    #[test]
    fn macros() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable {}", 1);
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
    }
}
