//! Offline stand-in for `once_cell`: just `sync::Lazy`, backed by
//! `std::sync::OnceLock`. The initializer is a plain `fn() -> T` pointer —
//! non-capturing closures coerce to it, which covers every use here.

pub mod sync {
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// A value initialized on first access.
    pub struct Lazy<T> {
        cell: OnceLock<T>,
        init: fn() -> T,
    }

    impl<T> Lazy<T> {
        pub const fn new(init: fn() -> T) -> Lazy<T> {
            Lazy {
                cell: OnceLock::new(),
                init,
            }
        }

        pub fn force(this: &Lazy<T>) -> &T {
            this.cell.get_or_init(this.init)
        }
    }

    impl<T> Deref for Lazy<T> {
        type Target = T;

        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::Lazy;

    static COUNTER: Lazy<u32> = Lazy::new(|| 41 + 1);

    #[test]
    fn initializes_once_and_derefs() {
        assert_eq!(*COUNTER, 42);
        assert_eq!(*COUNTER, 42);
        let local: Lazy<String> = Lazy::new(|| "hi".to_string());
        assert_eq!(local.len(), 2);
    }
}
