//! Compile-time stub of the PJRT/XLA binding surface this workspace uses.
//!
//! The real bindings wrap the PJRT C API; in this offline environment the
//! serving stack gates the GPU lane on `artifacts/manifest.json`, which is
//! only produced where the real runtime exists — so every entry point here
//! that would need PJRT returns [`Error::Unavailable`] instead. `Literal`
//! is a small functional host-side buffer so marshaling code and
//! microbenches still run.

use std::fmt;

/// Error type for the stubbed binding surface.
#[derive(Debug, Clone)]
pub enum Error {
    /// The PJRT runtime is not available in this build.
    Unavailable(String),
    /// A host-side literal operation failed.
    Literal(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "PJRT unavailable in this build (stubbed xla crate): {what}"
            ),
            Error::Literal(msg) => write!(f, "literal error: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error::Unavailable(what.to_string()))
}

/// Element types a [`Literal`] can be read back as.
pub trait Element: Copy {
    fn from_f32(v: f32) -> Self;
    fn to_f32(self) -> f32;
}

impl Element for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
    fn to_f32(self) -> f32 {
        self
    }
}

impl Element for f64 {
    fn from_f32(v: f32) -> f64 {
        v as f64
    }
    fn to_f32(self) -> f32 {
        self as f32
    }
}

/// Host-side tensor literal (f32 storage).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: Element>(values: &[T]) -> Literal {
        Literal {
            data: values.iter().map(|&v| v.to_f32()).collect(),
            dims: vec![values.len() as i64],
        }
    }

    /// Reshape without changing element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if count < 0 || count as usize != self.data.len() {
            return Err(Error::Literal(format!(
                "cannot reshape {} elements to {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Flat element read-back.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Split a tuple literal into its parts (stub literals are never
    /// tuples — real tuple outputs only come from PJRT execution).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("tuple literals come from PJRT execution")
    }
}

/// Parsed HLO module handle.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HLO parsing")
    }
}

/// Computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer handle returned by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("buffer read-back")
    }
}

/// Loaded executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("execution")
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The real crate constructs a CPU PJRT client here; the stub reports
    /// the runtime as unavailable so callers gate the GPU lane off.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PJRT CPU client")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        unavailable("compilation")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let lit = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(lit.shape(), &[2, 2]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn runtime_paths_report_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("unavailable"));
    }
}
