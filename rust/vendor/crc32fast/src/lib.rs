//! Offline stand-in for `crc32fast`: the standard reflected CRC-32
//! (IEEE 802.3, polynomial 0xEDB88320) with a compile-time lookup table.
//! Same `Hasher` API, no SIMD.

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut n = 0usize;
    while n < 256 {
        let mut c = n as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[n] = c;
        n += 1;
    }
    table
}

static TABLE: [u32; 256] = make_table();

/// Streaming CRC-32 hasher.
#[derive(Clone, Debug, Default)]
pub struct Hasher {
    state: u32,
}

impl Hasher {
    pub fn new() -> Hasher {
        Hasher { state: 0 }
    }

    /// Resume from a previous `finalize` value.
    pub fn new_with_initial(init: u32) -> Hasher {
        Hasher { state: init }
    }

    pub fn update(&mut self, buf: &[u8]) {
        let mut c = !self.state;
        for &b in buf {
            c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = !c;
    }

    pub fn finalize(self) -> u32 {
        self.state
    }

    pub fn reset(&mut self) {
        self.state = 0;
    }
}

/// One-shot convenience.
pub fn hash(buf: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(buf);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // canonical CRC-32 check value
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b""), 0);
        assert_eq!(hash(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn incremental_matches_oneshot() {
        let mut h = Hasher::new();
        h.update(b"hello ");
        h.update(b"world");
        assert_eq!(h.finalize(), hash(b"hello world"));
    }
}
