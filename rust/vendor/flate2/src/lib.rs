//! Offline stand-in for `flate2`: the `read::ZlibDecoder` /
//! `write::ZlibEncoder` API over a pure-Rust DEFLATE implementation.
//!
//! * Compressor: greedy LZ77 (32 KiB window, hash chains) emitted as one
//!   final block, fixed or dynamic Huffman by computed cost — real
//!   compression, standards-compliant output any inflater can read.
//! * Decompressor: full RFC 1951 inflate (stored, fixed and dynamic
//!   blocks), modeled on Mark Adler's `puff.c`, plus RFC 1950 zlib
//!   framing with adler32 verification. Corrupt input yields
//!   `io::Error`, never a panic.

use std::io::{self, Read, Write};

/// Compression level knob (accepted for API compatibility; the encoder
/// always runs the same LZ77 + fixed/dynamic-Huffman pipeline).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Compression(u32);

impl Compression {
    pub fn new(level: u32) -> Compression {
        Compression(level)
    }
    pub fn none() -> Compression {
        Compression(0)
    }
    pub fn fast() -> Compression {
        Compression(1)
    }
    pub fn best() -> Compression {
        Compression(9)
    }
    pub fn level(&self) -> u32 {
        self.0
    }
}

impl Default for Compression {
    fn default() -> Compression {
        Compression(6)
    }
}

fn corrupt(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("zlib: {msg}"))
}

// ---------------------------------------------------------------------------
// adler32 (RFC 1950)
// ---------------------------------------------------------------------------

fn adler32(data: &[u8]) -> u32 {
    const MOD: u32 = 65_521;
    let (mut a, mut b) = (1u32, 0u32);
    // 5552 is the largest n with n*(n+1)/2*255 + (n+1)*(MOD-1) < 2^32
    for chunk in data.chunks(5552) {
        for &byte in chunk {
            a += byte as u32;
            b += a;
        }
        a %= MOD;
        b %= MOD;
    }
    (b << 16) | a
}

// ---------------------------------------------------------------------------
// Shared length/distance symbol tables (RFC 1951 §3.2.5)
// ---------------------------------------------------------------------------

const LEN_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51,
    59, 67, 83, 99, 115, 131, 163, 195, 227, 258,
];
const LEN_EXTRA: [u8; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4,
    4, 5, 5, 5, 5, 0,
];
const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385,
    513, 769, 1025, 1537, 2049, 3073, 4097, 6145, 8193, 12289, 16385,
    24577,
];
const DIST_EXTRA: [u8; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10,
    10, 11, 11, 12, 12, 13, 13,
];

// ---------------------------------------------------------------------------
// Deflate (compressor)
// ---------------------------------------------------------------------------

/// LSB-first bit accumulator (DEFLATE bit order).
struct BitWriter {
    out: Vec<u8>,
    acc: u64,
    n: u32,
}

impl BitWriter {
    fn new() -> BitWriter {
        BitWriter {
            out: Vec::new(),
            acc: 0,
            n: 0,
        }
    }

    /// Write `n` bits of `value`, LSB first (plain integer fields).
    fn bits(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 32);
        self.acc |= value << self.n;
        self.n += n;
        while self.n >= 8 {
            self.out.push(self.acc as u8);
            self.acc >>= 8;
            self.n -= 8;
        }
    }

    /// Write a Huffman code (codes are packed MSB first in DEFLATE).
    fn huff(&mut self, code: u32, len: u32) {
        let mut rev = 0u32;
        for k in 0..len {
            rev = (rev << 1) | ((code >> k) & 1);
        }
        self.bits(rev as u64, len);
    }

    fn finish(mut self) -> Vec<u8> {
        if self.n > 0 {
            self.out.push(self.acc as u8);
        }
        self.out
    }
}

/// Fixed litlen code for symbol 0..=287: (code, bits). RFC 1951 §3.2.6.
fn fixed_lit_code(sym: usize) -> (u32, u32) {
    match sym {
        0..=143 => (0x30 + sym as u32, 8),
        144..=255 => (0x190 + (sym as u32 - 144), 9),
        256..=279 => (sym as u32 - 256, 7),
        _ => (0xC0 + (sym as u32 - 280), 8),
    }
}

fn length_symbol(len: usize) -> (usize, u32, u32) {
    debug_assert!((3..=258).contains(&len));
    let mut idx = 28;
    while LEN_BASE[idx] as usize > len {
        idx -= 1;
    }
    (
        257 + idx,
        LEN_EXTRA[idx] as u32,
        (len - LEN_BASE[idx] as usize) as u32,
    )
}

fn dist_symbol(dist: usize) -> (usize, u32, u32) {
    debug_assert!((1..=32768).contains(&dist));
    let mut idx = 29;
    while DIST_BASE[idx] as usize > dist {
        idx -= 1;
    }
    (
        idx,
        DIST_EXTRA[idx] as u32,
        (dist - DIST_BASE[idx] as usize) as u32,
    )
}

/// LZ77 token stream element.
enum Token {
    Lit(u8),
    Match { len: u16, dist: u16 },
}

/// Greedy LZ77 with hash chains: 32 KiB window, 3..258 match lengths.
fn lz77(data: &[u8]) -> Vec<Token> {
    const WINDOW: usize = 32 * 1024;
    const MIN_MATCH: usize = 3;
    const MAX_MATCH: usize = 258;
    const HASH_BITS: u32 = 15;
    const HASH_SIZE: usize = 1 << HASH_BITS;
    const MAX_CHAIN: usize = 64;
    const NONE: u32 = u32::MAX;

    let n = data.len();
    let mut tokens = Vec::new();
    let mut head = vec![NONE; HASH_SIZE];
    let mut prev = vec![NONE; n];
    let hash_at = |i: usize| -> usize {
        let h = ((data[i] as u32) << 16)
            ^ ((data[i + 1] as u32) << 8)
            ^ (data[i + 2] as u32);
        (h.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
    };

    let mut i = 0usize;
    while i < n {
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        if i + MIN_MATCH <= n {
            let h = hash_at(i);
            let mut cand = head[h];
            let mut steps = 0usize;
            let max = MAX_MATCH.min(n - i);
            while cand != NONE && steps < MAX_CHAIN {
                let c = cand as usize;
                let dist = i - c;
                if dist > WINDOW {
                    break;
                }
                let mut l = 0usize;
                while l < max && data[c + l] == data[i + l] {
                    l += 1;
                }
                if l > best_len {
                    best_len = l;
                    best_dist = dist;
                    if l >= max {
                        break;
                    }
                }
                cand = prev[c];
                steps += 1;
            }
            prev[i] = head[h];
            head[h] = i as u32;
        }
        if best_len >= MIN_MATCH {
            tokens.push(Token::Match {
                len: best_len as u16,
                dist: best_dist as u16,
            });
            // index the skipped positions so later matches can see them
            for j in i + 1..i + best_len {
                if j + MIN_MATCH <= n {
                    let h = hash_at(j);
                    prev[j] = head[h];
                    head[h] = j as u32;
                }
            }
            i += best_len;
        } else {
            tokens.push(Token::Lit(data[i]));
            i += 1;
        }
    }
    tokens
}

/// Length-limited Huffman code lengths from frequencies (heap build +
/// JPEG-style length rebalancing to `cap`). Zero-frequency symbols get no
/// code; a single-symbol alphabet gets a 1-bit code.
fn limited_lengths(freq: &[u64], cap: usize) -> Vec<u8> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    let n = freq.len();
    let mut lens = vec![0u8; n];
    let present: Vec<usize> = (0..n).filter(|&s| freq[s] > 0).collect();
    match present.len() {
        0 => return lens,
        1 => {
            lens[present[0]] = 1;
            return lens;
        }
        _ => {}
    }
    let mut parent = vec![usize::MAX; 2 * n];
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> =
        present.iter().map(|&s| Reverse((freq[s], s))).collect();
    let mut next_id = n;
    while heap.len() > 1 {
        let Reverse((wa, a)) = heap.pop().unwrap();
        let Reverse((wb, b)) = heap.pop().unwrap();
        parent[a] = next_id;
        parent[b] = next_id;
        heap.push(Reverse((wa + wb, next_id)));
        next_id += 1;
    }
    for &s in &present {
        let mut l = 0u32;
        let mut node = s;
        while parent[node] != usize::MAX {
            node = parent[node];
            l += 1;
        }
        lens[s] = l.min(255) as u8;
    }
    if lens.iter().all(|&l| (l as usize) <= cap) {
        return lens;
    }
    // rebalance the length multiset under the cap (classic adjust_bits)
    let mut counts = vec![0usize; 256];
    for &l in &lens {
        if l > 0 {
            counts[l as usize] += 1;
        }
    }
    let mut i = counts.len() - 1;
    while i > cap {
        while counts[i] > 0 {
            let mut j = i - 2;
            while counts[j] == 0 {
                j -= 1;
            }
            counts[i] -= 2;
            counts[i - 1] += 1;
            counts[j + 1] += 2;
            counts[j] -= 1;
        }
        i -= 1;
    }
    // reassign: most frequent symbols take the shortest lengths
    let mut by_freq = present;
    by_freq.sort_by_key(|&s| Reverse(freq[s]));
    let mut new_lens = vec![0u8; n];
    let mut li = 1usize;
    for &s in &by_freq {
        while li <= cap && counts[li] == 0 {
            li += 1;
        }
        new_lens[s] = li as u8;
        counts[li] -= 1;
    }
    new_lens
}

/// Canonical codes from lengths (RFC 1951 §3.2.2).
fn codes_from_lengths(lens: &[u8]) -> Vec<u32> {
    let max_len = lens.iter().copied().max().unwrap_or(0) as usize;
    let mut bl_count = vec![0u32; max_len + 1];
    for &l in lens {
        if l > 0 {
            bl_count[l as usize] += 1;
        }
    }
    let mut next_code = vec![0u32; max_len + 1];
    let mut code = 0u32;
    for bits in 1..=max_len {
        code = (code + bl_count[bits - 1]) << 1;
        next_code[bits] = code;
    }
    let mut codes = vec![0u32; lens.len()];
    for (s, &l) in lens.iter().enumerate() {
        if l > 0 {
            codes[s] = next_code[l as usize];
            next_code[l as usize] += 1;
        }
    }
    codes
}

/// Order of code-length code lengths in the dynamic header (RFC 1951).
const CLEN_ORDER: [usize; 19] = [
    16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15,
];

/// Emit the token stream with the given litlen/dist coders.
fn emit_tokens<L, D>(w: &mut BitWriter, tokens: &[Token], lit: L, dst: D)
where
    L: Fn(usize) -> (u32, u32),
    D: Fn(usize) -> (u32, u32),
{
    for t in tokens {
        match *t {
            Token::Lit(b) => {
                let (code, clen) = lit(b as usize);
                w.huff(code, clen);
            }
            Token::Match { len, dist } => {
                let (lsym, lbits, lval) = length_symbol(len as usize);
                let (code, clen) = lit(lsym);
                w.huff(code, clen);
                w.bits(lval as u64, lbits);
                let (dsym, dbits, dval) = dist_symbol(dist as usize);
                let (code, clen) = dst(dsym);
                w.huff(code, clen);
                w.bits(dval as u64, dbits);
            }
        }
    }
    let (code, clen) = lit(256); // end of block
    w.huff(code, clen);
}

/// Raw DEFLATE stream: one final block over the whole input, choosing
/// fixed or dynamic Huffman by computed cost.
fn deflate(data: &[u8]) -> Vec<u8> {
    let tokens = lz77(data);

    // symbol statistics
    let mut lit_freq = [0u64; 286];
    let mut dist_freq = [0u64; 30];
    for t in &tokens {
        match *t {
            Token::Lit(b) => lit_freq[b as usize] += 1,
            Token::Match { len, dist } => {
                lit_freq[length_symbol(len as usize).0] += 1;
                dist_freq[dist_symbol(dist as usize).0] += 1;
            }
        }
    }
    lit_freq[256] += 1;

    let lit_lens = limited_lengths(&lit_freq, 15);
    let dist_lens = limited_lengths(&dist_freq, 15);

    // dynamic header layout (no 16/17/18 run symbols: every length is a
    // direct clen symbol — simpler, still standards-valid)
    let hlit = lit_lens
        .iter()
        .rposition(|&l| l > 0)
        .map(|p| p + 1)
        .unwrap_or(257)
        .max(257);
    let hdist = dist_lens
        .iter()
        .rposition(|&l| l > 0)
        .map(|p| p + 1)
        .unwrap_or(1)
        .max(1);
    let entries: Vec<u8> = lit_lens[..hlit]
        .iter()
        .chain(dist_lens[..hdist].iter())
        .copied()
        .collect();
    let mut clen_freq = [0u64; 19];
    for &e in &entries {
        clen_freq[e as usize] += 1;
    }
    let clen_lens = limited_lengths(&clen_freq, 7);
    let clen_codes = codes_from_lengths(&clen_lens);
    let hclen = (4..=19)
        .rev()
        .find(|&k| clen_lens[CLEN_ORDER[k - 1]] > 0)
        .unwrap_or(4);

    // cost comparison (extra bits are identical on both sides)
    let fixed_cost: u64 = lit_freq
        .iter()
        .enumerate()
        .map(|(s, &f)| f * fixed_lit_code(s).1 as u64)
        .sum::<u64>()
        + dist_freq.iter().sum::<u64>() * 5;
    let header_cost: u64 = 14
        + 3 * hclen as u64
        + entries
            .iter()
            .map(|&e| clen_lens[e as usize] as u64)
            .sum::<u64>();
    let dyn_cost: u64 = header_cost
        + lit_freq
            .iter()
            .zip(&lit_lens)
            .map(|(&f, &l)| f * l as u64)
            .sum::<u64>()
        + dist_freq
            .iter()
            .zip(&dist_lens)
            .map(|(&f, &l)| f * l as u64)
            .sum::<u64>();

    let mut w = BitWriter::new();
    w.bits(1, 1); // BFINAL
    if dyn_cost < fixed_cost {
        w.bits(2, 2); // BTYPE = 10 (dynamic)
        w.bits(hlit as u64 - 257, 5);
        w.bits(hdist as u64 - 1, 5);
        w.bits(hclen as u64 - 4, 4);
        for &pos in CLEN_ORDER.iter().take(hclen) {
            w.bits(clen_lens[pos] as u64, 3);
        }
        for &e in &entries {
            w.huff(clen_codes[e as usize], clen_lens[e as usize] as u32);
        }
        let lit_codes = codes_from_lengths(&lit_lens);
        let dist_codes = codes_from_lengths(&dist_lens);
        emit_tokens(
            &mut w,
            &tokens,
            |s| (lit_codes[s], lit_lens[s] as u32),
            |d| (dist_codes[d], dist_lens[d] as u32),
        );
    } else {
        w.bits(1, 2); // BTYPE = 01 (fixed)
        emit_tokens(&mut w, &tokens, fixed_lit_code, |d| (d as u32, 5));
    }
    w.finish()
}

// ---------------------------------------------------------------------------
// Inflate (decompressor)
// ---------------------------------------------------------------------------

/// LSB-first bit reader over a byte slice.
struct BitReader<'a> {
    data: &'a [u8],
    byte: usize,
    bit: u32,
}

impl<'a> BitReader<'a> {
    fn new(data: &'a [u8]) -> BitReader<'a> {
        BitReader { data, byte: 0, bit: 0 }
    }

    fn bits(&mut self, n: u32) -> io::Result<u32> {
        let mut out = 0u32;
        for k in 0..n {
            if self.byte >= self.data.len() {
                return Err(corrupt("bitstream exhausted"));
            }
            let bit = (self.data[self.byte] >> self.bit) & 1;
            out |= (bit as u32) << k;
            self.bit += 1;
            if self.bit == 8 {
                self.bit = 0;
                self.byte += 1;
            }
        }
        Ok(out)
    }

    fn align_byte(&mut self) {
        if self.bit != 0 {
            self.bit = 0;
            self.byte += 1;
        }
    }

    fn take_bytes(&mut self, n: usize) -> io::Result<&'a [u8]> {
        debug_assert_eq!(self.bit, 0);
        if self.byte + n > self.data.len() {
            return Err(corrupt("stored block truncated"));
        }
        let out = &self.data[self.byte..self.byte + n];
        self.byte += n;
        Ok(out)
    }
}

const MAX_CODE_BITS: usize = 15;

/// Canonical Huffman decoder (puff.c count/offset scheme).
struct Huffman {
    count: [u16; MAX_CODE_BITS + 1],
    symbol: Vec<u16>,
}

impl Huffman {
    fn build(lengths: &[u8]) -> io::Result<Huffman> {
        let mut count = [0u16; MAX_CODE_BITS + 1];
        for &l in lengths {
            if l as usize > MAX_CODE_BITS {
                return Err(corrupt("code length exceeds 15"));
            }
            count[l as usize] += 1;
        }
        count[0] = 0;
        // over-subscription check
        let mut left = 1i32;
        for len in 1..=MAX_CODE_BITS {
            left <<= 1;
            left -= count[len] as i32;
            if left < 0 {
                return Err(corrupt("over-subscribed Huffman code"));
            }
        }
        let mut offs = [0usize; MAX_CODE_BITS + 2];
        for len in 1..=MAX_CODE_BITS {
            offs[len + 1] = offs[len] + count[len] as usize;
        }
        let nsym: usize = count[1..].iter().map(|&c| c as usize).sum();
        let mut symbol = vec![0u16; nsym];
        for (sym, &l) in lengths.iter().enumerate() {
            if l != 0 {
                symbol[offs[l as usize]] = sym as u16;
                offs[l as usize] += 1;
            }
        }
        Ok(Huffman { count, symbol })
    }

    fn decode(&self, r: &mut BitReader<'_>) -> io::Result<u16> {
        let mut code = 0i32;
        let mut first = 0i32;
        let mut index = 0i32;
        for len in 1..=MAX_CODE_BITS {
            code |= r.bits(1)? as i32;
            let count = self.count[len] as i32;
            if code - count < first {
                return Ok(self.symbol[(index + (code - first)) as usize]);
            }
            index += count;
            first += count;
            first <<= 1;
            code <<= 1;
        }
        Err(corrupt("invalid Huffman code"))
    }
}

fn inflate_block(
    r: &mut BitReader<'_>,
    out: &mut Vec<u8>,
    litlen: &Huffman,
    dist: &Huffman,
) -> io::Result<()> {
    loop {
        let sym = litlen.decode(r)? as usize;
        if sym < 256 {
            out.push(sym as u8);
        } else if sym == 256 {
            return Ok(());
        } else {
            if sym > 285 {
                return Err(corrupt("invalid length symbol"));
            }
            let idx = sym - 257;
            let length = LEN_BASE[idx] as usize
                + r.bits(LEN_EXTRA[idx] as u32)? as usize;
            let dsym = dist.decode(r)? as usize;
            if dsym > 29 {
                return Err(corrupt("invalid distance symbol"));
            }
            let distance = DIST_BASE[dsym] as usize
                + r.bits(DIST_EXTRA[dsym] as u32)? as usize;
            if distance > out.len() {
                return Err(corrupt("distance beyond output start"));
            }
            for _ in 0..length {
                let b = out[out.len() - distance];
                out.push(b);
            }
        }
    }
}

/// Raw DEFLATE decode from `r`; `r` ends positioned after the final block.
fn inflate(r: &mut BitReader<'_>) -> io::Result<Vec<u8>> {
    let mut out = Vec::new();
    loop {
        let bfinal = r.bits(1)?;
        let btype = r.bits(2)?;
        match btype {
            0 => {
                r.align_byte();
                let hdr = r.take_bytes(4)?;
                let len = u16::from_le_bytes([hdr[0], hdr[1]]) as usize;
                let nlen = u16::from_le_bytes([hdr[2], hdr[3]]);
                if nlen != !(len as u16) {
                    return Err(corrupt("stored block LEN/NLEN mismatch"));
                }
                out.extend_from_slice(r.take_bytes(len)?);
            }
            1 => {
                let mut litlen_lens = [0u8; 288];
                for (s, l) in litlen_lens.iter_mut().enumerate() {
                    *l = match s {
                        0..=143 => 8,
                        144..=255 => 9,
                        256..=279 => 7,
                        _ => 8,
                    };
                }
                let litlen = Huffman::build(&litlen_lens)?;
                let dist = Huffman::build(&[5u8; 30])?;
                inflate_block(r, &mut out, &litlen, &dist)?;
            }
            2 => {
                let hlit = r.bits(5)? as usize + 257;
                let hdist = r.bits(5)? as usize + 1;
                let hclen = r.bits(4)? as usize + 4;
                if hlit > 286 || hdist > 30 {
                    return Err(corrupt("bad dynamic header counts"));
                }
                const ORDER: [usize; 19] = [
                    16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2,
                    14, 1, 15,
                ];
                let mut clen_lens = [0u8; 19];
                for &pos in ORDER.iter().take(hclen) {
                    clen_lens[pos] = r.bits(3)? as u8;
                }
                let clen = Huffman::build(&clen_lens)?;
                let mut lens = vec![0u8; hlit + hdist];
                let mut i = 0usize;
                while i < lens.len() {
                    let sym = clen.decode(r)?;
                    match sym {
                        0..=15 => {
                            lens[i] = sym as u8;
                            i += 1;
                        }
                        16 => {
                            if i == 0 {
                                return Err(corrupt("repeat with no prior"));
                            }
                            let prev = lens[i - 1];
                            let rep = 3 + r.bits(2)? as usize;
                            if i + rep > lens.len() {
                                return Err(corrupt("repeat overruns"));
                            }
                            for _ in 0..rep {
                                lens[i] = prev;
                                i += 1;
                            }
                        }
                        17 => {
                            let rep = 3 + r.bits(3)? as usize;
                            if i + rep > lens.len() {
                                return Err(corrupt("zero-run overruns"));
                            }
                            i += rep;
                        }
                        18 => {
                            let rep = 11 + r.bits(7)? as usize;
                            if i + rep > lens.len() {
                                return Err(corrupt("zero-run overruns"));
                            }
                            i += rep;
                        }
                        _ => return Err(corrupt("bad code-length symbol")),
                    }
                }
                let litlen = Huffman::build(&lens[..hlit])?;
                let dist = Huffman::build(&lens[hlit..])?;
                inflate_block(r, &mut out, &litlen, &dist)?;
            }
            _ => return Err(corrupt("reserved block type")),
        }
        if bfinal == 1 {
            return Ok(out);
        }
    }
}

/// Decode a full zlib stream (RFC 1950 framing + adler32 check).
fn zlib_decode(data: &[u8]) -> io::Result<Vec<u8>> {
    if data.len() < 6 {
        return Err(corrupt("stream too short"));
    }
    let cmf = data[0];
    let flg = data[1];
    if cmf & 0x0F != 8 {
        return Err(corrupt("not a deflate stream"));
    }
    if flg & 0x20 != 0 {
        return Err(corrupt("preset dictionary unsupported"));
    }
    if (cmf as u32 * 256 + flg as u32) % 31 != 0 {
        return Err(corrupt("bad header check"));
    }
    let mut r = BitReader::new(&data[2..]);
    let out = inflate(&mut r)?;
    r.align_byte();
    let trailer = r.take_bytes(4)?;
    let want = u32::from_be_bytes([trailer[0], trailer[1], trailer[2], trailer[3]]);
    if adler32(&out) != want {
        return Err(corrupt("adler32 mismatch"));
    }
    Ok(out)
}

/// Encode a full zlib stream.
fn zlib_encode(data: &[u8]) -> Vec<u8> {
    let body = deflate(data);
    let mut out = Vec::with_capacity(body.len() + 6);
    out.push(0x78); // CM=8, CINFO=7 (32 KiB window)
    out.push(0x9C); // FLEVEL=2, FCHECK makes the pair divisible by 31
    out.extend_from_slice(&body);
    out.extend_from_slice(&adler32(data).to_be_bytes());
    out
}

// ---------------------------------------------------------------------------
// Public reader/writer wrappers
// ---------------------------------------------------------------------------

pub mod write {
    use super::*;

    /// Buffering zlib compressor: collects all input, compresses on
    /// `finish()`, writes the stream into the inner writer.
    pub struct ZlibEncoder<W: Write> {
        inner: Option<W>,
        buf: Vec<u8>,
        _level: Compression,
    }

    impl<W: Write> ZlibEncoder<W> {
        pub fn new(inner: W, level: Compression) -> ZlibEncoder<W> {
            ZlibEncoder {
                inner: Some(inner),
                buf: Vec::new(),
                _level: level,
            }
        }

        /// Compress everything written so far and return the inner writer.
        pub fn finish(mut self) -> io::Result<W> {
            let mut w = self
                .inner
                .take()
                .ok_or_else(|| corrupt("encoder already finished"))?;
            w.write_all(&zlib_encode(&self.buf))?;
            w.flush()?;
            Ok(w)
        }
    }

    impl<W: Write> Write for ZlibEncoder<W> {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    impl<W: Write> Drop for ZlibEncoder<W> {
        /// Match real flate2: finish the stream on drop (best effort) so
        /// callers that never call `finish()` don't silently lose data.
        fn drop(&mut self) {
            if let Some(mut w) = self.inner.take() {
                let _ = w.write_all(&zlib_encode(&self.buf));
                let _ = w.flush();
            }
        }
    }
}

pub mod read {
    use super::*;

    /// Zlib decompressor over any reader: decodes the whole stream on
    /// first read, then serves it out. A failed decode is sticky — later
    /// reads keep erroring instead of reporting a clean EOF.
    pub struct ZlibDecoder<R: Read> {
        src: Option<R>,
        out: Vec<u8>,
        pos: usize,
        failed: bool,
    }

    impl<R: Read> ZlibDecoder<R> {
        pub fn new(src: R) -> ZlibDecoder<R> {
            ZlibDecoder {
                src: Some(src),
                out: Vec::new(),
                pos: 0,
                failed: false,
            }
        }
    }

    impl<R: Read> Read for ZlibDecoder<R> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            if self.failed {
                return Err(corrupt("previous decode failed"));
            }
            if let Some(mut src) = self.src.take() {
                let decoded = (|| {
                    let mut raw = Vec::new();
                    src.read_to_end(&mut raw)?;
                    zlib_decode(&raw)
                })();
                match decoded {
                    Ok(out) => {
                        self.out = out;
                        self.pos = 0;
                    }
                    Err(e) => {
                        self.failed = true;
                        return Err(e);
                    }
                }
            }
            let n = buf.len().min(self.out.len() - self.pos);
            buf[..n].copy_from_slice(&self.out[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(data: &[u8]) -> Vec<u8> {
        let mut enc =
            write::ZlibEncoder::new(Vec::new(), Compression::new(6));
        enc.write_all(data).unwrap();
        let compressed = enc.finish().unwrap();
        let mut out = Vec::new();
        read::ZlibDecoder::new(&compressed[..])
            .read_to_end(&mut out)
            .unwrap();
        out
    }

    #[test]
    fn roundtrip_empty_and_small() {
        assert_eq!(roundtrip(b""), b"");
        assert_eq!(roundtrip(b"a"), b"a");
        assert_eq!(roundtrip(b"hello world hello world"), b"hello world hello world");
    }

    #[test]
    fn roundtrip_repetitive_compresses() {
        let data = vec![42u8; 100_000];
        let mut enc =
            write::ZlibEncoder::new(Vec::new(), Compression::default());
        enc.write_all(&data).unwrap();
        let compressed = enc.finish().unwrap();
        assert!(compressed.len() < 1000, "{} bytes", compressed.len());
        let mut out = Vec::new();
        read::ZlibDecoder::new(&compressed[..])
            .read_to_end(&mut out)
            .unwrap();
        assert_eq!(out, data);
    }

    #[test]
    fn roundtrip_pseudorandom() {
        // xorshift-ish deterministic bytes: mostly incompressible
        let mut x = 0x1234_5678_u32;
        let data: Vec<u8> = (0..50_000)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 17;
                x ^= x << 5;
                x as u8
            })
            .collect();
        assert_eq!(roundtrip(&data), data);
    }

    #[test]
    fn roundtrip_structured() {
        let data: Vec<u8> = (0..30_000u32)
            .map(|i| ((i / 7) % 251) as u8)
            .collect();
        assert_eq!(roundtrip(&data), data);
    }

    #[test]
    fn corrupt_streams_error_not_panic() {
        let mut enc =
            write::ZlibEncoder::new(Vec::new(), Compression::default());
        enc.write_all(b"some payload data to mangle, repeated a bit, \
                        some payload data to mangle")
            .unwrap();
        let good = enc.finish().unwrap();
        for i in 0..good.len() {
            let mut bad = good.clone();
            bad[i] ^= 0xFF;
            let mut out = Vec::new();
            // Err or (extremely unlikely) Ok, but never a panic
            let _ = read::ZlibDecoder::new(&bad[..]).read_to_end(&mut out);
        }
        for cut in 0..good.len().min(16) {
            let mut out = Vec::new();
            assert!(read::ZlibDecoder::new(&good[..cut])
                .read_to_end(&mut out)
                .is_err());
        }
    }

    #[test]
    fn adler_known_value() {
        // adler32("Wikipedia") = 0x11E60398
        assert_eq!(adler32(b"Wikipedia"), 0x11E6_0398);
    }

    #[test]
    fn known_stored_block_decodes() {
        // hand-built zlib stream: stored block "hi"
        let payload = b"hi";
        let mut raw = vec![0x78, 0x01];
        raw.push(0x01); // BFINAL=1, BTYPE=00
        raw.extend_from_slice(&2u16.to_le_bytes());
        raw.extend_from_slice(&(!2u16).to_le_bytes());
        raw.extend_from_slice(payload);
        raw.extend_from_slice(&adler32(payload).to_be_bytes());
        let mut out = Vec::new();
        read::ZlibDecoder::new(&raw[..]).read_to_end(&mut out).unwrap();
        assert_eq!(out, payload);
    }
}
