//! A1 ablation: cost and quality of each 8x8 transform implementation —
//! naive (paper eq. 6 verbatim), separable matrix, Loeffler (exact
//! rotators) and Cordic-based Loeffler — plus the fused vs unfused
//! artifact comparison on the PJRT lane (paper §3.2 runs DCT, quantizer
//! and IDCT as separate kernels; our fused kernel is the optimization).

use cordic_dct::bench::{bench_config, render_table, rows_to_json,
                        save_results, Row};
use cordic_dct::bench::tables::try_runtime;
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::Variant;
use cordic_dct::image::synthetic;
use cordic_dct::metrics;

fn main() -> anyhow::Result<()> {
    let bench = bench_config();
    let img = synthetic::lena_like(512, 512, 1);
    let mpix = img.pixels() as f64 / 1e6;

    println!("\n== transform variant ablation (512x512 Lena-like) ==");
    println!(
        "{:<18} {:>10} {:>10} {:>12} {:>12} {:>10}",
        "variant", "mult/blk", "add/blk", "ms/image", "ms/MPixel", "PSNR(dB)"
    );
    let mut rows = Vec::new();
    for variant in [
        Variant::Naive,
        Variant::Dct,
        Variant::Loeffler,
        Variant::Cordic,
    ] {
        let t = variant.transform();
        let (mul, add) = t.ops_per_block();
        let pipe = CpuPipeline::new(variant, 50);
        let stats = bench.run(|| pipe.compress(&img));
        let psnr = metrics::psnr(&img, &pipe.compress(&img).recon);
        println!(
            "{:<18} {:>10} {:>10} {:>12.2} {:>12.2} {:>10.2}",
            t.name(),
            mul,
            add,
            stats.median_ms,
            stats.median_ms / mpix,
            psnr
        );
        rows.push(Row {
            label: t.name().into(),
            cpu: Some(stats),
            cpu_par: None,
            gpu: None,
            extra: vec![
                ("mult_per_block".into(), mul.to_string()),
                ("add_per_block".into(), add.to_string()),
                ("psnr".into(), format!("{psnr:.3}")),
            ],
        });
    }

    // fused vs unfused PJRT pipelines (512x512 artifacts)
    if let Some(rt) = try_runtime() {
        println!("\n== fused vs unfused PJRT pipeline (512x512) ==");
        let input: Vec<f32> = img.to_f32();
        let mut fused_rows = Vec::new();
        for (label, name) in [
            ("fused dct", "compress_dct_512x512"),
            ("unfused dct", "compress_unfused_dct_512x512"),
            ("fused cordic", "compress_cordic_512x512"),
            ("unfused cordic", "compress_unfused_cordic_512x512"),
        ] {
            let exe = rt.executable(name)?;
            let stats =
                bench.run(|| exe.run_f32(&[(&input, 512, 512)]).unwrap());
            println!("{label:<16} {:>10.2} ms", stats.median_ms);
            fused_rows.push(Row {
                label: label.into(),
                cpu: None,
                cpu_par: None,
                gpu: Some(stats),
                extra: vec![],
            });
        }
        rows.extend(fused_rows);
    } else {
        println!("(PJRT fusion ablation skipped: no artifacts)");
    }

    let text = render_table("ablation: DCT variants", &rows);
    save_results(
        "ablation_dct_variants",
        &text,
        &rows_to_json("ablation_dct_variants", &rows),
    );
    Ok(())
}
