//! E2: paper Table 2 — Cable-car timing sweep, CPU vs GPU lanes.

use cordic_dct::bench::tables;

fn main() -> anyhow::Result<()> {
    tables::run_timing_experiment(
        "table2_cablecar",
        "Table 2: Cable-car pipeline timing (CPU serial vs PJRT)",
        "cablecar",
        tables::CABLECAR_SIZES,
        tables::PAPER_TABLE2,
    )
}
