//! E6: paper Table 4 — Cable-car PSNR, exact DCT vs Cordic-based
//! Loeffler, across the five Table 2 sizes.

use cordic_dct::bench::tables;

fn main() -> anyhow::Result<()> {
    tables::run_psnr_experiment(
        "table4_psnr_cablecar",
        "Table 4: Cable-car PSNR (DCT vs Cordic-based Loeffler)",
        "cablecar",
        tables::CABLECAR_PSNR_SIZES,
    )
}
