//! E3/E4: paper Figures 5/6 and 10/11 — the speedup curves derived from
//! the Table 1/2 sweeps, rendered as ASCII figures and saved as JSON
//! series for external plotting.

use cordic_dct::bench::tables::{
    self, render_speedup_figure, speedup_series,
};
use cordic_dct::bench::{bench_config, rows_to_json, save_results};
use cordic_dct::dct::Variant;

fn main() -> anyhow::Result<()> {
    let bench = bench_config();
    for (name, title, scene, sizes) in [
        (
            "figures_5_6_lena",
            "Figures 5-6: Lena speedup (CPU/GPU ratio per size)",
            "lena",
            tables::LENA_SIZES,
        ),
        (
            "figures_10_11_cablecar",
            "Figures 10-11: Cable-car speedup",
            "cablecar",
            tables::CABLECAR_SIZES,
        ),
    ] {
        let sizes = tables::maybe_trim(sizes);
        let rows =
            tables::timing_table(scene, &sizes, Variant::Cordic, bench)?;
        let series = speedup_series(&rows);
        let text = render_speedup_figure(title, &series);
        println!("{text}");
        if series.is_empty() {
            println!(
                "(no GPU lane — run `make artifacts` for speedup figures)"
            );
        } else {
            // the paper's qualitative claim: speedup grows with image size
            let first = series.last().unwrap().1; // smallest size
            let peak = series
                .iter()
                .map(|(_, v)| *v)
                .fold(f64::MIN, f64::max);
            println!(
                "smallest-size speedup {first:.1}x, peak {peak:.1}x -> \
                 gap widens with size: {}",
                peak > first
            );
        }
        save_results(name, &text, &rows_to_json(name, &rows));
    }
    Ok(())
}
