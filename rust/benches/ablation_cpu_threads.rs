//! A3 ablation: parallel-CPU-lane scaling — the serial pipeline vs the
//! block-parallel pipeline at 1/2/4/8 workers on a 512x512 synthetic
//! image, per transform variant. The acceptance bar for the lane is a
//! >1.5x speedup at 4 workers on a multi-core host.
//!
//! Set CORDIC_DCT_BENCH_QUICK=1 to trim iterations.

use cordic_dct::bench::{bench_config, render_table, rows_to_json,
                        save_results, Row};
use cordic_dct::dct::parallel::ParallelCpuPipeline;
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::Variant;
use cordic_dct::image::synthetic;

fn main() -> anyhow::Result<()> {
    let bench = bench_config();
    let img = synthetic::lena_like(512, 512, 1);
    let worker_sweep: &[usize] = &[1, 2, 4, 8];

    println!("== parallel CPU lane: worker sweep (512x512 Lena-like) ==");
    println!(
        "{:<12} {:>8} {:>12} {:>12} {:>9}",
        "variant", "workers", "serial ms", "parallel ms", "speedup"
    );
    let mut rows = Vec::new();
    for variant in [Variant::Dct, Variant::Cordic] {
        let serial_pipe = CpuPipeline::new(variant, 50);
        let serial = bench.run(|| serial_pipe.compress(&img));
        for &workers in worker_sweep {
            let par_pipe =
                ParallelCpuPipeline::with_workers(variant, 50, workers);
            let par = bench.run(|| par_pipe.compress(&img));
            let speedup = serial.median_ms / par.median_ms.max(1e-9);
            println!(
                "{:<12} {:>8} {:>12.2} {:>12.2} {:>8.2}x",
                variant.as_str(),
                workers,
                serial.median_ms,
                par.median_ms,
                speedup
            );
            rows.push(Row {
                label: format!("{}_w{workers}", variant.as_str()),
                cpu: Some(serial.clone()),
                cpu_par: Some(par),
                gpu: None,
                extra: vec![
                    ("workers".into(), workers.to_string()),
                    ("variant".into(), variant.as_str().into()),
                ],
            });
        }
    }

    // parity spot check rides along: the sweep is meaningless if the
    // parallel lane ever diverges from the serial one
    let serial = CpuPipeline::new(Variant::Cordic, 50).compress(&img);
    let par = ParallelCpuPipeline::with_workers(Variant::Cordic, 50, 8)
        .compress(&img);
    assert_eq!(
        serial.qcoef, par.qcoef,
        "parallel lane diverged from serial"
    );
    assert_eq!(serial.recon, par.recon);
    println!("parity: serial and parallel outputs bit-identical");

    let text = render_table("ablation: CPU thread scaling", &rows);
    save_results(
        "ablation_cpu_threads",
        &text,
        &rows_to_json("ablation_cpu_threads", &rows),
    );
    Ok(())
}
