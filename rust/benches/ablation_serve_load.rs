//! A6 ablation: the TCP front-end under concurrent load — throughput and
//! exact p50/p95/p99 request latency over a real loopback socket,
//! sweeping the number of concurrent clients.
//!
//! Everything in the measured path is real: framing, protocol
//! encode/decode, the coordinator queue with Reject backpressure, and the
//! worker lanes. The load generator is closed-loop (each client waits for
//! its response before sending the next request), so throughput saturates
//! at the worker pool, and overloaded replies count as backpressure
//! rather than failures.

use cordic_dct::bench::save_results;
use cordic_dct::coordinator::{Lane, ServiceConfig};
use cordic_dct::dct::Variant;
use cordic_dct::serve::{run_load, LoadSpec, ServeConfig, TcpServer};
use cordic_dct::util::json::Json;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("CORDIC_DCT_BENCH_QUICK").is_ok();
    let (size, requests) = if quick { (64, 8) } else { (128, 32) };
    let client_sweep: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8] };
    let cfg = ServeConfig {
        service: ServiceConfig {
            workers: 4,
            queue_capacity: 64,
            artifact_dir: None,
            ..Default::default()
        },
        max_connections: 16,
        ..Default::default()
    };
    let server = TcpServer::bind("127.0.0.1:0", cfg)?;
    let addr = server.local_addr();
    println!(
        "== serve load ablation: {size}x{size} cordic gray, \
         {requests} req/client over {addr} =="
    );
    println!(
        "{:>8} {:>10} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "clients", "req/s", "p50 ms", "p95 ms", "p99 ms", "max ms",
        "err rate"
    );
    let mut reports = Vec::new();
    for &clients in client_sweep {
        let spec = LoadSpec {
            clients,
            requests_per_client: requests,
            size,
            color: false,
            variant: Variant::Cordic,
            lane: Lane::Cpu,
            want_psnr: false,
            ..LoadSpec::new(addr)
        };
        let report = run_load(&spec)?;
        println!(
            "{:>8} {:>10.1} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>9.3}",
            clients,
            report.throughput_rps,
            report.p50_ms,
            report.p95_ms,
            report.p99_ms,
            report.max_ms,
            report.error_rate
        );
        anyhow::ensure!(
            report.failed == 0,
            "{} request(s) failed under load",
            report.failed
        );
        reports.push(report);
    }
    server.shutdown();
    let text: String = reports
        .iter()
        .map(|r| format!("{r}\n"))
        .collect();
    let json = Json::obj(vec![
        ("table", Json::str("ablation_serve_load")),
        ("size", size.into()),
        ("requests_per_client", requests.into()),
        (
            "rows",
            Json::Arr(reports.iter().map(|r| r.to_json()).collect()),
        ),
    ])
    .to_string();
    save_results("ablation_serve_load", &text, &json);
    Ok(())
}
