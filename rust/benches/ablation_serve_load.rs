//! A6 ablation: the TCP front-end under concurrent load — throughput and
//! exact p50/p95/p99 request latency over a real loopback socket,
//! sweeping the number of concurrent clients.
//!
//! Everything in the measured path is real: framing, protocol
//! encode/decode, the coordinator queue with Reject backpressure, and the
//! worker lanes. The load generator is closed-loop (each client waits for
//! its response before sending the next request), so throughput saturates
//! at the worker pool, and overloaded replies count as backpressure
//! rather than failures.
//!
//! The second artifact (`serve_mux_load`) sweeps the v2 pipelined path:
//! shard count × {closed-loop, depth-8 cache-cold, depth-8 cache-hot}
//! over servers with the response cache enabled. It self-gates on the
//! two properties the protocol exists for — pipelining must beat the
//! closed loop on throughput at equal client count, and a ≥90% cache-hit
//! workload must beat the cold path on p50 latency.

use cordic_dct::bench::save_results;
use cordic_dct::coordinator::{Lane, ServiceConfig};
use cordic_dct::dct::Variant;
use cordic_dct::serve::{
    run_load, ImageMix, LoadReport, LoadSpec, ServeConfig, ShardGroup,
    TcpServer,
};
use cordic_dct::util::json::Json;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("CORDIC_DCT_BENCH_QUICK").is_ok();
    let (size, requests) = if quick { (64, 8) } else { (128, 32) };
    let client_sweep: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8] };
    let cfg = ServeConfig {
        service: ServiceConfig {
            workers: 4,
            queue_capacity: 64,
            artifact_dir: None,
            ..Default::default()
        },
        max_connections: 16,
        ..Default::default()
    };
    let server = TcpServer::bind("127.0.0.1:0", cfg)?;
    let addr = server.local_addr();
    println!(
        "== serve load ablation: {size}x{size} cordic gray, \
         {requests} req/client over {addr} =="
    );
    println!(
        "{:>8} {:>10} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "clients", "req/s", "p50 ms", "p95 ms", "p99 ms", "max ms",
        "err rate"
    );
    let mut reports = Vec::new();
    for &clients in client_sweep {
        let spec = LoadSpec {
            clients,
            requests_per_client: requests,
            size,
            color: false,
            variant: Variant::Cordic,
            lane: Lane::Cpu,
            want_psnr: false,
            ..LoadSpec::new(addr)
        };
        let report = run_load(&spec)?;
        println!(
            "{:>8} {:>10.1} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>9.3}",
            clients,
            report.throughput_rps,
            report.p50_ms,
            report.p95_ms,
            report.p99_ms,
            report.max_ms,
            report.error_rate
        );
        anyhow::ensure!(
            report.failed == 0,
            "{} request(s) failed under load",
            report.failed
        );
        reports.push(report);
    }
    server.shutdown();
    let text: String = reports
        .iter()
        .map(|r| format!("{r}\n"))
        .collect();
    let json = Json::obj(vec![
        ("table", Json::str("ablation_serve_load")),
        ("size", size.into()),
        ("requests_per_client", requests.into()),
        (
            "rows",
            Json::Arr(reports.iter().map(|r| r.to_json()).collect()),
        ),
    ])
    .to_string();
    save_results("ablation_serve_load", &text, &json);
    mux_sweep(quick)?;
    Ok(())
}

/// One measured row of the pipelined sweep.
struct MuxRow {
    shards: usize,
    mode: &'static str,
    pipeline: usize,
    report: LoadReport,
}

/// Pipelined (v2) sweep: shard count × {closed, depth-8 cold, depth-8
/// hot} against cache-enabled servers, self-gating on the pipelining
/// and caching wins.
fn mux_sweep(quick: bool) -> anyhow::Result<()> {
    let (size, requests) = if quick { (64, 16) } else { (128, 48) };
    let depth = 8;
    let clients = 2;
    let shard_sweep: &[usize] = &[1, 2];
    let mut rows: Vec<MuxRow> = Vec::new();
    println!(
        "== serve mux ablation: {size}x{size} cordic gray, {clients} \
         clients x {requests} req, pipeline depth {depth} =="
    );
    println!(
        "{:>7} {:>15} {:>6} {:>10} {:>9} {:>9} {:>9}",
        "shards", "mode", "depth", "req/s", "p50 ms", "p95 ms", "err rate"
    );
    for &shards in shard_sweep {
        let cfg = ServeConfig {
            service: ServiceConfig {
                workers: 4,
                queue_capacity: 64,
                artifact_dir: None,
                ..Default::default()
            },
            max_connections: 16,
            cache_bytes: 32 * 1024 * 1024,
            ..Default::default()
        };
        let group = ShardGroup::bind("127.0.0.1:0", shards, cfg)?;
        let addrs = group.addrs();
        let base = LoadSpec {
            clients,
            requests_per_client: requests,
            size,
            color: false,
            variant: Variant::Cordic,
            lane: Lane::Cpu,
            want_psnr: false,
            addrs: if shards > 1 { addrs.clone() } else { Vec::new() },
            ..LoadSpec::new(addrs[0])
        };
        // unique images keep both cold modes honest: the cache is live
        // on the server but never hits
        let modes: [(&'static str, usize, ImageMix); 3] = [
            ("closed", 0, ImageMix::Unique),
            ("pipelined-cold", depth, ImageMix::Unique),
            ("pipelined-hot", depth, ImageMix::Shared(1)),
        ];
        for (mode, pipeline, mix) in modes {
            let spec = LoadSpec {
                pipeline,
                mix,
                ..base.clone()
            };
            let report = run_load(&spec)?;
            println!(
                "{:>7} {:>15} {:>6} {:>10.1} {:>9.2} {:>9.2} {:>9.3}",
                shards,
                mode,
                pipeline,
                report.throughput_rps,
                report.p50_ms,
                report.p95_ms,
                report.error_rate
            );
            anyhow::ensure!(
                report.failed == 0,
                "{} request(s) failed in mux sweep ({mode}, {shards} \
                 shard(s))",
                report.failed
            );
            rows.push(MuxRow {
                shards,
                mode,
                pipeline,
                report,
            });
        }
        group.shutdown();
    }
    // the sweep gates itself: each property below is the reason the
    // corresponding subsystem exists
    for &shards in shard_sweep {
        let find = |mode: &str| {
            rows.iter()
                .find(|r| r.shards == shards && r.mode == mode)
                .expect("sweep row")
        };
        let closed = find("closed");
        let cold = find("pipelined-cold");
        let hot = find("pipelined-hot");
        anyhow::ensure!(
            cold.report.throughput_rps > closed.report.throughput_rps,
            "pipelining lost to the closed loop at {shards} shard(s): \
             {:.1} <= {:.1} req/s",
            cold.report.throughput_rps,
            closed.report.throughput_rps
        );
        anyhow::ensure!(
            hot.report.p50_ms < cold.report.p50_ms,
            "cache-hot p50 not below cold p50 at {shards} shard(s): \
             {:.2} >= {:.2} ms",
            hot.report.p50_ms,
            cold.report.p50_ms
        );
    }
    let text: String = rows
        .iter()
        .map(|r| {
            format!(
                "{} shard(s) {} depth {}: {}\n",
                r.shards, r.mode, r.pipeline, r.report
            )
        })
        .collect();
    let json = Json::obj(vec![
        ("table", Json::str("serve_mux_load")),
        ("size", size.into()),
        ("requests_per_client", requests.into()),
        ("clients", clients.into()),
        ("pipeline_depth", depth.into()),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("shards", r.shards.into()),
                            ("mode", Json::str(r.mode)),
                            ("pipeline", r.pipeline.into()),
                            ("report", r.report.to_json()),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
    .to_string();
    save_results("serve_mux_load", &text, &json);
    Ok(())
}
