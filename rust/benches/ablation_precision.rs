//! Precision ablation for the fixed-point CORDIC-Loeffler lane: sweep
//! `FxpPrecision` levels through the full compress pipeline and record,
//! per level, the wall time plus the reconstruction PSNR next to the
//! exact float DCT and the float CORDIC approximation at the same
//! quality.
//!
//! Two result sets are written (both under `CORDIC_DCT_BENCH_OUT`, or
//! `bench_results/`): `ablation_precision` with every row, and
//! `precision_psnr` with the same rows under the name the CI bench-smoke
//! job uploads as an artifact. `CORDIC_DCT_BENCH_QUICK=1` shrinks the
//! image and iteration count for CI.

use cordic_dct::bench::{bench_config, rows_to_json, save_results, Row};
use cordic_dct::dct::batch::EngineConfig;
use cordic_dct::dct::cordic_fxp::FxpPrecision;
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::Variant;
use cordic_dct::image::synthetic;
use cordic_dct::metrics;

const QUALITY: u8 = 50;
const LEVELS: [u32; 6] = [1, 2, 3, 4, 6, 8];

fn main() {
    let bench = bench_config();
    let size = if std::env::var("CORDIC_DCT_BENCH_QUICK").is_ok() {
        128
    } else {
        512
    };
    let img = synthetic::lena_like(size, size, 1);
    let mut rows: Vec<Row> = Vec::new();

    println!("== cordic-fxp precision ablation ({size}x{size}, q{QUALITY}) ==");

    // float references: the exact DCT and the float CORDIC approximation
    // the fixed-point lane is trying to track
    let mut exact_psnr = 0.0f64;
    for variant in [Variant::Dct, Variant::Cordic] {
        let pipe = CpuPipeline::new(variant, QUALITY);
        let psnr = metrics::psnr(&img, &pipe.compress(&img).recon);
        let stats = bench.run(|| pipe.compress(&img));
        if variant == Variant::Dct {
            exact_psnr = psnr;
        }
        println!(
            "{:<24} {:>10.3} ms   PSNR {psnr:.2} dB",
            variant.as_str(),
            stats.median_ms
        );
        rows.push(Row {
            label: format!("{} (float ref)", variant.as_str()),
            cpu: Some(stats),
            cpu_par: None,
            gpu: None,
            extra: vec![("psnr_db".into(), format!("{psnr:.3}"))],
        });
    }

    for level in LEVELS {
        let precision = FxpPrecision::from_level(level);
        let cfg = EngineConfig {
            precision,
            ..EngineConfig::default()
        };
        let pipe = CpuPipeline::with_config(Variant::CordicFxp, QUALITY, cfg);
        let psnr = metrics::psnr(&img, &pipe.compress(&img).recon);
        let stats = bench.run(|| pipe.compress(&img));
        println!(
            "cordic-fxp level {level} ({} iters, Q{:<2}) {:>10.3} ms   \
             PSNR {psnr:.2} dB (exact {:+.2} dB)",
            precision.iters,
            precision.frac_bits,
            stats.median_ms,
            psnr - exact_psnr
        );
        rows.push(Row {
            label: format!("cordic-fxp level {level}"),
            cpu: Some(stats),
            cpu_par: None,
            gpu: None,
            extra: vec![
                ("psnr_db".into(), format!("{psnr:.3}")),
                (
                    "delta_vs_exact_db".into(),
                    format!("{:.3}", psnr - exact_psnr),
                ),
                ("iters".into(), precision.iters.to_string()),
                ("frac_bits".into(), precision.frac_bits.to_string()),
            ],
        });
    }

    let text = format!("{rows:#?}");
    save_results(
        "ablation_precision",
        &text,
        &rows_to_json("ablation_precision", &rows),
    );
    save_results(
        "precision_psnr",
        &text,
        &rows_to_json("precision_psnr", &rows),
    );
}
