//! A7 ablation: chaos soak — the TCP front-end under seeded fault
//! injection, with retrying circuit-breaking clients asserting the
//! resilience invariants.
//!
//! The server runs with a deliberately hostile (but reproducible) fault
//! plan: slow and short socket reads/writes, mid-frame disconnects,
//! worker panics, artificial job latency, and outbound payload
//! bit-flips. Degraded-mode load shedding is on. The chaos-mode load
//! generator then checks, per request:
//!
//! 1. no request outlives the retry policy's worst-case budget
//!    (client hang = violation),
//! 2. every success carries a container that decodes and is bit-exact
//!    against the client's reference reply (a surviving bit-flip =
//!    violation via the decode-error bucket — it must never count as
//!    success),
//!
//! and, run-wide: the error rate stays bounded, some requests still
//! succeed, and the server drains cleanly on shutdown. The whole soak
//! is deterministic from the two seeds below.

use std::time::Duration;

use cordic_dct::bench::save_results;
use cordic_dct::coordinator::{Lane, ServiceConfig};
use cordic_dct::dct::Variant;
use cordic_dct::faults::FaultPlan;
use cordic_dct::serve::{run_load, LoadSpec, ServeConfig, TcpServer};
use cordic_dct::util::json::Json;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("CORDIC_DCT_BENCH_QUICK").is_ok();
    let (size, requests, clients) =
        if quick { (48, 12, 3) } else { (96, 32, 6) };
    let plan = FaultPlan::parse(
        "seed=7,slow-read=0.05,slow-write=0.05,short-read=0.1,\
         short-write=0.1,disconnect=0.02,bitflip=0.02,panic=0.03,\
         latency=0.1,latency-ms=3,slow-ms=2",
    )?;
    let cfg = ServeConfig {
        service: ServiceConfig {
            workers: 2,
            queue_capacity: 16,
            artifact_dir: None,
            ..Default::default()
        },
        max_connections: 16,
        faults: Some(plan.clone()),
        degrade: true,
        ..Default::default()
    };
    let server = TcpServer::bind("127.0.0.1:0", cfg)?;
    let addr = server.local_addr();
    println!(
        "== chaos soak: {clients} clients x {requests} req, \
         {size}x{size} cordic gray over {addr} =="
    );
    println!("fault plan: {plan:?}");
    let spec = LoadSpec {
        clients,
        requests_per_client: requests,
        size,
        color: false,
        variant: Variant::Cordic,
        lane: Lane::Cpu,
        want_psnr: false,
        faults: true,
        deadline: Duration::from_secs(10),
        seed: 11,
        ..LoadSpec::new(addr)
    };
    let report = run_load(&spec)?;
    println!("{report}");
    println!(
        "errors: {} timeout / {} connect / {} decode / {} panic / \
         {} server",
        report.errors.timeouts,
        report.errors.connect,
        report.errors.decode,
        report.errors.panics,
        report.errors.server
    );
    // invariants: violations are resilience bugs, not load noise
    anyhow::ensure!(
        report.invariant_violations == 0,
        "{} invariant violation(s) under injected faults",
        report.invariant_violations
    );
    anyhow::ensure!(
        report.ok >= 1,
        "no request survived the fault plan — the soak proves nothing"
    );
    anyhow::ensure!(
        report.error_rate <= 0.75,
        "error rate {:.2} exceeds the 0.75 chaos bound",
        report.error_rate
    );
    // clean drain: shutdown() joins the accept thread, the connection
    // pool, and the (possibly respawned) workers — a hang here fails
    // the bench via the CI job timeout
    server.shutdown();
    println!("server drained cleanly");
    let json = Json::obj(vec![
        ("table", Json::str("ablation_chaos")),
        ("size", size.into()),
        ("clients", clients.into()),
        ("requests_per_client", requests.into()),
        ("fault_seed", Json::num(7.0)),
        ("jitter_seed", Json::num(11.0)),
        ("report", report.to_json()),
    ])
    .to_string();
    save_results("ablation_chaos", &format!("{report}\n"), &json);
    Ok(())
}
