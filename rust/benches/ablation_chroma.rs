//! Chroma ablation: the color (YCbCr) workload against the grayscale
//! baseline.
//!
//! Part A — color-vs-gray throughput: one gray compress vs one color
//! compress (3 planes, 4:2:0) at the same pixel count, serial and
//! parallel CPU lanes. The color job processes ~1.5x the samples of the
//! gray job under 4:2:0, so its wall time should land in that
//! neighborhood — far below the 3x a naive per-channel RGB codec pays.
//!
//! Part B — subsampling sweep: 4:4:4 / 4:2:2 / 4:2:0 across qualities,
//! recording weighted + per-plane PSNR and encoded bytes. Luma PSNR must
//! be mode-invariant (chroma decimation never touches Y).
//!
//! Part A also times the GPU lane (the planar-batch executor — PJRT when
//! artifacts exist, else the stub backend) on the same gray and color
//! jobs, filling the `gpu_ms` column and adding `gpu_backend` /
//! `gpu_psnr_weighted` to the color row; on the stub backend the GPU
//! reconstruction is asserted bit-identical to the serial CPU lane.
//!
//! Set CORDIC_DCT_BENCH_QUICK=1 to trim sizes + iterations (CI).

use std::sync::Arc;

use cordic_dct::bench::{bench_config, render_table, rows_to_json,
                        save_results, Row};
use cordic_dct::codec::{self, color as color_codec};
use cordic_dct::dct::color::{ColorPipeline, PlaneCoef};
use cordic_dct::dct::parallel::ParallelCpuPipeline;
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::Variant;
use cordic_dct::image::synthetic;
use cordic_dct::image::ycbcr::{rgb_to_ycbcr, Subsampling};
use cordic_dct::metrics;
use cordic_dct::metrics::color::psnr_color;
use cordic_dct::runtime::{Executor, Runtime};

/// Container size of already-computed plane coefficients (no second
/// forward transform — `compress` just produced these planes).
fn container_bytes(
    pipe: &ColorPipeline,
    w: usize,
    h: usize,
    planes: &[PlaneCoef; 3],
) -> anyhow::Result<usize> {
    let header = color_codec::ColorHeader {
        width: w as u32,
        height: h as u32,
        quality: pipe.quality,
        variant: codec::variant_tag(pipe.variant),
        subsampling: color_codec::subsampling_tag(pipe.subsampling),
    };
    Ok(color_codec::encode(&header, planes)?.len())
}

fn main() -> anyhow::Result<()> {
    let bench = bench_config();
    let quick = std::env::var("CORDIC_DCT_BENCH_QUICK").is_ok();
    let size = if quick { 256 } else { 512 };
    let variant = Variant::Cordic;
    let gray = synthetic::lena_like(size, size, 1);
    let rgb = synthetic::lena_like_rgb(size, size, 1);
    let mut rows = Vec::new();

    // Part A: color-vs-gray throughput, serial + parallel lanes
    println!("== color vs gray throughput ({size}x{size}, 4:2:0) ==");
    let ser_gray_pipe = CpuPipeline::new(variant, 50);
    let par_gray_pipe = ParallelCpuPipeline::new(variant, 50);
    let ser_color_pipe =
        ColorPipeline::new(variant, 50, Subsampling::S420);
    let par_color_pipe =
        ColorPipeline::parallel(variant, 50, Subsampling::S420, 0);
    // GPU lane: the planar-batch executor — PJRT when it loads and its
    // artifacts cover both bench workloads at this size, else the stub
    // backend (bit-identical to the CPU lanes)
    let mut gpu_ex =
        Executor::new(Arc::new(Runtime::new_or_stub("artifacts", 50)));
    if !gpu_ex.rt.is_stub()
        && !(gpu_ex.supports_gray(size, size, variant.as_str())
            && gpu_ex.supports_color(
                size,
                size,
                variant.as_str(),
                Subsampling::S420,
            ))
    {
        gpu_ex = Executor::new(Arc::new(Runtime::stub(50)));
    }
    let gpu_backend = if gpu_ex.rt.is_stub() { "stub" } else { "pjrt" };
    let gray_gpu =
        bench.run(|| gpu_ex.compress(&gray, variant.as_str()).unwrap());
    let color_gpu = bench.run(|| {
        gpu_ex
            .compress_color(&rgb, variant, Subsampling::S420)
            .unwrap()
    });
    let gpu_color_out = gpu_ex
        .compress_color(&rgb, variant, Subsampling::S420)?;
    let gpu_color_psnr = psnr_color(&rgb, &gpu_color_out.recon);
    let gray_ser = bench.run(|| ser_gray_pipe.compress(&gray));
    let gray_par = bench.run(|| par_gray_pipe.compress(&gray));
    let color_ser = bench.run(|| ser_color_pipe.compress(&rgb));
    let color_par = bench.run(|| par_color_pipe.compress(&rgb));
    if gpu_backend == "stub" {
        // the stub GPU lane must be bit-identical to the serial CPU lane
        let cpu_out = ser_color_pipe.compress(&rgb);
        assert_eq!(gpu_color_out.recon, cpu_out.recon);
        assert_eq!(gpu_color_out.scanned, cpu_out.scanned);
    }
    println!(
        "{:<12} {:>12} {:>12} {:>12}",
        "workload", "serial ms", "parallel ms", "gpu ms"
    );
    println!(
        "{:<12} {:>12.2} {:>12.2} {:>12.2}",
        "gray", gray_ser.median_ms, gray_par.median_ms,
        gray_gpu.median_ms
    );
    println!(
        "{:<12} {:>12.2} {:>12.2} {:>12.2} ({:.2}x the gray serial \
         cost; gpu={gpu_backend})",
        "color_420",
        color_ser.median_ms,
        color_par.median_ms,
        color_gpu.median_ms,
        color_ser.median_ms / gray_ser.median_ms.max(1e-9)
    );
    rows.push(Row {
        label: "gray".into(),
        cpu: Some(gray_ser.clone()),
        cpu_par: Some(gray_par),
        gpu: Some(gray_gpu),
        extra: vec![
            ("workload".into(), "gray".into()),
            ("gpu_backend".into(), gpu_backend.into()),
        ],
    });
    rows.push(Row {
        label: "color_420".into(),
        cpu: Some(color_ser.clone()),
        cpu_par: Some(color_par),
        gpu: Some(color_gpu),
        extra: vec![
            ("workload".into(), "color".into()),
            ("gpu_backend".into(), gpu_backend.into()),
            (
                "gpu_psnr_weighted".into(),
                format!("{:.4}", gpu_color_psnr.weighted),
            ),
            (
                "color_over_gray".into(),
                format!(
                    "{:.3}",
                    color_ser.median_ms / gray_ser.median_ms.max(1e-9)
                ),
            ),
        ],
    });

    // Part B: subsampling sweep across qualities
    println!("\n== chroma subsampling sweep ({size}x{size}) ==");
    println!(
        "{:<10} {:>8} {:>9} {:>9} {:>9} {:>10}",
        "mode", "quality", "Y(dB)", "wtd(dB)", "bytes", "ms"
    );
    let (y_src, _, _) = rgb_to_ycbcr(&rgb);
    let mut luma_by_quality: Vec<(u8, f64)> = Vec::new();
    for &quality in &[10u8, 50, 90] {
        for mode in Subsampling::ALL {
            let pipe = ColorPipeline::new(variant, quality, mode);
            let out = pipe.compress(&rgb);
            let p = psnr_color(&rgb, &out.recon);
            // plane-level luma PSNR: exactly mode-invariant (the Y path
            // never sees the chroma decimation)
            let psnr_y = metrics::psnr(&y_src, &out.recon_y);
            let bytes = container_bytes(
                &pipe,
                rgb.width,
                rgb.height,
                &out.planes,
            )?;
            let t = bench.run(|| pipe.compress(&rgb));
            println!(
                "{:<10} {:>8} {:>9.2} {:>9.2} {:>9} {:>10.2}",
                mode.as_str(),
                quality,
                psnr_y,
                p.weighted,
                bytes,
                t.median_ms
            );
            // luma invariance across modes at one quality
            match luma_by_quality.iter().find(|(q, _)| *q == quality) {
                Some(&(_, y0)) => assert!(
                    (psnr_y - y0).abs() < 1e-9,
                    "luma PSNR varies with chroma mode: {y0} vs \
                     {psnr_y}"
                ),
                None => luma_by_quality.push((quality, psnr_y)),
            }
            rows.push(Row {
                label: format!("{}_q{quality}", mode.tag()),
                cpu: Some(t),
                cpu_par: None,
                gpu: None,
                extra: vec![
                    ("mode".into(), mode.as_str().into()),
                    ("quality".into(), quality.to_string()),
                    ("psnr_y".into(), format!("{psnr_y:.4}")),
                    (
                        "psnr_weighted".into(),
                        format!("{:.4}", p.weighted),
                    ),
                    ("bytes".into(), bytes.to_string()),
                ],
            });
        }
    }
    println!("luma invariance: plane-level Y PSNR identical across modes");

    let text = render_table("ablation: chroma subsampling", &rows);
    save_results(
        "ablation_chroma",
        &text,
        &rows_to_json("ablation_chroma", &rows),
    );
    Ok(())
}
