//! A2 ablation: coordinator batching policy — throughput and latency of
//! the service under a same-shape burst, sweeping gpu_max_batch and
//! worker count. Uses the CPU lane fallback when artifacts are missing so
//! the queue/batcher mechanics are measured either way.

use std::time::Instant;

use cordic_dct::bench::{rows_to_json, save_results, Row};
use cordic_dct::coordinator::{
    Backpressure, Lane, Service, ServiceConfig,
};
use cordic_dct::coordinator::batcher::BatchPolicy;
use cordic_dct::dct::Variant;
use cordic_dct::image::synthetic;
use cordic_dct::util::timer::Stats;

fn run_once(workers: usize, batch: usize, n: usize, lane: Lane)
            -> anyhow::Result<(f64, f64)> {
    let cfg = ServiceConfig {
        workers,
        queue_capacity: n.max(4),
        backpressure: Backpressure::Block,
        batch: BatchPolicy {
            gpu_max_batch: batch,
            cpu_max_batch: batch,
            cpu_parallel_max_batch: batch,
            linger: std::time::Duration::from_micros(if batch > 1 {
                200
            } else {
                0
            }),
        },
        quality: 50,
        cpu_parallel_workers: 0,
        artifact_dir: Some("artifacts".into()),
        stub_gpu: false,
    };
    let svc = Service::start(cfg)?;
    let img = synthetic::lena_like(200, 200, 5); // 200x200 has artifacts
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n)
        .map(|_| svc.compress(img.clone(), Variant::Cordic, lane))
        .collect::<anyhow::Result<_>>()?;
    let mut total_lat = 0.0;
    for h in handles {
        let r = h.wait();
        r.result?;
        total_lat += r.queue_ms + r.process_ms;
    }
    let wall = t0.elapsed().as_secs_f64();
    svc.shutdown();
    Ok((n as f64 / wall, total_lat / n as f64))
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("CORDIC_DCT_BENCH_QUICK").is_ok();
    let n = if quick { 24 } else { 64 };
    let lane = if std::path::Path::new("artifacts/manifest.json").exists() {
        Lane::Gpu
    } else {
        Lane::Cpu
    };
    println!(
        "== batching ablation: {n} x 200x200 cordic jobs, lane {lane:?} =="
    );
    println!(
        "{:>8} {:>8} {:>14} {:>14}",
        "workers", "batch", "req/s", "mean lat (ms)"
    );
    let mut rows = Vec::new();
    let workers_sweep: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8] };
    let batch_sweep: &[usize] = if quick { &[1, 8] } else { &[1, 2, 8, 32] };
    for &workers in workers_sweep {
        for &batch in batch_sweep {
            let (rps, lat) = run_once(workers, batch, n, lane)?;
            println!("{workers:>8} {batch:>8} {rps:>14.1} {lat:>14.1}");
            rows.push(Row {
                label: format!("w{workers}_b{batch}"),
                cpu: Some(Stats::from_samples_ms(&[lat])),
                cpu_par: None,
                gpu: None,
                extra: vec![
                    ("workers".into(), workers.to_string()),
                    ("batch".into(), batch.to_string()),
                    ("req_per_s".into(), format!("{rps:.2}")),
                ],
            });
        }
    }
    save_results(
        "ablation_batching",
        &format!("{rows:#?}"),
        &rows_to_json("ablation_batching", &rows),
    );
    Ok(())
}
