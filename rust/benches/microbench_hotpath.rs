//! P1: hot-path microbenchmarks for the §Perf pass — per-component cost
//! so the optimization loop knows where the time goes:
//!
//! * block extract/store (layout plumbing)
//! * each 8x8 forward transform (including the fixed-point cordic-fxp
//!   lane), scalar path vs the 8-wide batched lane-major engine
//!   (`dct::batch`), with blocks/s + MB/s columns and the
//!   batched/scalar speedup recorded per variant; a 16-wide
//!   `batched16` row per variant shows the wide-lane figure
//!   (informational — the perf-sanity gate stays on the 8-wide path)
//! * quantize: scalar, batched, and fused batched quantize→zigzag
//! * Huffman: full entropy encode and decode (64-bit accumulator writer,
//!   LUT decoder)
//! * PJRT literal marshaling vs execute (GPU-lane overhead split)
//!
//! * steady-state allocation audit: with a cached pipeline and a reused
//!   scan buffer, repeat analysis of an 8-aligned image through
//!   `analyze_scanned_into` must be allocation-free (counted by a
//!   wrapping global allocator)
//!
//! With `CORDIC_DCT_PERF_SANITY=1` the process exits non-zero if the
//! batched engine is slower than the scalar path on the transform stage,
//! or if the steady-state analysis path allocates (the CI perf-sanity
//! gate; the transform check is gated on the paper's Cordic variant).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use cordic_dct::bench::tables::try_runtime;
use cordic_dct::bench::{bench_config, rows_to_json, save_results, Row};
use cordic_dct::codec::zigzag;
use cordic_dct::codec::{decoder, encoder, variant_tag, Header};
use cordic_dct::dct::batch::{
    gather, quantize_batch, quantize_zigzag_batch, BatchTransform,
    BlockBatch16, BlockBatch8, QBatch8, LANES, LANES_WIDE,
};
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::{blocks, quant, Variant};
use cordic_dct::image::synthetic;

const W: usize = 512;
const H: usize = 512;

/// Counts heap acquisitions (alloc / alloc_zeroed / realloc) so the
/// steady-state stage can assert the hot path is allocation-free.
/// Frees are deliberately not counted: reusing a buffer is the goal,
/// shrinking one is fine.
struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn main() -> anyhow::Result<()> {
    let bench = bench_config();
    let img = synthetic::lena_like(W, H, 1);
    let padded = blocks::pad_to_blocks(&img);
    let (gw, gh) = blocks::grid_dims(padded.width, padded.height);
    let nblocks = (gw * gh) as f64;
    let mb = (W * H) as f64 / 1e6; // 8-bit pixels -> MB per image pass
    let mut rows: Vec<Row> = Vec::new();
    let mut report = |label: &str,
                      stats: cordic_dct::util::timer::Stats,
                      per: f64,
                      unit: &str,
                      extra: Vec<(String, String)>| {
        println!(
            "{label:<28} {:>10.3} ms   {:>10.1} ns/{unit}",
            stats.median_ms,
            stats.median_ms * 1e6 / per
        );
        let mut e = vec![("unit".into(), unit.into())];
        e.extend(extra);
        rows.push(Row {
            label: label.into(),
            cpu: Some(stats),
            cpu_par: None,
            gpu: None,
            extra: e,
        });
    };
    // throughput columns for the bench JSON: blocks/s and MB/s of image
    // data per pass at the stage's median
    let throughput = |median_ms: f64| -> Vec<(String, String)> {
        let secs = median_ms / 1e3;
        vec![
            (
                "blocks_per_s".into(),
                format!("{:.0}", nblocks / secs),
            ),
            ("mb_per_s".into(), format!("{:.2}", mb / secs)),
        ]
    };

    println!("== hot-path microbench ({W}x{H}) ==");

    // layout plumbing
    let mut block = [0.0f32; 64];
    let s = bench.run(|| {
        for by in 0..gh {
            for bx in 0..gw {
                blocks::extract_block(&padded, bx, by, &mut block);
                std::hint::black_box(&block);
            }
        }
    });
    let e = throughput(s.median_ms);
    report("extract all blocks", s, nblocks, "block", e);

    // transforms: scalar one-block-at-a-time vs the 8-wide batched
    // engine, whole-grid passes of the same 4096 blocks
    let mut sanity: Vec<(Variant, f64, f64)> = Vec::new();
    for variant in [
        Variant::Dct,
        Variant::Loeffler,
        Variant::Cordic,
        Variant::CordicFxp,
    ] {
        let t = variant.transform();
        let s_scalar = bench.run(|| {
            for by in 0..gh {
                for bx in 0..gw {
                    blocks::extract_block(&padded, bx, by, &mut block);
                    t.forward(&mut block);
                    std::hint::black_box(&block);
                }
            }
        });
        let e = throughput(s_scalar.median_ms);
        report(
            &format!("fwd {} scalar", t.name()),
            s_scalar.clone(),
            nblocks,
            "block",
            e,
        );

        let bt = BatchTransform::new(variant);
        let mut batch = BlockBatch8::zeroed();
        let s_batched = bench.run(|| {
            for by in 0..gh {
                let mut bx = 0;
                while bx + LANES <= gw {
                    gather(&mut batch, &padded, bx, by, LANES);
                    bt.forward_batch(&mut batch);
                    std::hint::black_box(&batch);
                    bx += LANES;
                }
                while bx < gw {
                    blocks::extract_block(&padded, bx, by, &mut block);
                    bt.forward_scalar(&mut block);
                    std::hint::black_box(&block);
                    bx += 1;
                }
            }
        });
        let speedup = s_scalar.median_ms / s_batched.median_ms;
        let mut e = throughput(s_batched.median_ms);
        e.push((
            "speedup_vs_scalar".into(),
            format!("{speedup:.2}"),
        ));
        report(
            &format!("fwd {} batched", bt.name()),
            s_batched.clone(),
            nblocks,
            "block",
            e,
        );

        // 16-wide figure for the same grid: wide batches plus the
        // scalar tail the engine would run on a non-multiple width
        let mut wide = BlockBatch16::zeroed();
        let s_wide = bench.run(|| {
            for by in 0..gh {
                let mut bx = 0;
                while bx + LANES_WIDE <= gw {
                    gather(&mut wide, &padded, bx, by, LANES_WIDE);
                    bt.forward_batch(&mut wide);
                    std::hint::black_box(&wide);
                    bx += LANES_WIDE;
                }
                while bx < gw {
                    blocks::extract_block(&padded, bx, by, &mut block);
                    bt.forward_scalar(&mut block);
                    std::hint::black_box(&block);
                    bx += 1;
                }
            }
        });
        let mut e = throughput(s_wide.median_ms);
        e.push((
            "speedup_vs_scalar".into(),
            format!("{:.2}", s_scalar.median_ms / s_wide.median_ms),
        ));
        e.push((
            "speedup_vs_batched8".into(),
            format!("{:.2}", s_batched.median_ms / s_wide.median_ms),
        ));
        report(
            &format!("fwd {} batched16", bt.name()),
            s_wide,
            nblocks,
            "block",
            e,
        );
        sanity.push((variant, s_scalar.median_ms, s_batched.median_ms));
    }

    // quantization: scalar, batched, fused batched quantize->zigzag
    let q = quant::effective_qtable(50);
    let coef: [f32; 64] = std::array::from_fn(|i| (i as f32) * 3.7 - 100.0);
    let mut qc = [0i16; 64];
    let s = bench.run(|| {
        for _ in 0..1024 {
            quant::quantize_block(&coef, &q, &mut qc);
            std::hint::black_box(&qc);
        }
    });
    report("quantize scalar x1024", s, 1024.0, "block", vec![]);

    let mut qbatch = BlockBatch8::zeroed();
    for l in 0..LANES {
        qbatch.insert_lane(l, &coef);
    }
    let mut qout = QBatch8::zeroed();
    let s = bench.run(|| {
        for _ in 0..128 {
            quantize_batch(&qbatch, &q, &mut qout);
            std::hint::black_box(&qout);
        }
    });
    report("quantize batched x1024", s, 1024.0, "block", vec![]);
    let s = bench.run(|| {
        for _ in 0..128 {
            quantize_zigzag_batch(&qbatch, &q, &mut qout);
            std::hint::black_box(&qout);
        }
    });
    report("quantize+zigzag batched", s, 1024.0, "block", vec![]);

    // zigzag + symbols
    let s = bench.run(|| {
        for _ in 0..1024 {
            let z = zigzag::scan(&qc);
            std::hint::black_box(
                cordic_dct::codec::rle::encode_block(&z, 0),
            );
        }
    });
    report("zigzag+rle x1024", s, 1024.0, "block", vec![]);

    // full entropy encode + decode (Huffman fast paths)
    let pipe = CpuPipeline::new(Variant::Cordic, 50);
    let (qcoef, pw, ph) = pipe.analyze(&img);
    let header = Header {
        width: W as u32,
        height: H as u32,
        padded_width: pw as u32,
        padded_height: ph as u32,
        quality: 50,
        variant: variant_tag(Variant::Cordic),
    };
    let s = bench.run(|| encoder::encode(&header, &qcoef).unwrap());
    let e = throughput(s.median_ms);
    report("entropy encode image", s, nblocks, "block", e);
    let bytes = encoder::encode(&header, &qcoef)?;
    let s = bench.run(|| decoder::decode(&bytes).unwrap());
    let e = throughput(s.median_ms);
    report("entropy decode image", s, nblocks, "block", e);

    // full CPU pipeline for scale
    let s = bench.run(|| pipe.compress(&img));
    let e = throughput(s.median_ms);
    report("full cpu pipeline", s, nblocks, "block", e);

    // serve cache hit: everything a warm hit costs the server instead
    // of the compress above — key derivation (FNV over the pixels),
    // sharded lookup, and cloning the container bytes out
    {
        use cordic_dct::serve::cache::CachedReply;
        use cordic_dct::serve::{CacheKey, RequestMsg, ResponseCache};
        let cache = ResponseCache::new(32 * 1024 * 1024, 8);
        let msg = RequestMsg::CompressGray {
            image: img.clone(),
            variant: Variant::Cordic,
            lane: cordic_dct::coordinator::Lane::Cpu,
            want_psnr: false,
        };
        let key = CacheKey::for_request(&msg, 50, 4)
            .expect("compress requests are cacheable");
        cache.insert(
            key,
            CachedReply {
                lane: cordic_dct::coordinator::Lane::Cpu,
                psnr_db: None,
                container: std::sync::Arc::new(bytes.clone()),
            },
        );
        let s = bench.run(|| {
            let k = CacheKey::for_request(&msg, 50, 4).unwrap();
            let hit = cache.get(&k).expect("warm hit");
            std::hint::black_box((*hit.container).clone());
        });
        report("serve cache hit", s, 1.0, "req", vec![]);
    }

    // steady-state allocation audit: cached pipeline + reused scan
    // buffer; 512x512 is 8-aligned so the image is borrowed, never
    // padded-by-copy. After one warmup pass (scratch pool fill, buffer
    // sizing) repeat analysis must not touch the heap at all.
    let mut scan = encoder::ScanCoefs::zeroed(W, H, W, H);
    pipe.analyze_scanned_into(&img, &mut scan);
    let s = bench.run(|| {
        pipe.analyze_scanned_into(&img, &mut scan);
        std::hint::black_box(&scan);
    });
    const AUDIT_ITERS: u64 = 32;
    let before = ALLOC_COUNT.load(Ordering::Relaxed);
    for _ in 0..AUDIT_ITERS {
        pipe.analyze_scanned_into(&img, &mut scan);
        std::hint::black_box(&scan);
    }
    let steady_allocs = ALLOC_COUNT.load(Ordering::Relaxed) - before;
    println!(
        "steady-state analyze: {steady_allocs} heap allocation(s) over \
         {AUDIT_ITERS} passes"
    );
    let e = vec![
        ("allocs_per_pass".into(), {
            format!("{:.2}", steady_allocs as f64 / AUDIT_ITERS as f64)
        }),
        ("audit_iters".into(), AUDIT_ITERS.to_string()),
    ];
    report("analyze steady-state", s, nblocks, "block", e);

    // PJRT overhead split
    if let Some(rt) = try_runtime() {
        let exe = rt.executable("compress_cordic_512x512")?;
        let input = img.to_f32();
        let s = bench.run(|| exe.run_f32(&[(&input, 512, 512)]).unwrap());
        report("pjrt execute (warm)", s, nblocks, "block", vec![]);
        // marshaling only: build + drop the literal
        let s = bench.run(|| {
            let t0 = Instant::now();
            let lit = xla_literal_roundtrip(&input);
            std::hint::black_box(lit);
            t0.elapsed()
        });
        report("literal marshal 1 MPix", s, 512.0 * 512.0, "pixel", vec![]);
    } else {
        println!("(pjrt rows skipped: no artifacts)");
    }

    let text = format!("{rows:#?}");
    save_results(
        "microbench_hotpath",
        &text,
        &rows_to_json("microbench_hotpath", &rows),
    );

    // CI perf-sanity gate: the batched engine must not lose to the
    // scalar path on the transform stage (checked on the paper's Cordic
    // variant, where the lane-major win is structural, not noise-bound)
    if std::env::var("CORDIC_DCT_PERF_SANITY").is_ok() {
        let (_, scalar_ms, batched_ms) = sanity
            .iter()
            .find(|(v, _, _)| *v == Variant::Cordic)
            .copied()
            .expect("cordic transform stage measured");
        let speedup = scalar_ms / batched_ms;
        println!(
            "perf-sanity: cordic transform scalar {scalar_ms:.3} ms vs \
             batched {batched_ms:.3} ms ({speedup:.2}x)"
        );
        // 10% tolerance so shared-runner noise can't fail an unrelated
        // PR; a real regression (batched losing its structural win)
        // still lands far below 0.9x
        if batched_ms > scalar_ms * 1.10 {
            eprintln!(
                "perf-sanity FAILED: batched cordic transform is slower \
                 than scalar ({batched_ms:.3} ms > {scalar_ms:.3} ms)"
            );
            std::process::exit(1);
        }
        // the fused analysis path must stay allocation-free in steady
        // state — any hot-path Vec/Box that sneaks back in fails CI
        if steady_allocs != 0 {
            eprintln!(
                "perf-sanity FAILED: steady-state analyze allocated \
                 {steady_allocs} time(s) over {AUDIT_ITERS} passes \
                 (expected 0)"
            );
            std::process::exit(1);
        }
    }
    Ok(())
}

fn xla_literal_roundtrip(input: &[f32]) -> usize {
    let lit = xla::Literal::vec1(input);
    let lit = lit.reshape(&[512, 512]).unwrap();
    lit.to_vec::<f32>().map(|v| v.len()).unwrap_or(0)
}
