//! P1: hot-path microbenchmarks for the §Perf pass — per-component cost
//! so the optimization loop knows where the time goes:
//!
//! * block extract/store (layout plumbing)
//! * each 8x8 forward transform
//! * quantize/dequantize
//! * zigzag + RLE symbolization
//! * Huffman table build + full entropy encode
//! * PJRT literal marshaling vs execute (GPU-lane overhead split)

use std::time::Instant;

use cordic_dct::bench::{bench_config, rows_to_json, save_results, Row};
use cordic_dct::bench::tables::try_runtime;
use cordic_dct::codec::{encoder, variant_tag, Header};
use cordic_dct::codec::zigzag;
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::{blocks, quant, Variant};
use cordic_dct::image::synthetic;

fn main() -> anyhow::Result<()> {
    let bench = bench_config();
    let img = synthetic::lena_like(512, 512, 1);
    let padded = blocks::pad_to_blocks(&img);
    let (gw, gh) = blocks::grid_dims(padded.width, padded.height);
    let nblocks = (gw * gh) as f64;
    let mut rows: Vec<Row> = Vec::new();
    let mut report = |label: &str, stats: cordic_dct::util::timer::Stats,
                      per: f64, unit: &str| {
        println!(
            "{label:<28} {:>10.3} ms   {:>10.1} ns/{unit}",
            stats.median_ms,
            stats.median_ms * 1e6 / per
        );
        rows.push(Row {
            label: label.into(),
            cpu: Some(stats),
            cpu_par: None,
            gpu: None,
            extra: vec![("unit".into(), unit.into())],
        });
    };

    println!("== hot-path microbench (512x512) ==");

    // layout plumbing
    let mut block = [0.0f32; 64];
    let s = bench.run(|| {
        for by in 0..gh {
            for bx in 0..gw {
                blocks::extract_block(&padded, bx, by, &mut block);
                std::hint::black_box(&block);
            }
        }
    });
    report("extract all blocks", s, nblocks, "block");

    // transforms
    for variant in [
        Variant::Naive,
        Variant::Dct,
        Variant::Loeffler,
        Variant::Cordic,
    ] {
        let t = variant.transform();
        let proto: [f32; 64] = std::array::from_fn(|i| (i as f32) - 32.0);
        let s = bench.run(|| {
            let mut b = proto;
            for _ in 0..1024 {
                t.forward(&mut b);
                std::hint::black_box(&b);
            }
        });
        report(
            &format!("fwd8x8 {} x1024", t.name()),
            s,
            1024.0,
            "block",
        );
    }

    // quantization
    let q = quant::effective_qtable(50);
    let coef: [f32; 64] = std::array::from_fn(|i| (i as f32) * 3.7 - 100.0);
    let mut qc = [0i16; 64];
    let s = bench.run(|| {
        for _ in 0..1024 {
            quant::quantize_block(&coef, &q, &mut qc);
            std::hint::black_box(&qc);
        }
    });
    report("quantize x1024", s, 1024.0, "block");

    // zigzag + symbols
    let s = bench.run(|| {
        for _ in 0..1024 {
            let z = zigzag::scan(&qc);
            std::hint::black_box(
                cordic_dct::codec::rle::encode_block(&z, 0),
            );
        }
    });
    report("zigzag+rle x1024", s, 1024.0, "block");

    // full entropy encode
    let pipe = CpuPipeline::new(Variant::Cordic, 50);
    let (qcoef, pw, ph) = pipe.analyze(&img);
    let header = Header {
        width: 512,
        height: 512,
        padded_width: pw as u32,
        padded_height: ph as u32,
        quality: 50,
        variant: variant_tag(Variant::Cordic),
    };
    let s = bench.run(|| encoder::encode(&header, &qcoef).unwrap());
    report("entropy encode image", s, nblocks, "block");

    // full CPU pipeline for scale
    let s = bench.run(|| pipe.compress(&img));
    report("full cpu pipeline", s, nblocks, "block");

    // PJRT overhead split
    if let Some(rt) = try_runtime() {
        let exe = rt.executable("compress_cordic_512x512")?;
        let input = img.to_f32();
        let s = bench.run(|| exe.run_f32(&[(&input, 512, 512)]).unwrap());
        report("pjrt execute (warm)", s, nblocks, "block");
        // marshaling only: build + drop the literal
        let s = bench.run(|| {
            let t0 = Instant::now();
            let lit = xla_literal_roundtrip(&input);
            std::hint::black_box(lit);
            t0.elapsed()
        });
        report("literal marshal 1 MPix", s, 512.0 * 512.0, "pixel");
    } else {
        println!("(pjrt rows skipped: no artifacts)");
    }

    let text = format!("{rows:#?}");
    save_results(
        "microbench_hotpath",
        &text,
        &rows_to_json("microbench_hotpath", &rows),
    );
    Ok(())
}

fn xla_literal_roundtrip(input: &[f32]) -> usize {
    let lit = xla::Literal::vec1(input);
    let lit = lit.reshape(&[512, 512]).unwrap();
    lit.to_vec::<f32>().map(|v| v.len()).unwrap_or(0)
}
