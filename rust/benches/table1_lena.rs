//! E1: paper Table 1 — time comparison of the grayscale DCT-compression
//! pipeline on Lena across the paper's seven sizes, CPU (serial rust)
//! vs GPU (PJRT) lane.
//!
//! Set CORDIC_DCT_BENCH_QUICK=1 to trim to <=1 MPixel sizes.

use cordic_dct::bench::tables;

fn main() -> anyhow::Result<()> {
    tables::run_timing_experiment(
        "table1_lena",
        "Table 1: Lena pipeline timing (CPU serial vs PJRT)",
        "lena",
        tables::LENA_SIZES,
        tables::PAPER_TABLE1,
    )
}
