//! A8 ablation: error resilience of the CDC2 container — restart
//! interval vs size overhead vs salvage quality under seeded payload
//! bit-flips.
//!
//! For each fixture (lena-like, cablecar-like) and restart interval the
//! bench encodes one v2 container, measures its size overhead against
//! the v1 encoding of the same coefficients, then runs a pinned chaos
//! sweep: seeded bit-flips confined to the segment region (the codec's
//! failure model — a damaged *head* is a lost file, a damaged *segment*
//! is a lost band). Every corrupted stream must:
//!
//! 1. salvage-decode at the original geometry (recovery fraction
//!    >= 0.99 across the whole sweep),
//! 2. report non-zero damage (a flip the CRC misses would be a silent
//!    corruption), and
//! 3. reconstruct with a finite PSNR against the clean reconstruction.
//!
//! The default-interval overhead must stay under 3% — the headline cost
//! of turning every compressed reply into a salvageable stream.

use anyhow::ensure;
use cordic_dct::bench::save_results;
use cordic_dct::codec::{
    self, decoder, encoder, variant_tag, Header, DEFAULT_RESTART_INTERVAL,
};
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::Variant;
use cordic_dct::image::synthetic;
use cordic_dct::metrics::psnr;
use cordic_dct::util::json::Json;
use cordic_dct::util::prng::Rng;

const INTERVALS: [u16; 5] = [0, 1, 2, 4, 8];
const FLIP_COUNTS: [usize; 3] = [1, 4, 16];

struct SweepRow {
    scene: &'static str,
    interval: u16,
    v1_bytes: usize,
    v2_bytes: usize,
    overhead_pct: f64,
    trials: usize,
    recovered: usize,
    mean_damaged: f64,
    mean_psnr_db: f64,
    min_psnr_db: f64,
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("CORDIC_DCT_BENCH_QUICK").is_ok();
    let (size, trials_per_count) = if quick { (64, 4) } else { (128, 12) };
    let pipe = CpuPipeline::new(Variant::Cordic, 50);
    let mut rows: Vec<SweepRow> = Vec::new();
    let mut total_trials = 0usize;
    let mut total_recovered = 0usize;
    println!(
        "== resilience sweep: {size}x{size} cordic q50, intervals \
         {INTERVALS:?}, flips {FLIP_COUNTS:?} =="
    );
    for (scene, img) in [
        ("lena", synthetic::lena_like(size, size, 5)),
        ("cablecar", synthetic::cablecar_like(size, size, 5)),
    ] {
        let scanned = pipe.analyze_scanned(&img);
        let header = Header {
            width: img.width as u32,
            height: img.height as u32,
            padded_width: scanned.padded_width as u32,
            padded_height: scanned.padded_height as u32,
            quality: 50,
            variant: variant_tag(Variant::Cordic),
        };
        let v1 = encoder::encode_scanned(&header, &scanned)?;
        for interval in INTERVALS {
            let v2 =
                encoder::encode_scanned_v2(&header, &scanned, interval)?;
            let overhead_pct = (v2.len() as f64 - v1.len() as f64)
                / v1.len() as f64
                * 100.0;
            // the clean reconstruction every salvage is scored against
            let clean = decoder::decode(&v2)?;
            let recon = pipe.decode_coefficients(
                &clean.qcoef_planar,
                header.padded_width as usize,
                header.padded_height as usize,
                img.width,
                img.height,
            );
            // flips land beyond the first 40% of the container — the
            // head is ~3% of it, so this pins corruption to segments
            let lo = v2.len() * 2 / 5;
            let mut rng = Rng::new(0xC2C2 + interval as u64);
            let (mut recovered, mut damaged_sum) = (0usize, 0u64);
            let (mut psnr_sum, mut psnr_min, mut trials) =
                (0.0f64, f64::INFINITY, 0usize);
            for flips in FLIP_COUNTS {
                for _ in 0..trials_per_count {
                    trials += 1;
                    let mut bad = v2.clone();
                    for _ in 0..flips {
                        let at = lo
                            + rng.below((bad.len() - lo) as u64) as usize;
                        bad[at] ^= 1 << rng.below(8);
                    }
                    let Ok((dec, report)) = decoder::decode_salvage(&bad)
                    else {
                        continue;
                    };
                    if dec.header != header {
                        continue;
                    }
                    ensure!(
                        !report.is_clean(),
                        "{scene} interval {interval}: corrupted stream \
                         reported clean"
                    );
                    recovered += 1;
                    damaged_sum += report.segments_damaged as u64;
                    let salvaged = pipe.decode_coefficients(
                        &dec.qcoef_planar,
                        header.padded_width as usize,
                        header.padded_height as usize,
                        img.width,
                        img.height,
                    );
                    // cap: identical images give +inf, which JSON
                    // cannot carry
                    let p = psnr(&recon, &salvaged).min(99.0);
                    psnr_sum += p;
                    psnr_min = psnr_min.min(p);
                }
            }
            total_trials += trials;
            total_recovered += recovered;
            let row = SweepRow {
                scene,
                interval,
                v1_bytes: v1.len(),
                v2_bytes: v2.len(),
                overhead_pct,
                trials,
                recovered,
                mean_damaged: damaged_sum as f64 / recovered.max(1) as f64,
                mean_psnr_db: psnr_sum / recovered.max(1) as f64,
                min_psnr_db: psnr_min,
            };
            println!(
                "{:<9} interval {:>2}: {:>6} B (v1 {:>6} B, {:+.2}%), \
                 {}/{} recovered, mean {:.1} seg damaged, salvage PSNR \
                 mean {:.1} min {:.1} dB",
                row.scene,
                row.interval,
                row.v2_bytes,
                row.v1_bytes,
                row.overhead_pct,
                row.recovered,
                row.trials,
                row.mean_damaged,
                row.mean_psnr_db,
                row.min_psnr_db
            );
            if interval == DEFAULT_RESTART_INTERVAL {
                ensure!(
                    row.overhead_pct < 3.0,
                    "{scene}: default-interval overhead {:.2}% \
                     breaks the 3% budget",
                    row.overhead_pct
                );
            }
            rows.push(row);
        }
    }
    let recovery = total_recovered as f64 / total_trials.max(1) as f64;
    println!(
        "recovery: {total_recovered}/{total_trials} = {:.4}",
        recovery
    );
    ensure!(
        recovery >= 0.99,
        "salvage recovery {recovery:.4} below the 0.99 floor"
    );
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("scene", Json::str(r.scene)),
                ("interval", (r.interval as usize).into()),
                ("v1_bytes", r.v1_bytes.into()),
                ("v2_bytes", r.v2_bytes.into()),
                ("overhead_pct", Json::num(r.overhead_pct)),
                ("trials", r.trials.into()),
                ("recovered", r.recovered.into()),
                ("mean_damaged_segments", Json::num(r.mean_damaged)),
                ("salvage_psnr_mean_db", Json::num(r.mean_psnr_db)),
                ("salvage_psnr_min_db", Json::num(r.min_psnr_db)),
            ])
        })
        .collect();
    let json = Json::obj(vec![
        ("table", Json::str("resilience")),
        ("size", size.into()),
        (
            "default_interval",
            (codec::DEFAULT_RESTART_INTERVAL as usize).into(),
        ),
        ("recovery_fraction", Json::num(recovery)),
        ("rows", Json::Arr(json_rows)),
    ])
    .to_string();
    let text = rows
        .iter()
        .map(|r| {
            format!(
                "{} interval {}: {} B ({:+.2}%), {}/{} recovered\n",
                r.scene,
                r.interval,
                r.v2_bytes,
                r.overhead_pct,
                r.recovered,
                r.trials
            )
        })
        .collect::<String>();
    save_results("resilience", &text, &json);
    Ok(())
}
