//! E5: paper Table 3 — Lena PSNR, exact DCT vs Cordic-based Loeffler,
//! per size (200^2, 512^2, 2048^2, 3072^2).

use cordic_dct::bench::tables;

fn main() -> anyhow::Result<()> {
    tables::run_psnr_experiment(
        "table3_psnr_lena",
        "Table 3: Lena PSNR (DCT vs Cordic-based Loeffler)",
        "lena",
        tables::LENA_PSNR_SIZES,
    )
}
