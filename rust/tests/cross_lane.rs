//! Cross-lane integration: the CPU serial lane and the PJRT lane must
//! compute the same pipeline (same transform arithmetic, same quantizer),
//! across sizes, scenes and variants. Skips (with a note) when artifacts
//! have not been built.

use std::sync::Arc;

use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::Variant;
use cordic_dct::image::synthetic;
use cordic_dct::metrics;
use cordic_dct::runtime::{Executor, Runtime};

fn executor() -> Option<Executor> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("cross_lane tests skipped: run `make artifacts`");
        return None;
    }
    Some(Executor::new(Arc::new(Runtime::new("artifacts").unwrap())))
}

#[test]
fn lanes_agree_across_sizes_and_variants() {
    let Some(ex) = executor() else { return };
    // paper sizes (h, w) that stay fast in CI
    for &(h, w) in &[(200usize, 200usize), (320, 288), (512, 480)] {
        for variant in [Variant::Dct, Variant::Cordic] {
            let img = synthetic::cablecar_like(w, h, 11);
            let gpu = ex.compress(&img, variant.as_str()).unwrap();
            let cpu = CpuPipeline::new(variant, 50).compress(&img);
            let cross = metrics::psnr(&gpu.recon, &cpu.recon);
            assert!(
                cross > 45.0,
                "{w}x{h} {} lanes disagree: {cross} dB",
                variant.as_str()
            );
            // quantized coefficients nearly identical (round ties only)
            let ndiff = gpu
                .qcoef
                .iter()
                .zip(&cpu.qcoef)
                .filter(|(a, b)| a != b)
                .count();
            assert!(
                (ndiff as f64) < 0.002 * gpu.qcoef.len() as f64,
                "{ndiff} coefficient mismatches of {}",
                gpu.qcoef.len()
            );
        }
    }
}

#[test]
fn gpu_coefficients_feed_cpu_entropy_codec() {
    // the serving path: PJRT produces coefficients, rust entropy-codes
    // them, a decoder reconstructs — end to end across the lane boundary.
    let Some(ex) = executor() else { return };
    let img = synthetic::lena_like(200, 200, 3);
    let gpu = ex.compress(&img, "cordic").unwrap();
    let header = cordic_dct::codec::Header {
        width: 200,
        height: 200,
        padded_width: gpu.padded_width as u32,
        padded_height: gpu.padded_height as u32,
        quality: 50,
        variant: cordic_dct::codec::variant_tag(Variant::Cordic),
    };
    let bytes =
        cordic_dct::codec::encoder::encode(&header, &gpu.qcoef).unwrap();
    assert!(bytes.len() < img.pixels(), "must actually compress");
    let dec = cordic_dct::codec::decoder::decode(&bytes).unwrap();
    assert_eq!(dec.qcoef_planar, gpu.qcoef, "entropy codec is lossless");
    let recon = CpuPipeline::new(Variant::Cordic, 50).decode_coefficients(
        &dec.qcoef_planar,
        gpu.padded_width,
        gpu.padded_height,
        200,
        200,
    );
    let p = metrics::psnr(&img, &recon);
    let p_gpu = metrics::psnr(&img, &gpu.recon);
    assert!(
        (p - p_gpu).abs() < 0.2,
        "file-path recon {p} vs direct {p_gpu}"
    );
}

#[test]
fn histeq_lanes_agree() {
    let Some(ex) = executor() else { return };
    // artifact histeq_384x352 => height 384, width 352
    let img = synthetic::cablecar_like(352, 384, 9);
    let (gpu, _) = ex.histeq(&img).unwrap();
    let cpu = cordic_dct::image::histeq::histeq(&img);
    let ndiff = gpu
        .data
        .iter()
        .zip(&cpu.data)
        .filter(|(a, b)| a != b)
        .count();
    assert!(
        ndiff * 1000 < img.pixels(),
        "{ndiff}/{} histeq pixels differ",
        img.pixels()
    );
}

#[test]
fn psnr_artifact_matches_cpu_for_pipeline_outputs() {
    let Some(ex) = executor() else { return };
    let img = synthetic::lena_like(200, 200, 5);
    let rec = ex.compress(&img, "dct").unwrap().recon;
    let gpu_psnr = ex.psnr(&img, &rec).unwrap();
    let cpu_psnr = metrics::psnr(&img, &rec);
    assert!(
        (gpu_psnr - cpu_psnr).abs() < 0.01,
        "{gpu_psnr} vs {cpu_psnr}"
    );
}

#[test]
fn paper_psnr_shape_cordic_trails_dct_on_both_scenes() {
    // Tables 3-4 shape on the GPU lane itself.
    let Some(ex) = executor() else { return };
    for scene in ["lena", "cablecar"] {
        let img = synthetic::by_name(scene, 512, 512, 13).unwrap();
        let p_dct = metrics::psnr(
            &img,
            &ex.compress(&img, "dct").unwrap().recon,
        );
        let p_cor = metrics::psnr(
            &img,
            &ex.compress(&img, "cordic").unwrap().recon,
        );
        assert!(
            p_cor < p_dct,
            "{scene}: cordic {p_cor} must trail dct {p_dct}"
        );
        assert!(
            (0.3..8.0).contains(&(p_dct - p_cor)),
            "{scene}: gap {}",
            p_dct - p_cor
        );
    }
}
