//! Property tests for the content-addressed response cache: a hit must
//! return bytes identical to a cold compress for random request draws,
//! distinct request shapes must never alias, and the byte budget must
//! hold under a seeded insert/evict fuzz.

use std::sync::Arc;
use std::time::Duration;

use cordic_dct::coordinator::{Lane, ServiceConfig};
use cordic_dct::dct::Variant;
use cordic_dct::image::synthetic;
use cordic_dct::image::ycbcr::Subsampling;
use cordic_dct::serve::cache::CachedReply;
use cordic_dct::serve::{
    CacheKey, Client, RequestMsg, ResponseCache, ResponseMsg,
    ServeConfig, TcpServer,
};

/// Deterministic xorshift64* PRNG (no dev-dependencies).
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

fn cached_server() -> TcpServer {
    let cfg = ServeConfig {
        service: ServiceConfig {
            workers: 2,
            queue_capacity: 32,
            artifact_dir: None,
            ..Default::default()
        },
        max_connections: 4,
        cache_bytes: 16 * 1024 * 1024,
        ..Default::default()
    };
    TcpServer::bind("127.0.0.1:0", cfg).expect("bind test server")
}

fn stat_field(stats: &str, key: &str) -> f64 {
    // the stats frame is flat JSON; a string search keeps the test free
    // of a JSON parser dependency
    let needle = format!("\"{key}\":");
    let at = stats
        .find(&needle)
        .unwrap_or_else(|| panic!("no {key} in {stats}"));
    let rest = &stats[at + needle.len()..];
    let end = rest
        .find([',', '}'])
        .unwrap_or_else(|| panic!("unterminated {key} in {stats}"));
    rest[..end].trim().parse().expect("numeric stats field")
}

#[test]
fn hits_return_bytes_identical_to_the_cold_compress() {
    let server = cached_server();
    let mut client = Client::connect(server.local_addr())
        .unwrap()
        .with_deadline(Duration::from_secs(30));
    let mut rng = Rng(0xCAC4E_01);
    let variants = [Variant::Dct, Variant::Loeffler, Variant::Cordic];
    let subs =
        [Subsampling::S444, Subsampling::S422, Subsampling::S420];
    let mut draws = Vec::new();
    for _ in 0..6 {
        let w = 16 + rng.below(32) as usize;
        let h = 16 + rng.below(32) as usize;
        let seed = rng.next();
        let color = rng.below(2) == 1;
        let variant = variants[rng.below(3) as usize];
        let msg = if color {
            RequestMsg::CompressColor {
                image: synthetic::lena_like_rgb(w, h, seed),
                variant,
                lane: Lane::Cpu,
                subsampling: subs[rng.below(3) as usize],
                want_psnr: false,
            }
        } else {
            RequestMsg::CompressGray {
                image: synthetic::lena_like(w, h, seed),
                variant,
                lane: Lane::Cpu,
                want_psnr: false,
            }
        };
        let cold = match client.request(&msg).unwrap() {
            ResponseMsg::Compressed { container, .. } => container,
            other => panic!("expected Compressed, got {other:?}"),
        };
        assert!(!cold.is_empty());
        draws.push((msg, cold));
    }
    // distinct draws must have produced distinct containers (distinct
    // keys never alias onto one cached entry)
    for i in 0..draws.len() {
        for j in i + 1..draws.len() {
            assert_ne!(
                draws[i].1, draws[j].1,
                "draws {i} and {j} aliased to one container"
            );
        }
    }
    // replays in shuffled order: every hit bit-identical to its cold run
    for k in (0..draws.len()).rev() {
        let (msg, cold) = &draws[k];
        let hit = match client.request(msg).unwrap() {
            ResponseMsg::Compressed { container, .. } => container,
            other => panic!("expected Compressed, got {other:?}"),
        };
        assert_eq!(
            &hit, cold,
            "draw {k}: cache hit diverged from the cold compress"
        );
    }
    // the stats frame proves these were hits, not recomputes
    let stats = client.stats_json().unwrap();
    let hits = stat_field(&stats, "cache_hits");
    let misses = stat_field(&stats, "cache_misses");
    assert!(hits >= draws.len() as f64, "{stats}");
    assert!(misses >= draws.len() as f64, "{stats}");
    server.shutdown();
}

#[test]
fn want_psnr_variants_are_cached_separately() {
    // the PSNR flag changes the reply (a figure is attached) but not the
    // container; the key must split on it so a no-psnr hit never
    // shadows a with-psnr request
    let server = cached_server();
    let mut client = Client::connect(server.local_addr())
        .unwrap()
        .with_deadline(Duration::from_secs(30));
    let img = synthetic::lena_like(32, 32, 77);
    let no_psnr = RequestMsg::CompressGray {
        image: img.clone(),
        variant: Variant::Cordic,
        lane: Lane::Cpu,
        want_psnr: false,
    };
    let with_psnr = RequestMsg::CompressGray {
        image: img,
        variant: Variant::Cordic,
        lane: Lane::Cpu,
        want_psnr: true,
    };
    let (a, b) = match (
        client.request(&no_psnr).unwrap(),
        client.request(&with_psnr).unwrap(),
    ) {
        (
            ResponseMsg::Compressed {
                psnr_db: pa,
                container: ca,
                ..
            },
            ResponseMsg::Compressed {
                psnr_db: pb,
                container: cb,
                ..
            },
        ) => {
            assert!(pa.is_none());
            assert!(pb.is_some(), "psnr lost to a cache alias");
            (ca, cb)
        }
        other => panic!("expected two Compressed, got {other:?}"),
    };
    assert_eq!(a, b, "the container itself is psnr-independent");
    // replay the psnr request: the hit must still carry the figure
    match client.request(&with_psnr).unwrap() {
        ResponseMsg::Compressed { psnr_db, .. } => {
            assert!(psnr_db.is_some(), "cached reply dropped the psnr");
        }
        other => panic!("expected Compressed, got {other:?}"),
    }
    server.shutdown();
}

#[test]
fn budget_holds_under_seeded_insert_evict_fuzz() {
    let mut rng = Rng(0xCAC4E_02);
    for round in 0..8 {
        let shards = 1 + rng.below(8) as usize;
        let budget = 4096 + rng.below(64 * 1024) as usize;
        let cache = ResponseCache::new(budget, shards);
        let effective_budget = cache.stats().budget_bytes;
        // last-written length per key: reinserting a key must replace
        // its bytes, and a hit must always return the latest insert
        let mut expected =
            std::collections::HashMap::<CacheKey, usize>::new();
        let mut keys = Vec::new();
        for i in 0..400u64 {
            let msg = RequestMsg::CompressGray {
                image: synthetic::lena_like(
                    8 + (i % 16) as usize,
                    8,
                    rng.below(64),
                ),
                variant: Variant::Cordic,
                lane: Lane::Cpu,
                want_psnr: false,
            };
            let key = CacheKey::for_request(&msg, 50, 4).unwrap();
            // like a real compress, the key fixes the bytes: size is a
            // pure function of the key, spanning tiny to
            // oversized-for-a-shard
            let len =
                (key.digest % (budget as u64 / 2 + 64)) as usize;
            cache.insert(
                key,
                CachedReply {
                    lane: Lane::Cpu,
                    psnr_db: None,
                    container: Arc::new(vec![key.digest as u8; len]),
                },
            );
            if expected.insert(key, len).is_none() {
                keys.push(key);
            }
            // interleave hits so LRU order churns
            if rng.below(3) == 0 {
                let k = keys[rng.below(keys.len() as u64) as usize];
                if let Some(hit) = cache.get(&k) {
                    assert_eq!(
                        hit.container.len(),
                        expected[&k],
                        "round {round}: hit returned stale bytes"
                    );
                }
            }
            let s = cache.stats();
            assert!(
                s.bytes <= effective_budget,
                "round {round} step {i}: {} bytes exceeds the {} \
                 budget ({s:?})",
                s.bytes,
                effective_budget
            );
        }
        let s = cache.stats();
        assert!(
            s.hits + s.misses > 0 && s.bytes <= effective_budget,
            "round {round}: {s:?}"
        );
    }
}

#[test]
fn same_pixels_different_knobs_never_alias() {
    // in-process key-level variant of the e2e aliasing test: sweep every
    // knob dimension with identical pixel content
    let img = synthetic::lena_like(24, 24, 9);
    let base = RequestMsg::CompressGray {
        image: img.clone(),
        variant: Variant::Cordic,
        lane: Lane::Cpu,
        want_psnr: false,
    };
    let k = |msg: &RequestMsg, q: u8, ri: u16| {
        CacheKey::for_request(msg, q, ri).unwrap()
    };
    let base_key = k(&base, 50, 4);
    let mut seen = std::collections::HashSet::new();
    assert!(seen.insert(base_key));
    for q in [10u8, 30, 70, 90] {
        assert!(seen.insert(k(&base, q, 4)), "quality {q} aliased");
    }
    for ri in [0u16, 1, 8, 64] {
        assert!(seen.insert(k(&base, 50, ri)), "restart {ri} aliased");
    }
    for variant in [Variant::Dct, Variant::Loeffler, Variant::CordicFxp]
    {
        let msg = RequestMsg::CompressGray {
            image: img.clone(),
            variant,
            lane: Lane::Cpu,
            want_psnr: false,
        };
        assert!(seen.insert(k(&msg, 50, 4)), "{variant:?} aliased");
    }
    let color = RequestMsg::CompressColor {
        image: synthetic::lena_like_rgb(24, 24, 9),
        variant: Variant::Cordic,
        lane: Lane::Cpu,
        subsampling: Subsampling::S420,
        want_psnr: false,
    };
    assert!(seen.insert(k(&color, 50, 4)), "color aliased gray");
}
