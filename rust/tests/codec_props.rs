//! Property tests (in-crate proptest harness) over the codec and
//! transform invariants DESIGN.md §7 calls out.

use cordic_dct::codec::{decoder, encoder, variant_tag, zigzag, Header};
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::{matrix::MatrixDct, Transform8x8, Variant};
use cordic_dct::image::GrayImage;
use cordic_dct::metrics;
use cordic_dct::util::proptest::{check, gen, Shrink};
use cordic_dct::util::prng::Rng;

/// A random quantized-coefficient image for codec round-trips.
#[derive(Clone, Debug)]
struct CoefImage {
    gw: usize,
    gh: usize,
    data: Vec<i32>, // i16-ranged values
}

impl Shrink for CoefImage {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.gw > 1 {
            let gw = self.gw / 2;
            out.push(CoefImage {
                gw,
                gh: self.gh,
                data: shrink_grid(&self.data, self.gw, self.gh, gw, self.gh),
            });
        }
        if self.gh > 1 {
            let gh = self.gh / 2;
            out.push(CoefImage {
                gw: self.gw,
                gh,
                data: shrink_grid(&self.data, self.gw, self.gh, self.gw, gh),
            });
        }
        // zero out the second half of the data
        let mut z = self.clone();
        let n = z.data.len();
        for v in &mut z.data[n / 2..] {
            *v = 0;
        }
        if z.data != self.data {
            out.push(z);
        }
        out
    }
}

fn shrink_grid(
    data: &[i32],
    gw: usize,
    _gh: usize,
    new_gw: usize,
    new_gh: usize,
) -> Vec<i32> {
    let w = gw * 8;
    let nw = new_gw * 8;
    let nh = new_gh * 8;
    let mut out = vec![0i32; nw * nh];
    for y in 0..nh {
        for x in 0..nw {
            out[y * nw + x] = data[y * w + x];
        }
    }
    out
}

fn gen_coef_image(rng: &mut Rng) -> CoefImage {
    let gw = rng.range_i64(1, 6) as usize;
    let gh = rng.range_i64(1, 6) as usize;
    let n = gw * gh * 64;
    // sparse, JPEG-like distribution with occasional large DCs
    let data = (0..n)
        .map(|_| {
            if rng.chance(0.7) {
                0
            } else if rng.chance(0.9) {
                rng.range_i64(-30, 30) as i32
            } else {
                rng.range_i64(-1000, 1000) as i32
            }
        })
        .collect();
    CoefImage { gw, gh, data }
}

#[test]
fn prop_container_roundtrip_lossless() {
    check(40, gen_coef_image, |ci| {
        let pw = ci.gw * 8;
        let ph = ci.gh * 8;
        let planar: Vec<f32> =
            ci.data.iter().map(|&v| v as f32).collect();
        let header = Header {
            width: pw as u32,
            height: ph as u32,
            padded_width: pw as u32,
            padded_height: ph as u32,
            quality: 50,
            variant: variant_tag(Variant::Dct),
        };
        let bytes = encoder::encode(&header, &planar)
            .map_err(|e| e.to_string())?;
        let dec = decoder::decode(&bytes).map_err(|e| e.to_string())?;
        if dec.qcoef_planar != planar {
            return Err("coefficients not preserved".into());
        }
        Ok(())
    });
}

#[test]
fn prop_zigzag_roundtrip() {
    check(
        100,
        |rng| gen::vec_i32(rng, 64, -2000, 2000),
        |v| {
            let mut block = [0i16; 64];
            for (i, &x) in v.iter().enumerate().take(64) {
                block[i] = x as i16;
            }
            let back = zigzag::unscan(&zigzag::scan(&block));
            if back == block {
                Ok(())
            } else {
                Err("zigzag not a bijection".into())
            }
        },
    );
}

#[test]
fn prop_dct_idct_identity() {
    check(
        60,
        |rng| gen::vec_f32(rng, 64, -128.0, 128.0),
        |v| {
            let m = MatrixDct::new();
            let mut block = [0.0f32; 64];
            for (i, &x) in v.iter().enumerate().take(64) {
                block[i] = x;
            }
            let orig = block;
            m.forward(&mut block);
            m.inverse(&mut block);
            for i in 0..64 {
                if (block[i] - orig[i]).abs() > 1e-3 {
                    return Err(format!(
                        "idct(dct(x))[{i}] = {} != {}",
                        block[i], orig[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pipeline_error_bounded_by_quant_step() {
    // reconstruction error of the exact-DCT pipeline is bounded by the
    // worst quantization step (q_max/2 per coefficient => per-pixel
    // bound of q_max/2 * 8 in the worst case; empirically much smaller —
    // assert the loose analytic bound).
    #[derive(Clone, Debug)]
    struct ImgCase {
        w: usize,
        h: usize,
        data: Vec<u8>,
    }
    impl Shrink for ImgCase {
        fn shrinks(&self) -> Vec<Self> {
            Vec::new() // shape-coupled; skip shrinking
        }
    }
    check(
        15,
        |rng| {
            let w = gen::dim8(rng, 6);
            let h = gen::dim8(rng, 6);
            let data = (0..w * h)
                .map(|_| rng.range_i64(0, 255) as u8)
                .collect();
            ImgCase { w, h, data }
        },
        |case| {
            let img =
                GrayImage::from_vec(case.w, case.h, case.data.clone())
                    .map_err(|e| e.to_string())?;
            let out = CpuPipeline::new(Variant::Dct, 50).compress(&img);
            let q_max = 121.0 / 4.0; // largest effective q at quality 50
            let bound = q_max / 2.0 * 8.0;
            for (a, b) in img.data.iter().zip(&out.recon.data) {
                let d = (*a as f32 - *b as f32).abs();
                if d > bound {
                    return Err(format!("pixel error {d} > {bound}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_psnr_scale_invariant_ordering() {
    // adding more noise never increases PSNR
    check(
        30,
        |rng| {
            let n = gen::vec_i32(rng, 32, 0, 255);
            (n, rng.range_i64(1, 20) as i32)
        },
        |(vals, amp)| {
            if vals.len() < 4 {
                return Ok(());
            }
            let w = vals.len();
            let a = GrayImage::from_vec(
                w,
                1,
                vals.iter().map(|&v| v as u8).collect(),
            )
            .unwrap();
            let mk_noisy = |k: i32| {
                let data: Vec<u8> = vals
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| {
                        let sign = if i % 2 == 0 { 1 } else { -1 };
                        (v + sign * k).clamp(0, 255) as u8
                    })
                    .collect();
                GrayImage::from_vec(w, 1, data).unwrap()
            };
            let p_small = metrics::psnr(&a, &mk_noisy(*amp));
            let p_big = metrics::psnr(&a, &mk_noisy(*amp * 3));
            if p_big <= p_small + 1e-9 {
                Ok(())
            } else {
                Err(format!("psnr not monotone: {p_small} vs {p_big}"))
            }
        },
    );
}

#[test]
fn prop_decoder_never_panics_on_mutations() {
    // hammer the decoder with structured mutations of a valid file
    let img = cordic_dct::image::synthetic::lena_like(48, 40, 3);
    let pipe = CpuPipeline::new(Variant::Dct, 50);
    let (qcoef, pw, ph) = pipe.analyze(&img);
    let header = Header {
        width: 48,
        height: 40,
        padded_width: pw as u32,
        padded_height: ph as u32,
        quality: 50,
        variant: variant_tag(Variant::Dct),
    };
    let valid = encoder::encode(&header, &qcoef).unwrap();
    check(
        150,
        |rng| {
            let mut v = valid.clone();
            for _ in 0..rng.range_i64(1, 6) {
                let i = rng.below(v.len() as u64) as usize;
                v[i] = rng.next_u32() as u8;
            }
            // occasional truncation
            if rng.chance(0.3) {
                let keep = rng.below(v.len() as u64) as usize;
                v.truncate(keep.max(1));
            }
            v.into_iter().map(|b| b as i32).collect::<Vec<i32>>()
        },
        |bytes| {
            let raw: Vec<u8> =
                bytes.iter().map(|&b| b as u8).collect();
            // Ok or Err both fine — panics are what the harness catches
            let _ = decoder::decode(&raw);
            Ok(())
        },
    );
}
