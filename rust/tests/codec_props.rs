//! Property tests (in-crate proptest harness) over the codec and
//! transform invariants DESIGN.md §7 calls out.

use cordic_dct::codec::huffman::{HuffmanCode, HuffmanDecoder};
use cordic_dct::codec::{decoder, encoder, rle, variant_tag, zigzag, Header};
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::{matrix::MatrixDct, Transform8x8, Variant};
use cordic_dct::image::GrayImage;
use cordic_dct::metrics;
use cordic_dct::util::bitio::{BitReader, BitWriter};
use cordic_dct::util::proptest::{check, gen, Shrink};
use cordic_dct::util::prng::Rng;

/// A random quantized-coefficient image for codec round-trips.
#[derive(Clone, Debug)]
struct CoefImage {
    gw: usize,
    gh: usize,
    data: Vec<i32>, // i16-ranged values
}

impl Shrink for CoefImage {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.gw > 1 {
            let gw = self.gw / 2;
            out.push(CoefImage {
                gw,
                gh: self.gh,
                data: shrink_grid(&self.data, self.gw, self.gh, gw, self.gh),
            });
        }
        if self.gh > 1 {
            let gh = self.gh / 2;
            out.push(CoefImage {
                gw: self.gw,
                gh,
                data: shrink_grid(&self.data, self.gw, self.gh, self.gw, gh),
            });
        }
        // zero out the second half of the data
        let mut z = self.clone();
        let n = z.data.len();
        for v in &mut z.data[n / 2..] {
            *v = 0;
        }
        if z.data != self.data {
            out.push(z);
        }
        out
    }
}

fn shrink_grid(
    data: &[i32],
    gw: usize,
    _gh: usize,
    new_gw: usize,
    new_gh: usize,
) -> Vec<i32> {
    let w = gw * 8;
    let nw = new_gw * 8;
    let nh = new_gh * 8;
    let mut out = vec![0i32; nw * nh];
    for y in 0..nh {
        for x in 0..nw {
            out[y * nw + x] = data[y * w + x];
        }
    }
    out
}

fn gen_coef_image(rng: &mut Rng) -> CoefImage {
    let gw = rng.range_i64(1, 6) as usize;
    let gh = rng.range_i64(1, 6) as usize;
    let n = gw * gh * 64;
    // sparse, JPEG-like distribution with occasional large DCs
    let data = (0..n)
        .map(|_| {
            if rng.chance(0.7) {
                0
            } else if rng.chance(0.9) {
                rng.range_i64(-30, 30) as i32
            } else {
                rng.range_i64(-1000, 1000) as i32
            }
        })
        .collect();
    CoefImage { gw, gh, data }
}

#[test]
fn prop_container_roundtrip_lossless() {
    check(40, gen_coef_image, |ci| {
        let pw = ci.gw * 8;
        let ph = ci.gh * 8;
        let planar: Vec<f32> =
            ci.data.iter().map(|&v| v as f32).collect();
        let header = Header {
            width: pw as u32,
            height: ph as u32,
            padded_width: pw as u32,
            padded_height: ph as u32,
            quality: 50,
            variant: variant_tag(Variant::Dct),
        };
        let bytes = encoder::encode(&header, &planar)
            .map_err(|e| e.to_string())?;
        let dec = decoder::decode(&bytes).map_err(|e| e.to_string())?;
        if dec.qcoef_planar != planar {
            return Err("coefficients not preserved".into());
        }
        Ok(())
    });
}

/// Full single-block path the container uses, with *real* per-block
/// Huffman tables: zigzag -> RLE symbols -> canonical Huffman -> bitstream
/// -> decode -> unscan must be lossless.
fn block_roundtrip_via_huffman(block: &[i16; 64], prev_dc: i16) -> [i16; 64] {
    let scan = zigzag::scan(block);
    let sym = rle::encode_block(&scan, prev_dc);
    // build tables from this block's own statistics (as the two-pass
    // encoder does per image)
    let mut dc_freq = [0u64; 256];
    let mut ac_freq = [0u64; 256];
    dc_freq[sym.dc.0 as usize] += 1;
    for &(s, _) in &sym.ac {
        ac_freq[s as usize] += 1;
    }
    if ac_freq.iter().all(|&f| f == 0) {
        ac_freq[rle::EOB as usize] = 1;
    }
    let dc_code = HuffmanCode::build(&dc_freq).unwrap();
    let ac_code = HuffmanCode::build(&ac_freq).unwrap();
    let mut w = BitWriter::new();
    rle::write_block(
        &mut w,
        &sym,
        |w, s| dc_code.put(w, s),
        |w, s| ac_code.put(w, s),
    );
    let bytes = w.finish();
    let dc_dec = HuffmanDecoder::new(&dc_code);
    let ac_dec = HuffmanDecoder::new(&ac_code);
    let mut r = BitReader::new(&bytes);
    let back = rle::read_block(
        &mut r,
        prev_dc,
        |r| dc_dec.get(r),
        |r| ac_dec.get(r),
    )
    .unwrap();
    zigzag::unscan(&back)
}

#[test]
fn prop_block_symbol_stream_lossless() {
    // random quantized blocks across the sparsity spectrum, plus random
    // DPCM predecessors
    check(
        120,
        |rng| {
            let density = rng.range_f64(0.0, 1.0);
            let mut v = vec![0i32; 65];
            for slot in v.iter_mut().take(64) {
                if rng.chance(density) {
                    *slot = rng.range_i64(-1500, 1500) as i32;
                }
            }
            v[64] = rng.range_i64(-1500, 1500) as i32; // prev_dc
            v
        },
        |v| {
            if v.len() != 65 {
                return Ok(()); // shrunk vectors lose the shape; skip
            }
            let mut block = [0i16; 64];
            for i in 0..64 {
                block[i] = v[i] as i16;
            }
            let prev_dc = v[64] as i16;
            let back = block_roundtrip_via_huffman(&block, prev_dc);
            if back == block {
                Ok(())
            } else {
                Err("block not preserved through symbol stream".into())
            }
        },
    );
}

#[test]
fn block_roundtrip_all_zero() {
    let block = [0i16; 64];
    for prev_dc in [0i16, -37, 1000] {
        assert_eq!(block_roundtrip_via_huffman(&block, prev_dc), block);
    }
}

#[test]
fn block_roundtrip_single_dc() {
    for dc in [1i16, -1, 512, -1024] {
        let mut block = [0i16; 64];
        block[0] = dc;
        assert_eq!(block_roundtrip_via_huffman(&block, 0), block);
        assert_eq!(block_roundtrip_via_huffman(&block, dc), block);
    }
}

#[test]
fn block_roundtrip_dense_and_tail() {
    // fully dense block (no EOB) and a lone last-coefficient block (long
    // ZRL run) — the two structural extremes of the AC model
    let dense: [i16; 64] = std::array::from_fn(|i| (i as i16 % 7) - 3 + 1);
    assert_eq!(block_roundtrip_via_huffman(&dense, 5), dense);
    let mut tail = [0i16; 64];
    tail[63] = -2;
    assert_eq!(block_roundtrip_via_huffman(&tail, 0), tail);
}

#[test]
fn prop_container_roundtrip_includes_degenerate_blocks() {
    // whole-container property again, but biased to degenerate content:
    // all-zero grids and single-DC grids must also be lossless
    check(
        25,
        |rng| {
            let gw = rng.range_i64(1, 4) as usize;
            let gh = rng.range_i64(1, 4) as usize;
            let mode = rng.range_i64(0, 2); // 0 zero, 1 dc-only, 2 mixed
            let mut data = vec![0i32; gw * gh * 64 + 2];
            data[0] = gw as i32;
            data[1] = gh as i32;
            if mode > 0 {
                let w = gw * 8;
                for by in 0..gh {
                    for bx in 0..gw {
                        let dc = rng.range_i64(-900, 900) as i32;
                        data[2 + (by * 8) * w + bx * 8] = dc;
                        if mode == 2 && rng.chance(0.5) {
                            data[2 + (by * 8 + 3) * w + bx * 8 + 2] =
                                rng.range_i64(-40, 40) as i32;
                        }
                    }
                }
            }
            data
        },
        |data| {
            if data.len() < 2 {
                return Ok(());
            }
            let (gw, gh) = (data[0], data[1]);
            if !(1..=8).contains(&gw) || !(1..=8).contains(&gh) {
                return Ok(()); // shrunk shapes; skip
            }
            let (gw, gh) = (gw as usize, gh as usize);
            if data.len() != gw * gh * 64 + 2 {
                return Ok(());
            }
            let (pw, ph) = (gw * 8, gh * 8);
            let planar: Vec<f32> =
                data[2..].iter().map(|&v| v as f32).collect();
            let header = Header {
                width: pw as u32,
                height: ph as u32,
                padded_width: pw as u32,
                padded_height: ph as u32,
                quality: 50,
                variant: variant_tag(Variant::Dct),
            };
            let bytes = encoder::encode(&header, &planar)
                .map_err(|e| e.to_string())?;
            let dec = decoder::decode(&bytes).map_err(|e| e.to_string())?;
            if dec.qcoef_planar != planar {
                return Err("degenerate grid not preserved".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_zigzag_roundtrip() {
    check(
        100,
        |rng| gen::vec_i32(rng, 64, -2000, 2000),
        |v| {
            let mut block = [0i16; 64];
            for (i, &x) in v.iter().enumerate().take(64) {
                block[i] = x as i16;
            }
            let back = zigzag::unscan(&zigzag::scan(&block));
            if back == block {
                Ok(())
            } else {
                Err("zigzag not a bijection".into())
            }
        },
    );
}

#[test]
fn prop_dct_idct_identity() {
    check(
        60,
        |rng| gen::vec_f32(rng, 64, -128.0, 128.0),
        |v| {
            let m = MatrixDct::new();
            let mut block = [0.0f32; 64];
            for (i, &x) in v.iter().enumerate().take(64) {
                block[i] = x;
            }
            let orig = block;
            m.forward(&mut block);
            m.inverse(&mut block);
            for i in 0..64 {
                if (block[i] - orig[i]).abs() > 1e-3 {
                    return Err(format!(
                        "idct(dct(x))[{i}] = {} != {}",
                        block[i], orig[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pipeline_error_bounded_by_quant_step() {
    // reconstruction error of the exact-DCT pipeline is bounded by the
    // worst quantization step (q_max/2 per coefficient => per-pixel
    // bound of q_max/2 * 8 in the worst case; empirically much smaller —
    // assert the loose analytic bound).
    #[derive(Clone, Debug)]
    struct ImgCase {
        w: usize,
        h: usize,
        data: Vec<u8>,
    }
    impl Shrink for ImgCase {
        fn shrinks(&self) -> Vec<Self> {
            Vec::new() // shape-coupled; skip shrinking
        }
    }
    check(
        15,
        |rng| {
            let w = gen::dim8(rng, 6);
            let h = gen::dim8(rng, 6);
            let data = (0..w * h)
                .map(|_| rng.range_i64(0, 255) as u8)
                .collect();
            ImgCase { w, h, data }
        },
        |case| {
            let img =
                GrayImage::from_vec(case.w, case.h, case.data.clone())
                    .map_err(|e| e.to_string())?;
            let out = CpuPipeline::new(Variant::Dct, 50).compress(&img);
            let q_max = 121.0 / 4.0; // largest effective q at quality 50
            let bound = q_max / 2.0 * 8.0;
            for (a, b) in img.data.iter().zip(&out.recon.data) {
                let d = (*a as f32 - *b as f32).abs();
                if d > bound {
                    return Err(format!("pixel error {d} > {bound}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_psnr_scale_invariant_ordering() {
    // adding more noise never increases PSNR
    check(
        30,
        |rng| {
            let n = gen::vec_i32(rng, 32, 0, 255);
            (n, rng.range_i64(1, 20) as i32)
        },
        |(vals, amp)| {
            if vals.len() < 4 {
                return Ok(());
            }
            let w = vals.len();
            let a = GrayImage::from_vec(
                w,
                1,
                vals.iter().map(|&v| v as u8).collect(),
            )
            .unwrap();
            let mk_noisy = |k: i32| {
                let data: Vec<u8> = vals
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| {
                        let sign = if i % 2 == 0 { 1 } else { -1 };
                        (v + sign * k).clamp(0, 255) as u8
                    })
                    .collect();
                GrayImage::from_vec(w, 1, data).unwrap()
            };
            let p_small = metrics::psnr(&a, &mk_noisy(*amp));
            let p_big = metrics::psnr(&a, &mk_noisy(*amp * 3));
            if p_big <= p_small + 1e-9 {
                Ok(())
            } else {
                Err(format!("psnr not monotone: {p_small} vs {p_big}"))
            }
        },
    );
}

#[test]
fn prop_decoder_never_panics_on_mutations() {
    // hammer the decoder with structured mutations of a valid file
    let img = cordic_dct::image::synthetic::lena_like(48, 40, 3);
    let pipe = CpuPipeline::new(Variant::Dct, 50);
    let (qcoef, pw, ph) = pipe.analyze(&img);
    let header = Header {
        width: 48,
        height: 40,
        padded_width: pw as u32,
        padded_height: ph as u32,
        quality: 50,
        variant: variant_tag(Variant::Dct),
    };
    let valid = encoder::encode(&header, &qcoef).unwrap();
    check(
        150,
        |rng| {
            let mut v = valid.clone();
            for _ in 0..rng.range_i64(1, 6) {
                let i = rng.below(v.len() as u64) as usize;
                v[i] = rng.next_u32() as u8;
            }
            // occasional truncation
            if rng.chance(0.3) {
                let keep = rng.below(v.len() as u64) as usize;
                v.truncate(keep.max(1));
            }
            v.into_iter().map(|b| b as i32).collect::<Vec<i32>>()
        },
        |bytes| {
            let raw: Vec<u8> =
                bytes.iter().map(|&b| b as u8).collect();
            // Ok or Err both fine — panics are what the harness catches
            let _ = decoder::decode(&raw);
            Ok(())
        },
    );
}
