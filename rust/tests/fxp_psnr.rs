//! Quality acceptance for the integer fixed-point CORDIC-Loeffler lane
//! (`Variant::CordicFxp`). Unlike the f32 lanes, the fxp transform is
//! *not* bit-parity-bound to an exact reference — its accuracy is a
//! function of `FxpPrecision` — so this suite locks behaviour with
//! PSNR floors instead:
//!
//! * at the default precision the lane must track the float CORDIC
//!   pipeline it is calibrated against (relative floor), and clear a
//!   conservative absolute floor;
//! * across the `--precision` sweep, quality must be monotone in the
//!   level up to a small slack, and high levels must stay close to the
//!   default-level figure;
//! * a CordicFxp-tagged CDC1 container must round-trip through the
//!   entropy codec and decode back to the pipeline's exact recon.
//!
//! Floors are deliberately loose (several dB of headroom) — they exist
//! to catch structural breakage (wrong shift, lost compensation step,
//! overflow), not to pin the third decimal of a PSNR figure.

use cordic_dct::codec::{decoder, encoder, tag_variant, variant_tag, Header};
use cordic_dct::dct::batch::EngineConfig;
use cordic_dct::dct::cordic_fxp::FxpPrecision;
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::Variant;
use cordic_dct::image::synthetic;
use cordic_dct::metrics;

const QUALITY: u8 = 50;

/// Pinned-seed fixtures: every test in this suite measures PSNR against
/// these exact pixels, so the seeds are part of the contract — bumping
/// one silently re-bases every floor below.
const LENA_SEED: u64 = 1;
const CABLECAR_SEED: u64 = 3;

fn lena_fixture() -> cordic_dct::image::GrayImage {
    synthetic::lena_like(64, 64, LENA_SEED)
}

fn cablecar_fixture() -> cordic_dct::image::GrayImage {
    synthetic::cablecar_like(72, 40, CABLECAR_SEED)
}

fn fxp_pipeline(precision: FxpPrecision) -> CpuPipeline {
    CpuPipeline::with_config(
        Variant::CordicFxp,
        QUALITY,
        EngineConfig {
            precision,
            ..EngineConfig::default()
        },
    )
}

fn psnr_at(precision: FxpPrecision) -> f64 {
    let img = lena_fixture();
    let out = fxp_pipeline(precision).compress(&img);
    metrics::psnr(&img, &out.recon)
}

#[test]
fn default_precision_tracks_float_cordic() {
    let img = lena_fixture();
    let float_cordic = CpuPipeline::new(Variant::Cordic, QUALITY);
    let p_float = metrics::psnr(&img, &float_cordic.compress(&img).recon);
    let p_fxp = psnr_at(FxpPrecision::default());
    // the default fxp calibration mirrors the float CORDIC lane's
    // (same micro-rotation count and grid), so it must land within a
    // couple of dB of it — and stay usable in absolute terms
    assert!(
        p_fxp >= p_float - 2.0,
        "fxp default {p_fxp:.2} dB vs float cordic {p_float:.2} dB"
    );
    assert!(p_fxp >= 20.0, "fxp default PSNR too low: {p_fxp:.2} dB");
}

#[test]
fn precision_sweep_is_monotone_with_slack() {
    let levels = [1u32, 2, 3, 4, 6, 8];
    let psnrs: Vec<f64> = levels
        .iter()
        .map(|&l| psnr_at(FxpPrecision::from_level(l)))
        .collect();
    for (i, &p) in psnrs.iter().enumerate() {
        assert!(
            p.is_finite() && p > 5.0,
            "level {} PSNR degenerate: {p:.2} dB",
            levels[i]
        );
    }
    // more iterations + fraction bits must not make things much worse:
    // allow a small slack for plateau noise once the curve saturates
    for w in psnrs.windows(2) {
        assert!(
            w[1] >= w[0] - 2.5,
            "precision sweep not monotone: {psnrs:.2?}"
        );
    }
    // the top of the sweep must be at least as good (minus slack) as
    // the default calibration — extra precision can't cost quality
    let p_default = psnr_at(FxpPrecision::default());
    let p_top = *psnrs.last().unwrap();
    assert!(
        p_top >= p_default - 1.0,
        "level 8 {p_top:.2} dB far below default {p_default:.2} dB"
    );
}

#[test]
fn per_level_floors() {
    // conservative structural floors per CLI level: even the coarsest
    // usable settings must beat these on the 64x64 synthetic scene
    for (level, floor) in [(2u32, 8.0f64), (3, 18.0), (6, 18.0), (8, 18.0)]
    {
        let p = psnr_at(FxpPrecision::from_level(level));
        assert!(
            p >= floor,
            "level {level}: {p:.2} dB below floor {floor} dB"
        );
    }
}

#[test]
fn fxp_container_roundtrip_is_bit_exact() {
    // a CordicFxp-tagged CDC1 container must survive the entropy codec
    // and decode to the pipeline's exact reconstruction — the fxp lane
    // is approximate at the transform, never at the container
    let img = cablecar_fixture();
    let pipe = fxp_pipeline(FxpPrecision::default());
    let (qcoef, pw, ph) = pipe.analyze(&img);
    let header = Header {
        width: img.width as u32,
        height: img.height as u32,
        padded_width: pw as u32,
        padded_height: ph as u32,
        quality: QUALITY,
        variant: variant_tag(Variant::CordicFxp),
    };
    let bytes = encoder::encode(&header, &qcoef).unwrap();
    let dec = decoder::decode(&bytes).unwrap();
    assert_eq!(
        tag_variant(dec.header.variant).unwrap(),
        Variant::CordicFxp,
        "variant tag must round-trip"
    );
    assert_eq!(dec.qcoef_planar, qcoef, "coefficients must round-trip");
    let decoded = pipe.decode_coefficients(
        &dec.qcoef_planar,
        dec.header.padded_width as usize,
        dec.header.padded_height as usize,
        dec.header.width as usize,
        dec.header.height as usize,
    );
    let direct = pipe.compress(&img).recon;
    assert_eq!(decoded, direct, "container decode must match direct recon");
}
