//! Property tests for the color substrate: RGB↔YCbCr round-trip error
//! bounds and chroma subsample/upsample invariants, on the crate's
//! seeded generate-and-shrink harness (`util::proptest`).

use cordic_dct::image::color::ColorImage;
use cordic_dct::image::ycbcr::{
    downsample, rgb_to_ycbcr, upsample, ycbcr_to_rgb, Subsampling,
};
use cordic_dct::image::GrayImage;
use cordic_dct::util::prng::Rng;
use cordic_dct::util::proptest::{check, gen};

/// Build an RGB image of the given dims by cycling generated samples
/// (deterministic filler when the generated vector is empty).
fn rgb_from(w: usize, h: usize, samples: &[i32]) -> ColorImage {
    let n = w * h * 3;
    let data: Vec<u8> = (0..n)
        .map(|i| {
            if samples.is_empty() {
                (i * 37 % 256) as u8
            } else {
                samples[i % samples.len()] as u8
            }
        })
        .collect();
    ColorImage::from_vec(w, h, data).expect("sized to w*h*3")
}

/// Deterministic gray plane keyed on its dimensions.
fn plane_from(w: usize, h: usize) -> GrayImage {
    let mut rng = Rng::new((w * 4099 + h) as u64);
    let data: Vec<u8> =
        (0..w * h).map(|_| rng.next_u32() as u8).collect();
    GrayImage::from_vec(w, h, data).expect("sized to w*h")
}

#[test]
fn rgb_ycbcr_roundtrip_error_at_most_2() {
    check(
        60,
        |r| {
            let w = r.below(24) as usize + 1;
            let h = r.below(24) as usize + 1;
            ((w, h), gen::vec_i32(r, 96, 0, 255))
        },
        |input| {
            let ((w, h), samples) = input;
            let img = rgb_from(*w, *h, samples);
            let (y, cb, cr) = rgb_to_ycbcr(&img);
            let back =
                ycbcr_to_rgb(&y, &cb, &cr).map_err(|e| e.to_string())?;
            for (i, (a, b)) in
                img.data.iter().zip(&back.data).enumerate()
            {
                let d = (*a as i16 - *b as i16).abs();
                if d > 2 {
                    return Err(format!(
                        "byte {i}: {a} -> {b} (err {d} > 2)"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn saturated_corners_roundtrip_error_at_most_2() {
    // the clamp-heavy extremes, exhaustively
    let corners: Vec<u8> = vec![0, 1, 127, 128, 254, 255];
    let mut data = Vec::new();
    for &r in &corners {
        for &g in &corners {
            for &b in &corners {
                data.extend_from_slice(&[r, g, b]);
            }
        }
    }
    let n = data.len() / 3;
    let img = ColorImage::from_vec(n, 1, data).unwrap();
    let (y, cb, cr) = rgb_to_ycbcr(&img);
    let back = ycbcr_to_rgb(&y, &cb, &cr).unwrap();
    for (a, b) in img.data.iter().zip(&back.data) {
        assert!(
            (*a as i16 - *b as i16).abs() <= 2,
            "{a} -> {b}"
        );
    }
}

#[test]
fn subsample_upsample_shape_invariants() {
    check(
        80,
        |r| {
            // odd sizes included by construction
            (r.below(33) as usize + 1, r.below(33) as usize + 1)
        },
        |&(w, h)| {
            let plane = plane_from(w, h);
            for mode in Subsampling::ALL {
                let d = downsample(&plane, mode);
                let (cw, ch) = mode.chroma_dims(w, h);
                if (d.width, d.height) != (cw, ch) {
                    return Err(format!(
                        "{} of {w}x{h}: got {}x{}, want {cw}x{ch}",
                        mode.as_str(),
                        d.width,
                        d.height
                    ));
                }
                let u = upsample(&d, mode, w, h);
                if (u.width, u.height) != (w, h) {
                    return Err(format!(
                        "upsample {} lost shape: {}x{}",
                        mode.as_str(),
                        u.width,
                        u.height
                    ));
                }
                if mode == Subsampling::S444
                    && (d != plane || u != plane)
                {
                    return Err("4:4:4 must be identity".to_string());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn downsample_stays_within_window_bounds() {
    check(
        60,
        |r| (r.below(25) as usize + 1, r.below(25) as usize + 1),
        |&(w, h)| {
            let plane = plane_from(w, h);
            for mode in [Subsampling::S422, Subsampling::S420] {
                let (fx, fy) = mode.factors();
                let d = downsample(&plane, mode);
                for oy in 0..d.height {
                    for ox in 0..d.width {
                        let mut lo = 255u8;
                        let mut hi = 0u8;
                        for dy in 0..fy {
                            let sy = (oy * fy + dy).min(h - 1);
                            for dx in 0..fx {
                                let sx = (ox * fx + dx).min(w - 1);
                                let v = plane.get(sx, sy);
                                lo = lo.min(v);
                                hi = hi.max(v);
                            }
                        }
                        let v = d.get(ox, oy);
                        if v < lo || v > hi {
                            return Err(format!(
                                "{} ({ox},{oy}): {v} outside \
                                 [{lo},{hi}]",
                                mode.as_str()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn constant_plane_roundtrips_exactly() {
    check(
        40,
        |r| {
            (
                (r.below(20) as usize + 1, r.below(20) as usize + 1),
                r.below(256) as i32,
            )
        },
        |input| {
            let ((w, h), v) = *input;
            let plane = GrayImage::from_vec(
                w,
                h,
                vec![v as u8; w * h],
            )
            .map_err(|e| e.to_string())?;
            for mode in Subsampling::ALL {
                let u = upsample(
                    &downsample(&plane, mode),
                    mode,
                    w,
                    h,
                );
                if u != plane {
                    return Err(format!(
                        "constant {v} not preserved under {}",
                        mode.as_str()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn odd_edge_uses_replicated_column_and_row() {
    // 5x3, last column/row distinct: the overhanging 4:2:0 windows must
    // average the replicated edge samples, nothing else
    let mut plane = GrayImage::new(5, 3);
    for y in 0..3 {
        for x in 0..5 {
            plane.set(x, y, (10 * (y * 5 + x)) as u8);
        }
    }
    let d = downsample(&plane, Subsampling::S420);
    assert_eq!((d.width, d.height), (3, 2));
    // last column, first row: window x=4,5→4 / y=0,1
    let want = ((plane.get(4, 0) as u32 * 2
        + plane.get(4, 1) as u32 * 2
        + 2)
        / 4) as u8;
    assert_eq!(d.get(2, 0), want);
    // bottom-right corner: only pixel (4,2), replicated 4x
    assert_eq!(d.get(2, 1), plane.get(4, 2));
}
