//! Color ↔ grayscale conformance: the color pipeline is a per-plane
//! orchestration of the grayscale pipeline, so on an `R = G = B` image at
//! 4:4:4 its luma path must reproduce the grayscale pipeline's output
//! bit-identically — for both CPU lanes, every variant, several
//! qualities and odd shapes. Plus container round-trips and the
//! luma-invariance guarantee under chroma subsampling.

use cordic_dct::codec::{color as color_codec, variant_tag};
use cordic_dct::dct::color::ColorPipeline;
use cordic_dct::dct::parallel::ParallelCpuPipeline;
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::Variant;
use cordic_dct::image::color::ColorImage;
use cordic_dct::image::synthetic;
use cordic_dct::image::ycbcr::Subsampling;
use cordic_dct::metrics;

const VARIANTS: [Variant; 4] = [
    Variant::Dct,
    Variant::Loeffler,
    Variant::Cordic,
    Variant::Naive,
];

#[test]
fn gray_input_444_matches_grayscale_pipeline_serial() {
    for variant in VARIANTS {
        for quality in [10u8, 50, 90] {
            let gray = synthetic::lena_like(40, 24, 3);
            let rgb = ColorImage::from_gray(&gray);
            let gray_out =
                CpuPipeline::new(variant, quality).compress(&gray);
            let color_out = ColorPipeline::new(
                variant,
                quality,
                Subsampling::S444,
            )
            .compress(&rgb);
            // luma plane: bit-identical coefficients + reconstruction
            assert_eq!(
                color_out.planes[0].qcoef, gray_out.qcoef,
                "{} q{quality}",
                variant.as_str()
            );
            assert_eq!(color_out.recon_y, gray_out.recon);
            // neutral chroma survives the chroma pipeline exactly, so
            // the RGB reconstruction replicates the gray one
            assert_eq!(
                color_out.recon,
                ColorImage::from_gray(&gray_out.recon)
            );
        }
    }
}

#[test]
fn gray_input_444_matches_grayscale_pipeline_parallel() {
    for variant in [Variant::Dct, Variant::Cordic] {
        for quality in [10u8, 50, 90] {
            // odd size exercises pad + crop through both lanes
            let gray = synthetic::cablecar_like(30, 21, 5);
            let rgb = ColorImage::from_gray(&gray);
            let gray_out =
                ParallelCpuPipeline::with_workers(variant, quality, 3)
                    .compress(&gray);
            let color_out = ColorPipeline::parallel(
                variant,
                quality,
                Subsampling::S444,
                3,
            )
            .compress(&rgb);
            assert_eq!(
                color_out.planes[0].qcoef, gray_out.qcoef,
                "{} q{quality}",
                variant.as_str()
            );
            assert_eq!(color_out.recon_y, gray_out.recon);
            assert_eq!(
                color_out.recon,
                ColorImage::from_gray(&gray_out.recon)
            );
        }
    }
}

#[test]
fn luma_plane_invariant_under_chroma_subsampling() {
    // the Y plane never touches the chroma path: all three modes must
    // produce the same luma reconstruction on a real color image
    let rgb = synthetic::lena_like_rgb(48, 33, 9);
    let base =
        ColorPipeline::new(Variant::Cordic, 50, Subsampling::S444)
            .compress(&rgb);
    for mode in [Subsampling::S422, Subsampling::S420] {
        let out = ColorPipeline::new(Variant::Cordic, 50, mode)
            .compress(&rgb);
        assert_eq!(out.recon_y, base.recon_y, "{}", mode.as_str());
        assert_eq!(out.planes[0], base.planes[0]);
    }
}

#[test]
fn luma_psnr_within_tenth_db_of_grayscale_at_420() {
    // the acceptance bar: 4:2:0 color luma PSNR vs the grayscale
    // pipeline at the same quality (bit-identical planes => delta 0)
    let rgb = synthetic::cablecar_like_rgb(64, 48, 11);
    let (y_plane, _, _) =
        cordic_dct::image::ycbcr::rgb_to_ycbcr(&rgb);
    for quality in [10u8, 50, 90] {
        let gray_recon = CpuPipeline::new(Variant::Cordic, quality)
            .compress(&y_plane)
            .recon;
        let color_out = ColorPipeline::new(
            Variant::Cordic,
            quality,
            Subsampling::S420,
        )
        .compress(&rgb);
        let p_gray = metrics::psnr(&y_plane, &gray_recon);
        let p_color = metrics::psnr(&y_plane, &color_out.recon_y);
        assert!(
            (p_gray - p_color).abs() < 0.1,
            "q{quality}: gray {p_gray:.4} vs color {p_color:.4}"
        );
    }
}

#[test]
fn color_container_roundtrips_through_codec() {
    for mode in Subsampling::ALL {
        let rgb = synthetic::lena_like_rgb(30, 21, 4);
        let pipe = ColorPipeline::new(Variant::Cordic, 75, mode);
        let out = pipe.compress(&rgb);
        let header = color_codec::ColorHeader {
            width: rgb.width as u32,
            height: rgb.height as u32,
            quality: 75,
            variant: variant_tag(Variant::Cordic),
            subsampling: color_codec::subsampling_tag(mode),
        };
        let bytes = color_codec::encode(&header, &out.planes).unwrap();
        let dec = color_codec::decode(&bytes).unwrap();
        assert_eq!(dec.planes, out.planes, "{}", mode.as_str());
        let recon = pipe.decode_coefficients(&dec.planes);
        assert_eq!(recon, out.recon);
    }
}

#[test]
fn worker_count_invariance_for_color() {
    let rgb = synthetic::lena_like_rgb(40, 40, 8);
    let base =
        ColorPipeline::parallel(Variant::Dct, 50, Subsampling::S420, 1)
            .compress(&rgb);
    for workers in [2usize, 4, 7] {
        let out = ColorPipeline::parallel(
            Variant::Dct,
            50,
            Subsampling::S420,
            workers,
        )
        .compress(&rgb);
        assert_eq!(out.recon, base.recon, "workers={workers}");
        assert_eq!(out.planes, base.planes);
    }
}
