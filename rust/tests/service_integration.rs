//! Whole-service integration: mixed workloads through the coordinator,
//! conservation invariants, routing, backpressure under load.

use cordic_dct::coordinator::{
    Backpressure, Lane, Service, ServiceConfig,
};
use cordic_dct::coordinator::batcher::BatchPolicy;
use cordic_dct::dct::Variant;
use cordic_dct::image::synthetic;
use cordic_dct::util::prng::Rng;

fn config(workers: usize, gpu: bool) -> ServiceConfig {
    ServiceConfig {
        workers,
        cpu_parallel_workers: 0,
        queue_capacity: 64,
        backpressure: Backpressure::Block,
        batch: BatchPolicy::default(),
        quality: 50,
        artifact_dir: gpu.then(|| "artifacts".into()),
        stub_gpu: false,
        ..ServiceConfig::default()
    }
}

fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

#[test]
fn mixed_workload_conservation() {
    // every submitted job returns exactly once with a sane payload,
    // across mixed shapes, scenes, variants and kinds.
    let svc = Service::start(config(4, artifacts_present())).unwrap();
    let mut rng = Rng::new(99);
    let mut handles = Vec::new();
    for i in 0..60u64 {
        let w = 8 * rng.range_i64(2, 30) as usize;
        let h = 8 * rng.range_i64(2, 30) as usize;
        let scene = if rng.chance(0.5) { "lena" } else { "cablecar" };
        let img = synthetic::by_name(scene, w, h, i).unwrap();
        let variant = if rng.chance(0.5) {
            Variant::Dct
        } else {
            Variant::Cordic
        };
        if rng.chance(0.2) {
            handles.push(svc.histeq(img, Lane::Cpu).unwrap());
        } else {
            // mix all three CPU-side routes through the coordinator
            let lane = if rng.chance(0.3) {
                Lane::CpuParallel
            } else {
                Lane::Auto
            };
            handles.push(svc.compress(img, variant, lane).unwrap());
        }
    }
    let mut ids: Vec<u64> = Vec::new();
    for h in handles {
        let resp = h.wait();
        let out = resp.result.expect("job must succeed");
        assert!(out.image.as_ref().is_some_and(|im| im.pixels() > 0));
        if let Some(p) = out.psnr_db {
            assert!(p > 20.0, "PSNR {p}");
        }
        ids.push(resp.id);
    }
    ids.sort_unstable();
    let n = ids.len();
    ids.dedup();
    assert_eq!(ids.len(), n, "duplicate responses");
    svc.shutdown();
}

#[test]
fn auto_routes_gpu_for_artifact_shapes() {
    if !artifacts_present() {
        eprintln!("skipped: no artifacts");
        return;
    }
    let svc = Service::start(config(2, true)).unwrap();
    assert!(svc.has_gpu_lane());
    // 200x200 has artifacts -> Gpu; 72x72 does not -> Cpu
    let on_artifact = svc
        .compress(
            synthetic::lena_like(200, 200, 1),
            Variant::Dct,
            Lane::Auto,
        )
        .unwrap()
        .wait();
    assert_eq!(on_artifact.lane, Lane::Gpu);
    let off_artifact = svc
        .compress(
            synthetic::lena_like(72, 72, 1),
            Variant::Dct,
            Lane::Auto,
        )
        .unwrap()
        .wait();
    assert_eq!(off_artifact.lane, Lane::Cpu);
    on_artifact.result.unwrap();
    off_artifact.result.unwrap();
    svc.shutdown();
}

#[test]
fn forced_gpu_without_artifact_fails_cleanly() {
    if !artifacts_present() {
        return;
    }
    let svc = Service::start(config(1, true)).unwrap();
    let resp = svc
        .compress(
            synthetic::lena_like(72, 72, 2),
            Variant::Dct,
            Lane::Gpu,
        )
        .unwrap()
        .wait();
    assert!(resp.result.is_err(), "no artifact for 72x72");
    svc.shutdown();
}

#[test]
fn reject_backpressure_under_burst() {
    let cfg = ServiceConfig {
        workers: 1,
        queue_capacity: 2,
        backpressure: Backpressure::Reject,
        artifact_dir: None,
        ..Default::default()
    };
    let svc = Service::start(cfg).unwrap();
    // burst far beyond capacity: some must be rejected, none lost
    let mut accepted = Vec::new();
    let mut rejected = 0usize;
    for i in 0..50u64 {
        match svc.compress(
            synthetic::lena_like(128, 128, i),
            Variant::Dct,
            Lane::Cpu,
        ) {
            Ok(h) => accepted.push(h),
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "burst should trip backpressure");
    for h in accepted {
        h.wait().result.unwrap();
    }
    svc.shutdown();
}

#[test]
fn stats_track_throughput() {
    let svc = Service::start(config(2, false)).unwrap();
    let handles: Vec<_> = (0..10)
        .map(|i| {
            svc.compress(
                synthetic::lena_like(64, 64, i),
                Variant::Cordic,
                Lane::Cpu,
            )
            .unwrap()
        })
        .collect();
    for h in handles {
        h.wait().result.unwrap();
    }
    let s = svc.stats();
    assert_eq!(s.submitted, 10);
    assert_eq!(s.process.0, 10);
    assert!(s.process.1 > 0.0, "mean process time recorded");
    assert_eq!(s.queue_depth, 0);
    svc.shutdown();
}

#[test]
fn concurrent_submitters() {
    use std::sync::Arc;
    let svc = Arc::new(Service::start(config(4, false)).unwrap());
    let mut threads = Vec::new();
    for t in 0..4u64 {
        let svc = Arc::clone(&svc);
        threads.push(std::thread::spawn(move || {
            for i in 0..8u64 {
                let img = synthetic::cablecar_like(96, 96, t * 100 + i);
                let resp = svc
                    .compress(img, Variant::Dct, Lane::Cpu)
                    .unwrap()
                    .wait();
                resp.result.unwrap();
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(svc.stats().process.0, 32);
}
