//! GPU-lane color conformance: since the planar-batch rework,
//! `Lane::Gpu` accepts `JobImage::Color` — this suite locks the GPU
//! lane's color output bit-identical to the CPU lanes on the stub
//! backend (which runs the exact CPU arithmetic host-side), across
//! variants × qualities × odd/tail sizes, through the raw executor and
//! through the coordinator; plus decode-only parity of the emitted
//! container and the regression for the old color-on-GPU error path
//! (reject → route).

use std::sync::Arc;

use cordic_dct::codec::{color as color_codec, variant_tag};
use cordic_dct::coordinator::{Lane, Service, ServiceConfig};
use cordic_dct::dct::color::ColorPipeline;
use cordic_dct::dct::Variant;
use cordic_dct::image::synthetic;
use cordic_dct::image::ycbcr::Subsampling;
use cordic_dct::runtime::{Executor, Runtime};

const VARIANTS: [Variant; 3] =
    [Variant::Dct, Variant::Loeffler, Variant::Cordic];

/// Odd / tail-heavy shapes: non-multiple-of-8 in both axes, a grid-tail
/// width (9 blocks = one 8-wide batch + scalar tail), and aligned
/// controls.
const SIZES: [(usize, usize); 4] = [(30, 21), (17, 9), (72, 16), (64, 48)];

fn stub_executor(quality: u8) -> Executor {
    Executor::new(Arc::new(Runtime::stub(quality)))
}

#[test]
fn gpu_color_bit_identical_to_serial_cpu() {
    for variant in VARIANTS {
        for quality in [10u8, 50, 90] {
            for (w, h) in SIZES {
                let rgb = synthetic::lena_like_rgb(w, h, 11);
                let gpu = stub_executor(quality)
                    .compress_color(&rgb, variant, Subsampling::S420)
                    .unwrap();
                let cpu = ColorPipeline::new(
                    variant,
                    quality,
                    Subsampling::S420,
                )
                .compress(&rgb);
                let tag =
                    format!("{} q{quality} {w}x{h}", variant.as_str());
                // qcoef parity per plane (planar interchange + fused)
                assert_eq!(gpu.planes, cpu.planes, "{tag}");
                assert_eq!(gpu.scanned, cpu.scanned, "{tag}");
                // reconstruction parity: planes and reassembled RGB
                assert_eq!(gpu.recon_y, cpu.recon_y, "{tag}");
                assert_eq!(gpu.recon_cb, cpu.recon_cb, "{tag}");
                assert_eq!(gpu.recon_cr, cpu.recon_cr, "{tag}");
                assert_eq!(gpu.recon, cpu.recon, "{tag}");
            }
        }
    }
}

#[test]
fn gpu_color_bit_identical_to_parallel_cpu_all_modes() {
    for mode in Subsampling::ALL {
        let rgb = synthetic::cablecar_like_rgb(30, 21, 7);
        let gpu = stub_executor(50)
            .compress_color(&rgb, Variant::Cordic, mode)
            .unwrap();
        let cpu =
            ColorPipeline::parallel(Variant::Cordic, 50, mode, 3)
                .compress(&rgb);
        assert_eq!(gpu.planes, cpu.planes, "{}", mode.as_str());
        assert_eq!(gpu.scanned, cpu.scanned, "{}", mode.as_str());
        assert_eq!(gpu.recon, cpu.recon, "{}", mode.as_str());
    }
}

#[test]
fn gpu_container_decodes_to_gpu_reconstruction() {
    // decode-only parity: the container the GPU lane emits (fused
    // zigzag planes -> encode_scanned) decodes on the CPU side to the
    // exact reconstruction the GPU lane reported.
    for (w, h) in [(40, 21), (17, 9)] {
        let rgb = synthetic::lena_like_rgb(w, h, 5);
        let gpu = stub_executor(50)
            .compress_color(&rgb, Variant::Cordic, Subsampling::S420)
            .unwrap();
        let header = color_codec::ColorHeader {
            width: w as u32,
            height: h as u32,
            quality: 50,
            variant: variant_tag(Variant::Cordic),
            subsampling: color_codec::subsampling_tag(Subsampling::S420),
        };
        let bytes =
            color_codec::encode_scanned(&header, &gpu.scanned).unwrap();
        // byte-identical to the planar-interchange encode path
        assert_eq!(
            bytes,
            color_codec::encode(&header, &gpu.planes).unwrap()
        );
        let dec = color_codec::decode(&bytes).unwrap();
        let pipe =
            ColorPipeline::new(Variant::Cordic, 50, Subsampling::S420);
        assert_eq!(dec.planes, gpu.planes, "{w}x{h}");
        assert_eq!(pipe.decode_coefficients(&dec.planes), gpu.recon);
    }
}

#[test]
fn color_on_gpu_rejects_without_executor_routes_with_stub() {
    // The old behavior — `Lane::Gpu` + color bails — must survive only
    // when no GPU lane is configured at all; with the stub-backed GPU
    // lane the same request now routes and succeeds, and `Auto` picks
    // the GPU lane for color.
    let rgb = synthetic::lena_like_rgb(24, 16, 2);

    let no_gpu = Service::start(ServiceConfig {
        workers: 1,
        artifact_dir: None,
        stub_gpu: false,
        ..Default::default()
    })
    .unwrap();
    let resp = no_gpu
        .compress_color(
            rgb.clone(),
            Variant::Cordic,
            Lane::Gpu,
            Subsampling::S420,
        )
        .unwrap()
        .wait();
    assert!(resp.result.is_err(), "no GPU lane: color job must fail");
    let auto = no_gpu
        .compress_color(
            rgb.clone(),
            Variant::Cordic,
            Lane::Auto,
            Subsampling::S420,
        )
        .unwrap()
        .wait();
    assert_eq!(auto.lane, Lane::Cpu, "Auto falls back to CPU");
    auto.result.unwrap();
    no_gpu.shutdown();

    let stubbed = Service::start(ServiceConfig {
        workers: 1,
        artifact_dir: None,
        stub_gpu: true,
        ..Default::default()
    })
    .unwrap();
    let forced = stubbed
        .compress_color(
            rgb.clone(),
            Variant::Cordic,
            Lane::Gpu,
            Subsampling::S420,
        )
        .unwrap()
        .wait();
    assert_eq!(forced.lane, Lane::Gpu);
    let forced_out = forced.result.unwrap();
    let routed = stubbed
        .compress_color(
            rgb.clone(),
            Variant::Cordic,
            Lane::Auto,
            Subsampling::S420,
        )
        .unwrap()
        .wait();
    assert_eq!(routed.lane, Lane::Gpu, "Auto now picks the GPU lane");
    let routed_out = routed.result.unwrap();
    // and the GPU lane's payload matches the CPU lane's bit-for-bit
    let cpu = stubbed
        .compress_color(
            rgb,
            Variant::Cordic,
            Lane::Cpu,
            Subsampling::S420,
        )
        .unwrap()
        .wait()
        .result
        .unwrap();
    assert_eq!(forced_out.color_image, cpu.color_image);
    assert_eq!(forced_out.compressed_bytes, cpu.compressed_bytes);
    assert_eq!(forced_out.psnr_db, cpu.psnr_db);
    assert_eq!(routed_out.color_image, cpu.color_image);
    stubbed.shutdown();
}

#[test]
fn gpu_gray_scanned_feed_matches_cpu_container() {
    // gray jobs ride the same fused entropy feed: the coordinator's GPU
    // and CPU lanes must report identical compressed sizes and images.
    let svc = Service::start(ServiceConfig {
        workers: 1,
        artifact_dir: None,
        stub_gpu: true,
        ..Default::default()
    })
    .unwrap();
    let img = synthetic::lena_like(30, 21, 9);
    let gpu = svc
        .compress(img.clone(), Variant::Cordic, Lane::Gpu)
        .unwrap()
        .wait();
    assert_eq!(gpu.lane, Lane::Gpu);
    let gpu = gpu.result.unwrap();
    let cpu = svc
        .compress(img, Variant::Cordic, Lane::Cpu)
        .unwrap()
        .wait()
        .result
        .unwrap();
    assert_eq!(gpu.image, cpu.image);
    assert_eq!(gpu.compressed_bytes, cpu.compressed_bytes);
    assert_eq!(gpu.psnr_db, cpu.psnr_db);
    svc.shutdown();
}
