//! End-to-end tests of the TCP front-end over real loopback sockets:
//! happy-path round trips, hostile input (garbage frames, corrupt
//! containers, mid-frame disconnects), admission control, and graceful
//! shutdown. The hard invariant throughout: the server answers
//! structured frames and keeps serving — it never panics and never
//! wedges.

use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use cordic_dct::coordinator::{Lane, ServiceConfig};
use cordic_dct::dct::Variant;
use cordic_dct::faults::FaultPlan;
use cordic_dct::image::synthetic;
use cordic_dct::image::ycbcr::Subsampling;
use cordic_dct::serve::framing::{self, FrameEvent};
use cordic_dct::serve::protocol::{
    RequestMsg, ResponseMsg, ERR_BAD_FRAME, ERR_DECODE_BAD_MAGIC,
    ERR_DECODE_TRUNCATED, ERR_WORKER_PANIC,
};
use cordic_dct::serve::{Client, ImagePayload, ServeConfig, TcpServer};

fn test_server(max_connections: usize) -> TcpServer {
    let cfg = ServeConfig {
        service: ServiceConfig {
            workers: 2,
            queue_capacity: 32,
            artifact_dir: None,
            ..Default::default()
        },
        max_connections,
        ..Default::default()
    };
    TcpServer::bind("127.0.0.1:0", cfg).expect("bind test server")
}

/// Read one frame from a raw stream, tolerating idle ticks, with an
/// overall deadline.
fn read_one_frame(stream: &TcpStream) -> ResponseMsg {
    stream
        .set_read_timeout(Some(Duration::from_millis(100)))
        .unwrap();
    let mut reader = std::io::BufReader::new(stream);
    let t0 = Instant::now();
    loop {
        match framing::read_frame(&mut reader, 1 << 20).expect("read frame")
        {
            FrameEvent::Frame { kind, payload } => {
                return ResponseMsg::decode(kind, &payload).expect("decode")
            }
            FrameEvent::Eof => panic!("EOF before a frame arrived"),
            FrameEvent::Idle => {
                assert!(
                    t0.elapsed() < Duration::from_secs(10),
                    "no frame within 10s"
                );
            }
        }
    }
}

#[test]
fn compress_decode_round_trip_over_socket() {
    let server = test_server(8);
    let mut client = Client::connect(server.local_addr()).unwrap();
    client.ping().unwrap();

    let img = synthetic::lena_like(64, 48, 7);
    let full = client
        .compress_gray(&img, Variant::Cordic, Lane::Cpu, true)
        .unwrap();
    assert!(!full.container.is_empty());
    let psnr = full.psnr_db.expect("want_psnr=true returns a PSNR");
    assert!(psnr > 20.0, "implausible psnr {psnr}");

    // the psnr-free fast path returns the same container, no number
    let fast = client
        .compress_gray(&img, Variant::Cordic, Lane::Cpu, false)
        .unwrap();
    assert_eq!(fast.container, full.container);
    assert!(fast.psnr_db.is_none());

    // server-side decode of the container we just got back
    match client.decode(full.container, Lane::Cpu).unwrap() {
        ImagePayload::Gray(g) => {
            assert_eq!((g.width, g.height), (64, 48));
        }
        other => panic!("expected gray image, got {other:?}"),
    }
    server.shutdown();
}

#[test]
fn color_and_histeq_round_trip() {
    let server = test_server(8);
    let mut client = Client::connect(server.local_addr()).unwrap();

    let rgb = synthetic::lena_like_rgb(32, 32, 3);
    let comp = client
        .compress_color(
            &rgb,
            Variant::Cordic,
            Lane::Cpu,
            Subsampling::S420,
            false,
        )
        .unwrap();
    assert!(!comp.container.is_empty());
    match client.decode(comp.container, Lane::Cpu).unwrap() {
        ImagePayload::Color(c) => {
            assert_eq!((c.width, c.height), (32, 32));
        }
        other => panic!("expected color image, got {other:?}"),
    }

    let gray = synthetic::lena_like(40, 24, 5);
    let eq = client.histeq(&gray, Lane::Cpu).unwrap();
    assert_eq!((eq.width, eq.height), (40, 24));
    server.shutdown();
}

#[test]
fn corrupt_containers_answer_decode_error_frames() {
    let server = test_server(8);
    let mut client = Client::connect(server.local_addr()).unwrap();

    // garbage bytes: wrong magic
    let resp = client
        .request(&RequestMsg::Decode {
            container: b"definitely not a container".to_vec(),
            lane: Lane::Cpu,
        })
        .unwrap();
    match resp {
        ResponseMsg::Error { code, .. } => {
            assert_eq!(code, ERR_DECODE_BAD_MAGIC);
        }
        other => panic!("expected Error frame, got {other:?}"),
    }

    // a real container cut short: truncated
    let img = synthetic::lena_like(32, 32, 9);
    let good = client
        .compress_gray(&img, Variant::Cordic, Lane::Cpu, false)
        .unwrap()
        .container;
    let resp = client
        .request(&RequestMsg::Decode {
            container: good[..8].to_vec(),
            lane: Lane::Cpu,
        })
        .unwrap();
    match resp {
        ResponseMsg::Error { code, .. } => {
            assert_eq!(code, ERR_DECODE_TRUNCATED);
        }
        other => panic!("expected Error frame, got {other:?}"),
    }

    // a flipped header byte lands somewhere in the decode-error range
    let mut bad = good.clone();
    let mid = bad.len() / 2;
    bad[mid] ^= 0xFF;
    let resp = client
        .request(&RequestMsg::Decode {
            container: bad,
            lane: Lane::Cpu,
        })
        .unwrap();
    if let ResponseMsg::Error { code, .. } = resp {
        assert!(
            (10..=14).contains(&code),
            "expected a decode error code, got {code}"
        );
    }

    // the connection survived every hostile container
    client.ping().unwrap();
    server.shutdown();
}

#[test]
fn unknown_frame_kind_keeps_connection_alive() {
    let server = test_server(8);
    let stream = TcpStream::connect(server.local_addr()).unwrap();
    let mut w = stream.try_clone().unwrap();

    // a well-formed frame with an unsupported kind byte
    framing::write_frame(&mut w, 0x77, b"whatever").unwrap();
    match read_one_frame(&stream) {
        ResponseMsg::Error { code, .. } => assert_eq!(code, ERR_BAD_FRAME),
        other => panic!("expected Error frame, got {other:?}"),
    }

    // same connection still answers a valid request afterwards
    let (k, p) = RequestMsg::Ping.encode();
    framing::write_frame(&mut w, k, &p).unwrap();
    assert_eq!(read_one_frame(&stream), ResponseMsg::Pong);
    server.shutdown();
}

#[test]
fn desynchronized_stream_gets_error_then_close() {
    let server = test_server(8);
    let stream = TcpStream::connect(server.local_addr()).unwrap();
    let mut w = stream.try_clone().unwrap();

    // a length prefix far above the server's frame cap
    w.write_all(&0xFFFF_FFFFu32.to_le_bytes()).unwrap();
    w.flush().unwrap();
    match read_one_frame(&stream) {
        ResponseMsg::Error { code, .. } => assert_eq!(code, ERR_BAD_FRAME),
        other => panic!("expected Error frame, got {other:?}"),
    }
    // after the error frame the server closes: the next read is EOF
    stream
        .set_read_timeout(Some(Duration::from_millis(100)))
        .unwrap();
    let mut reader = std::io::BufReader::new(&stream);
    let t0 = Instant::now();
    loop {
        match framing::read_frame(&mut reader, 1 << 20).unwrap() {
            FrameEvent::Eof => break,
            FrameEvent::Frame { .. } => panic!("unexpected frame"),
            FrameEvent::Idle => {
                assert!(t0.elapsed() < Duration::from_secs(10));
            }
        }
    }

    // the server keeps serving fresh connections
    let mut client = Client::connect(server.local_addr()).unwrap();
    client.ping().unwrap();
    server.shutdown();
}

#[test]
fn mid_frame_disconnect_does_not_wedge_server() {
    let server = test_server(8);
    {
        let mut stream = TcpStream::connect(server.local_addr()).unwrap();
        // claim a 100-byte frame, send 3 bytes, vanish
        stream.write_all(&100u32.to_le_bytes()).unwrap();
        stream.write_all(&[1, 2, 3]).unwrap();
        stream.flush().unwrap();
    } // drop = abrupt close mid-frame

    // other connections are unaffected
    let mut client = Client::connect(server.local_addr()).unwrap();
    client.ping().unwrap();
    let stats = client.stats_json().unwrap();
    assert!(stats.contains("frames_ok"), "stats missing counters: {stats}");
    server.shutdown();
}

#[test]
fn admission_gate_answers_overloaded_frame() {
    let server = test_server(1);
    // occupy the single connection slot and prove it is live
    let mut first = Client::connect(server.local_addr()).unwrap();
    first.ping().unwrap();

    // the next connection must get a structured Overloaded frame without
    // sending anything
    let rejected = TcpStream::connect(server.local_addr()).unwrap();
    assert_eq!(read_one_frame(&rejected), ResponseMsg::Overloaded);
    assert!(server.overload_rejects() >= 1);

    // freeing the slot readmits new clients (the server notices the
    // close at its next read tick)
    drop(first);
    let t0 = Instant::now();
    loop {
        let mut retry = Client::connect(server.local_addr()).unwrap();
        if retry.ping().is_ok() {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "slot never freed after client disconnect"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    server.shutdown();
}

#[test]
fn graceful_shutdown_drains_and_stops() {
    let server = test_server(8);
    let addr: SocketAddr = server.local_addr();
    let mut client = Client::connect(addr).unwrap();
    client.ping().unwrap();

    // shutdown must complete while a client connection is still open
    // (the handler notices the flag at its next idle tick)
    let t0 = Instant::now();
    server.shutdown();
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "shutdown took {:?}",
        t0.elapsed()
    );

    // the drained connection is closed from the server side
    assert!(client.ping().is_err());
    // and the listener is gone: a fresh connect either fails outright or
    // is never served
    match Client::connect(addr) {
        Err(_) => {}
        Ok(mut c) => assert!(c
            .with_deadline(Duration::from_secs(2))
            .ping()
            .is_err()),
    }
}

/// A server whose every socket read and write is injected with a fault
/// (p = 1.0, so the test is deterministic regardless of PRNG stream
/// assignment) must still complete full round trips: short reads and
/// writes only slow the framing layer down, they never corrupt it.
#[test]
fn injected_socket_faults_do_not_break_round_trips() {
    let cfg = ServeConfig {
        service: ServiceConfig {
            workers: 2,
            queue_capacity: 32,
            artifact_dir: None,
            ..Default::default()
        },
        max_connections: 8,
        faults: Some(
            FaultPlan::parse(
                "seed=5,slow-read=1.0,slow-write=1.0,short-read=1.0,\
                 short-write=1.0,slow-ms=1",
            )
            .unwrap(),
        ),
        ..Default::default()
    };
    let server = TcpServer::bind("127.0.0.1:0", cfg).unwrap();
    let img = synthetic::lena_like(48, 32, 7);
    let mut a = Client::connect(server.local_addr()).unwrap();
    let mut b = Client::connect(server.local_addr()).unwrap();
    a.ping().unwrap();
    let ca = a
        .compress_gray(&img, Variant::Cordic, Lane::Cpu, true)
        .unwrap();
    // a concurrent connection is independently faulted yet unaffected
    let cb = b
        .compress_gray(&img, Variant::Cordic, Lane::Cpu, false)
        .unwrap();
    assert!(!ca.container.is_empty());
    assert_eq!(
        ca.container, cb.container,
        "socket faults must never change the payload"
    );
    assert!(ca.psnr_db.is_some());
    a.ping().unwrap();
    server.shutdown();
}

/// A client dribbling its request a few bytes at a time (the mirror
/// image of server-side short writes) keeps its connection: partial
/// frames are legal as long as progress continues under the mid-frame
/// stall timeout. A second connection round-trips while the first is
/// still mid-frame.
#[test]
fn dribbled_request_frame_survives_and_others_proceed() {
    let server = test_server(8);
    let stream = TcpStream::connect(server.local_addr()).unwrap();
    let mut w = stream.try_clone().unwrap();
    let img = synthetic::lena_like(16, 8, 3);
    let (kind, payload) = RequestMsg::Histeq {
        image: img,
        lane: Lane::Cpu,
    }
    .encode();
    let frame = framing::encode_frame(kind, &payload).unwrap();
    let chunks: Vec<_> = frame.chunks(3).collect();
    let halfway = chunks.len() / 2;
    for (i, chunk) in chunks.into_iter().enumerate() {
        w.write_all(chunk).unwrap();
        w.flush().unwrap();
        std::thread::sleep(Duration::from_millis(2));
        // halfway through, prove the server still serves other peers
        if i == halfway {
            let mut other = Client::connect(server.local_addr()).unwrap();
            other.ping().unwrap();
        }
    }
    match read_one_frame(&stream) {
        ResponseMsg::Image {
            image: ImagePayload::Gray(g),
            ..
        } => assert_eq!((g.width, g.height), (16, 8)),
        other => panic!("expected gray Image, got {other:?}"),
    }
    server.shutdown();
}

/// Injected worker panics answer a structured `ERR_WORKER_PANIC` frame,
/// the pool respawns the worker (visible in the stats), and the
/// connection keeps serving.
#[test]
fn injected_worker_panics_answer_structured_frames() {
    let cfg = ServeConfig {
        service: ServiceConfig {
            workers: 1,
            queue_capacity: 8,
            artifact_dir: None,
            faults: Some(FaultPlan::parse("seed=1,panic=1.0").unwrap()),
            ..Default::default()
        },
        max_connections: 4,
        ..Default::default()
    };
    let server = TcpServer::bind("127.0.0.1:0", cfg).unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();
    let img = synthetic::lena_like(24, 24, 1);
    for _ in 0..2 {
        let resp = client
            .request(&RequestMsg::CompressGray {
                image: img.clone(),
                variant: Variant::Cordic,
                lane: Lane::Cpu,
                want_psnr: false,
            })
            .unwrap();
        match resp {
            ResponseMsg::Error { code, message } => {
                assert_eq!(code, ERR_WORKER_PANIC, "{message}");
                assert!(
                    message.contains("worker panicked"),
                    "unexpected message: {message}"
                );
            }
            other => panic!("expected a panic Error frame, got {other:?}"),
        }
    }
    // the connection survived both panics and the stats frame counts
    // the respawns
    client.ping().unwrap();
    let stats = client.stats_json().unwrap();
    assert!(
        stats.contains("\"worker_restarts\""),
        "stats missing restart counter: {stats}"
    );
    assert!(
        !stats.contains("\"worker_restarts\":0,"),
        "restarts never counted: {stats}"
    );
    server.shutdown();
}

/// With `--degrade`, queue-rejected compress requests come back as
/// reduced-quality Degraded replies (flagged on the client), and every
/// shed container still decodes.
#[test]
fn degrade_mode_sheds_load_with_reduced_quality_replies() {
    let cfg = ServeConfig {
        service: ServiceConfig {
            workers: 1,
            queue_capacity: 1,
            artifact_dir: None,
            // every job sleeps, so concurrent clients overrun the
            // one-deep queue deterministically
            faults: Some(
                FaultPlan::parse("seed=2,latency=1.0,latency-ms=200")
                    .unwrap(),
            ),
            ..Default::default()
        },
        max_connections: 8,
        degrade: true,
        ..Default::default()
    };
    let server = TcpServer::bind("127.0.0.1:0", cfg).unwrap();
    let addr = server.local_addr();
    let outs: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                s.spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    let img = synthetic::lena_like(32, 32, 9);
                    (0..3)
                        .map(|_| {
                            c.compress_gray(
                                &img,
                                Variant::Cordic,
                                Lane::Cpu,
                                false,
                            )
                            .unwrap()
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let degraded: Vec<_> = outs.iter().filter(|c| c.degraded).collect();
    assert!(
        !degraded.is_empty(),
        "no request was shed despite a one-deep queue and slow jobs"
    );
    for c in &outs {
        let dec = cordic_dct::codec::decoder::decode(&c.container)
            .expect("every container decodes");
        assert_eq!((dec.header.width, dec.header.height), (32, 32));
        if c.degraded {
            // half the default service quality (50), floor 10
            assert_eq!(dec.header.quality, 25);
        }
    }
    server.shutdown();
}

#[test]
fn in_flight_request_completes_during_shutdown() {
    let server = test_server(8);
    let addr = server.local_addr();
    let (admitted_tx, admitted_rx) = std::sync::mpsc::channel();
    let worker = std::thread::spawn(move || {
        let mut client = Client::connect(addr).unwrap();
        // prove the connection is admitted and its handler is live
        // before the main thread is allowed to start the shutdown
        client.ping().unwrap();
        admitted_tx.send(()).unwrap();
        let img = synthetic::lena_like(128, 128, 11);
        client.compress_gray(&img, Variant::Cordic, Lane::Cpu, true)
    });
    admitted_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("worker never got admitted");
    // give the request frame time to reach the handler (it is sent
    // right after the signal; the handler only exits on an *idle* tick,
    // so an in-flight frame is always processed), then pull the rug
    std::thread::sleep(Duration::from_millis(50));
    server.shutdown();
    // the in-flight job still produced a full response
    let comp = worker.join().unwrap().unwrap();
    assert!(!comp.container.is_empty());
    assert!(comp.psnr_db.is_some());
}
