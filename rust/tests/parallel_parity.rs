//! Conformance suite for the parallel CPU lane: the block-parallel
//! pipeline must be *bit-identical* to the serial reference for every
//! variant, quality and image shape (the precision-validation approach of
//! Ben Saad et al., arXiv:1606.02424, applied to threading instead of
//! arithmetic), plus thread-pool failure-propagation coverage.

use std::panic::{catch_unwind, AssertUnwindSafe};

use cordic_dct::dct::parallel::ParallelCpuPipeline;
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::Variant;
use cordic_dct::image::synthetic;
use cordic_dct::metrics::psnr;
use cordic_dct::util::threadpool::{parallel_map, ThreadPool};

const ALL_VARIANTS: [Variant; 4] = [
    Variant::Dct,
    Variant::Loeffler,
    Variant::Cordic,
    Variant::Naive,
];

/// The acceptance-criteria matrix: every variant at qualities {10, 50, 90}
/// produces bit-identical coefficients and reconstruction.
#[test]
fn bit_identical_across_variants_and_qualities() {
    let img = synthetic::lena_like(48, 40, 7);
    for variant in ALL_VARIANTS {
        for quality in [10u8, 50, 90] {
            let serial = CpuPipeline::new(variant, quality).compress(&img);
            let par =
                ParallelCpuPipeline::with_workers(variant, quality, 4)
                    .compress(&img);
            assert_eq!(
                par.qcoef,
                serial.qcoef,
                "qcoef diverged: {} q{quality}",
                variant.as_str()
            );
            assert_eq!(
                par.recon,
                serial.recon,
                "recon diverged: {} q{quality}",
                variant.as_str()
            );
            // bit-identical recon implies equal PSNR, but assert the
            // metric the paper reports explicitly
            let p_ser = psnr(&img, &serial.recon);
            let p_par = psnr(&img, &par.recon);
            assert_eq!(p_ser, p_par);
        }
    }
}

/// Odd (non-8-aligned) sizes exercise the pad/crop path under threading.
#[test]
fn bit_identical_on_odd_image_sizes() {
    for (w, h) in [(1usize, 1usize), (7, 31), (30, 21), (57, 9), (64, 1)] {
        let img = synthetic::cablecar_like(w, h, (w * 100 + h) as u64);
        let serial = CpuPipeline::new(Variant::Cordic, 50).compress(&img);
        let par = ParallelCpuPipeline::with_workers(Variant::Cordic, 50, 3)
            .compress(&img);
        assert_eq!(par.qcoef, serial.qcoef, "{w}x{h}");
        assert_eq!(par.recon, serial.recon, "{w}x{h}");
        assert_eq!((par.recon.width, par.recon.height), (w, h));
        assert_eq!(
            (par.padded_width, par.padded_height),
            (serial.padded_width, serial.padded_height)
        );
    }
}

/// Worker count must never change the answer (1..=8 including counts
/// larger than the band count).
#[test]
fn worker_count_never_changes_output() {
    let img = synthetic::lena_like(40, 24, 3); // 3 bands
    let reference = CpuPipeline::new(Variant::Dct, 50).compress(&img);
    for workers in 1..=8 {
        let par =
            ParallelCpuPipeline::with_workers(Variant::Dct, 50, workers)
                .compress(&img);
        assert_eq!(par.qcoef, reference.qcoef, "workers={workers}");
        assert_eq!(par.recon, reference.recon, "workers={workers}");
    }
}

/// analyze() and decode_coefficients() agree with the serial lane too —
/// the halves the coordinator and codec actually use.
#[test]
fn analyze_and_decode_match_serial() {
    let img = synthetic::cablecar_like(50, 34, 11);
    for variant in [Variant::Dct, Variant::Cordic] {
        let serial = CpuPipeline::new(variant, 75);
        let par = ParallelCpuPipeline::with_workers(variant, 75, 4);
        let (qs, pws, phs) = serial.analyze(&img);
        let (qp, pwp, php) = par.analyze(&img);
        assert_eq!((pws, phs), (pwp, php));
        assert_eq!(qs, qp, "{}", variant.as_str());
        let rs = serial.decode_coefficients(&qs, pws, phs, 50, 34);
        let rp = par.decode_coefficients(&qp, pwp, php, 50, 34);
        assert_eq!(rs, rp);
    }
}

/// Cross-pipeline mix-and-match: parallel analyze feeding the serial
/// decoder (and vice versa) reconstructs identically.
#[test]
fn lanes_interchange_through_coefficients() {
    let img = synthetic::lena_like(33, 26, 5);
    let serial = CpuPipeline::new(Variant::Loeffler, 50);
    let par = ParallelCpuPipeline::with_workers(Variant::Loeffler, 50, 2);
    let (qcoef, pw, ph) = par.analyze(&img);
    let via_serial = serial.decode_coefficients(&qcoef, pw, ph, 33, 26);
    let via_par = par.decode_coefficients(&qcoef, pw, ph, 33, 26);
    assert_eq!(via_serial, via_par);
    assert_eq!(via_serial, serial.compress(&img).recon);
}

/// ThreadPool: a panicking job must surface as a panic on join().
#[test]
fn threadpool_propagates_job_panic_on_join() {
    let pool = ThreadPool::new(2);
    pool.execute(|| panic!("boom in worker"));
    // drain: panic count becomes visible once the job ran
    while pool.panic_count() == 0 {
        std::thread::yield_now();
    }
    assert_eq!(pool.panic_count(), 1);
    let joined = catch_unwind(AssertUnwindSafe(move || pool.join()));
    assert!(joined.is_err(), "join() must re-throw job panics");
}

/// ThreadPool: healthy jobs join cleanly (no false positives).
#[test]
fn threadpool_join_clean_when_no_panics() {
    let pool = ThreadPool::new(3);
    for i in 0..30 {
        pool.execute(move || {
            let _ = i * i;
        });
    }
    assert_eq!(pool.panic_count(), 0);
    pool.join(); // must not panic
}

/// Scoped parallel_map: a panic in any band propagates to the caller
/// (std::thread::scope re-throws on scope exit), so a poisoned parallel
/// compress can never silently return partial output.
#[test]
fn parallel_map_propagates_panics() {
    let result = catch_unwind(AssertUnwindSafe(|| {
        parallel_map(8, 4, |i| {
            if i == 5 {
                panic!("band failure");
            }
            i
        })
    }));
    assert!(result.is_err(), "panicking band must propagate");
    // and a healthy map still works afterwards
    let v = parallel_map(8, 4, |i| i * 2);
    assert_eq!(v, vec![0, 2, 4, 6, 8, 10, 12, 14]);
}
