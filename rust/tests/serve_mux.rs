//! End-to-end tests of the pipelined (protocol v2) serve path over real
//! loopback sockets: many requests in flight on one connection matched
//! back by request id under fault-randomized completion order, the
//! duplicate-id and truncated-prefix error paths, window admission
//! (Busy frames) with slot recycling, and the v1-client-vs-v2-server
//! byte-compatibility regression.

use std::time::{Duration, Instant};

use cordic_dct::codec::decoder;
use cordic_dct::coordinator::{Lane, ServiceConfig};
use cordic_dct::dct::Variant;
use cordic_dct::faults::FaultPlan;
use cordic_dct::image::synthetic;
use cordic_dct::serve::framing::{self, FrameEvent};
use cordic_dct::serve::protocol::{
    ERR_BAD_FRAME, ERR_DUPLICATE_ID, REQ_V2, RESP_COMPRESSED,
    V2_PREFIX_LEN,
};
use cordic_dct::serve::{
    MuxClient, MuxEvent, RequestMsg, ResponseMsg, ServeConfig, TcpServer,
};

/// A v2-capable test server. `job_faults` arms *worker-side* fault
/// injection only (latency, panics) — the socket path stays clean so
/// frames are never corrupted in these tests.
fn mux_server(
    workers: usize,
    max_inflight: usize,
    cache_bytes: usize,
    job_faults: Option<&str>,
) -> TcpServer {
    let cfg = ServeConfig {
        service: ServiceConfig {
            workers,
            queue_capacity: 32,
            artifact_dir: None,
            faults: job_faults
                .map(|s| FaultPlan::parse(s).expect("fault spec")),
            ..Default::default()
        },
        max_connections: 8,
        max_inflight,
        cache_bytes,
        ..Default::default()
    };
    TcpServer::bind("127.0.0.1:0", cfg).expect("bind test server")
}

fn compress_req(width: usize, height: usize, seed: u64) -> RequestMsg {
    RequestMsg::CompressGray {
        image: synthetic::lena_like(width, height, seed),
        variant: Variant::Cordic,
        lane: Lane::Cpu,
        want_psnr: false,
    }
}

#[test]
fn pipelined_responses_match_their_request_ids() {
    // ~half the jobs take a fault-injected latency hit, so completion
    // order is decoupled from send order; each response must still land
    // on its own request id — proven by the decoded geometry, which is
    // unique per request
    let server = mux_server(
        4,
        32,
        0,
        Some("seed=9,latency=0.5,latency-ms=40"),
    );
    let mut client = MuxClient::connect(server.local_addr()).unwrap();
    let n = 8usize;
    let mut expected = std::collections::HashMap::new();
    for i in 0..n {
        let width = 8 * (i + 2); // unique per request
        let id = client
            .send(&compress_req(width, 16, i as u64 + 1))
            .unwrap();
        expected.insert(id, width);
    }
    let mut arrival = Vec::new();
    for _ in 0..n {
        match client.recv().unwrap() {
            MuxEvent::Response { request_id, msg } => {
                let width = expected
                    .remove(&request_id)
                    .unwrap_or_else(|| {
                        panic!("unknown or repeated id {request_id}")
                    });
                let ResponseMsg::Compressed { container, .. } = msg
                else {
                    panic!("expected Compressed, got {msg:?}");
                };
                let decoded = decoder::decode(&container)
                    .expect("container decodes");
                assert_eq!(
                    decoded.header.width as usize, width,
                    "response correlated to the wrong request"
                );
                arrival.push(request_id);
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
    assert!(expected.is_empty(), "not every request was answered");
    assert_eq!(arrival.len(), n);
    server.shutdown();
}

#[test]
fn duplicate_inflight_id_answers_structured_error() {
    // every job sleeps 200 ms, so id 7 is still in flight when its
    // duplicate arrives; the duplicate answers an inline error frame
    // under the same id and the original completes normally afterwards
    let server =
        mux_server(2, 32, 0, Some("seed=3,latency=1,latency-ms=200"));
    let mut client = MuxClient::connect(server.local_addr()).unwrap();
    let msg = compress_req(32, 32, 5);
    client.send_with_id(7, &msg).unwrap();
    client.send_with_id(7, &msg).unwrap();
    match client.recv().unwrap() {
        MuxEvent::Response { request_id, msg } => {
            assert_eq!(request_id, 7);
            let ResponseMsg::Error { code, message } = msg else {
                panic!("expected the duplicate-id error, got {msg:?}");
            };
            assert_eq!(code, ERR_DUPLICATE_ID);
            assert!(message.contains('7'), "{message}");
        }
        other => panic!("unexpected event {other:?}"),
    }
    match client.recv().unwrap() {
        MuxEvent::Response { request_id, msg } => {
            assert_eq!(request_id, 7);
            assert!(
                matches!(msg, ResponseMsg::Compressed { .. }),
                "original request must still complete, got {msg:?}"
            );
        }
        other => panic!("unexpected event {other:?}"),
    }
    // the id is free again once the original completed
    let id = client.send_with_id(7, &msg);
    assert!(id.is_ok());
    match client.recv().unwrap() {
        MuxEvent::Response { request_id, msg } => {
            assert_eq!(request_id, 7);
            assert!(matches!(msg, ResponseMsg::Compressed { .. }));
        }
        other => panic!("unexpected event {other:?}"),
    }
    server.shutdown();
}

#[test]
fn truncated_v2_prefix_answers_unwrapped_bad_frame() {
    // a v2 frame too short to carry a request id cannot be answered
    // under one — the error comes back as a plain (unwrapped) v1 error
    // frame, and the connection survives it
    let server = mux_server(1, 32, 0, None);
    let mut client = MuxClient::connect(server.local_addr()).unwrap();
    {
        let mut raw = client.stream().try_clone().unwrap();
        framing::write_frame(&mut raw, REQ_V2, &[0u8; 4]).unwrap();
    }
    // read raw: the reply must be a bare v1 error frame, not RESP_V2
    let mut reader =
        std::io::BufReader::new(client.stream().try_clone().unwrap());
    let t0 = Instant::now();
    let (kind, payload) = loop {
        match framing::read_frame(&mut reader, 1 << 20).unwrap() {
            FrameEvent::Frame { kind, payload } => break (kind, payload),
            FrameEvent::Eof => panic!("EOF before the error frame"),
            FrameEvent::Idle => assert!(
                t0.elapsed() < Duration::from_secs(10),
                "no frame within 10s"
            ),
        }
    };
    let msg = ResponseMsg::decode(kind, &payload).unwrap();
    let ResponseMsg::Error { code, .. } = msg else {
        panic!("expected a bad-frame error, got {msg:?}");
    };
    assert_eq!(code, ERR_BAD_FRAME);
    // the same connection still serves well-formed v2 traffic
    let id = client.send(&RequestMsg::Ping).unwrap();
    match client.recv().unwrap() {
        MuxEvent::Response { request_id, msg } => {
            assert_eq!(request_id, id);
            assert!(matches!(msg, ResponseMsg::Pong));
        }
        other => panic!("unexpected event {other:?}"),
    }
    server.shutdown();
}

#[test]
fn full_window_answers_busy_and_recycles_slots() {
    // window of 2, every job sleeps 150 ms: the third send must bounce
    // with a structured Busy frame carrying the cap, and once a slot
    // frees the same id is admitted and completes
    let server =
        mux_server(2, 2, 0, Some("seed=5,latency=1,latency-ms=150"));
    let mut client = MuxClient::connect(server.local_addr()).unwrap();
    let msg = compress_req(24, 24, 1);
    let a = client.send(&msg).unwrap();
    let b = client.send(&msg).unwrap();
    let c = client.send(&msg).unwrap();
    match client.recv().unwrap() {
        MuxEvent::Busy {
            request_id,
            max_inflight,
        } => {
            assert_eq!(request_id, c);
            assert_eq!(max_inflight, 2);
        }
        other => panic!("expected Busy first, got {other:?}"),
    }
    // drain one completion, freeing a slot
    let first_done = match client.recv().unwrap() {
        MuxEvent::Response { request_id, msg } => {
            assert!(matches!(msg, ResponseMsg::Compressed { .. }));
            request_id
        }
        other => panic!("unexpected event {other:?}"),
    };
    assert!(first_done == a || first_done == b);
    client.send_with_id(c, &msg).unwrap();
    let mut remaining = vec![
        if first_done == a { b } else { a },
        c,
    ];
    while !remaining.is_empty() {
        match client.recv().unwrap() {
            MuxEvent::Response { request_id, msg } => {
                assert!(
                    matches!(msg, ResponseMsg::Compressed { .. }),
                    "{msg:?}"
                );
                let pos = remaining
                    .iter()
                    .position(|&id| id == request_id)
                    .expect("known id");
                remaining.remove(pos);
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
    server.shutdown();
}

#[test]
fn v1_client_and_v2_wrapper_answer_byte_identical_payloads() {
    // the bit-compat regression: a v1 frame on a v2-capable server (with
    // the cache on) must answer the plain v1 frame shape, and the same
    // request wrapped in v2 must carry the identical payload bytes
    // behind its 9-byte prefix — cold, cached, v1, or v2
    let server = mux_server(2, 32, 8 * 1024 * 1024, None);
    let addr = server.local_addr();
    let req = compress_req(48, 32, 11);
    let (req_kind, req_payload) = req.encode();

    let raw_v1_exchange = || {
        let stream = std::net::TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_millis(100)))
            .unwrap();
        let mut w = stream.try_clone().unwrap();
        framing::write_frame(&mut w, req_kind, &req_payload).unwrap();
        let mut reader = std::io::BufReader::new(stream);
        let t0 = Instant::now();
        loop {
            match framing::read_frame(&mut reader, 1 << 24).unwrap() {
                FrameEvent::Frame { kind, payload } => {
                    return (kind, payload)
                }
                FrameEvent::Eof => panic!("EOF before a frame"),
                FrameEvent::Idle => assert!(
                    t0.elapsed() < Duration::from_secs(10),
                    "no frame within 10s"
                ),
            }
        }
    };

    // cold v1 request: plain kind byte, no v2 prefix
    let (k_cold, p_cold) = raw_v1_exchange();
    assert_eq!(k_cold, RESP_COMPRESSED, "v1 client saw a v2 frame kind");
    // same request through the v2 wrapper (a cache hit now): identical
    // inner bytes behind the prefix
    let mut mux = MuxClient::connect(addr).unwrap();
    let id = mux.send(&req).unwrap();
    let inner = match mux.recv().unwrap() {
        MuxEvent::Response { request_id, msg } => {
            assert_eq!(request_id, id);
            let (inner_kind, inner_payload) = msg.encode();
            assert_eq!(inner_kind, RESP_COMPRESSED);
            inner_payload
        }
        other => panic!("unexpected event {other:?}"),
    };
    assert_eq!(
        inner, p_cold,
        "v2-wrapped response bytes diverge from the v1 frame"
    );
    // and a second v1 exchange (served from the cache) is bit-identical
    // to the cold one
    let (k_hit, p_hit) = raw_v1_exchange();
    assert_eq!(k_hit, k_cold);
    assert_eq!(p_hit, p_cold, "cache hit changed the v1 wire bytes");
    // sanity: the v2 payload really is prefix + v1 payload
    assert_eq!(V2_PREFIX_LEN, 9);
    server.shutdown();
}
