//! Batched-engine parity suite: the width-generic lane-major SoA engine
//! behind both CPU lanes must be *bit-identical* — `qcoef` and
//! reconstruction — to the seed one-block-at-a-time scalar path, for
//! every transform variant (including the integer cordic-fxp lane,
//! whose scalar path is the W=1 instantiation of the same kernel),
//! quality, odd/non-multiple-of-8 size, gray and color — at both the
//! 8-wide and the 16-wide lane width.
//!
//! The reference below is a transliteration of the pre-batch pipeline:
//! `extract_block -> Box<dyn Transform8x8>::forward -> quantize_block ->
//! store_coef_planar -> dequantize_block -> MatrixDct::inverse ->
//! store_block`, one block at a time.

use cordic_dct::dct::batch::{
    gather, gather_coef, scatter_blocks, scatter_coef, BatchWidth,
    BlockBatch8, EngineConfig, QBatch8, LANES,
};
use cordic_dct::dct::blocks::{
    extract_block, grid_dims, pad_to_blocks, store_block, store_coef_planar,
};
use cordic_dct::dct::color::ColorPipeline;
use cordic_dct::dct::matrix::MatrixDct;
use cordic_dct::dct::parallel::ParallelCpuPipeline;
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::quant::{
    dequantize_block, effective_qtable, effective_qtable_chroma,
    quantize_block,
};
use cordic_dct::dct::{Transform8x8, Variant};
use cordic_dct::image::ycbcr::{self, Subsampling};
use cordic_dct::image::{synthetic, GrayImage};
use cordic_dct::util::proptest::{check, gen};

const VARIANTS: [Variant; 4] = [
    Variant::Dct,
    Variant::Loeffler,
    Variant::Cordic,
    Variant::CordicFxp,
];
const QUALITIES: [u8; 3] = [10, 50, 90];

/// Explicit per-width engine configs for the cross-width tests (never
/// `Auto`, which could resolve to either width on a given runner).
fn width_cfg(width: BatchWidth) -> EngineConfig {
    EngineConfig {
        width,
        ..EngineConfig::default()
    }
}

/// Sizes exercising aligned, odd, tiny and tail-heavy block grids
/// (grid widths 8, 4, 3, 1, 9, 13 — full batches, pure tails, and
/// full-batch + tail mixes).
const SIZES: [(usize, usize); 6] =
    [(64, 64), (30, 21), (17, 9), (8, 8), (72, 16), (100, 24)];

/// Seed-path reference compression: one block at a time through the
/// virtual-dispatch transform, exactly as the pre-batch pipeline ran.
fn reference_compress(
    variant: Variant,
    qtable: &[f32; 64],
    img: &GrayImage,
) -> (Vec<f32>, GrayImage, usize, usize) {
    let transform = variant.transform();
    let decoder = MatrixDct::new();
    let padded = pad_to_blocks(img);
    let (gw, gh) = grid_dims(padded.width, padded.height);
    let mut recon = GrayImage::new(padded.width, padded.height);
    let mut qcoef = vec![0.0f32; padded.pixels()];
    let mut block = [0.0f32; 64];
    let mut qc = [0i16; 64];
    for by in 0..gh {
        for bx in 0..gw {
            extract_block(&padded, bx, by, &mut block);
            transform.forward(&mut block);
            quantize_block(&block, qtable, &mut qc);
            store_coef_planar(&mut qcoef, padded.width, bx, by, &qc);
            dequantize_block(&qc, qtable, &mut block);
            decoder.inverse(&mut block);
            store_block(&mut recon, bx, by, &block);
        }
    }
    let recon = if (padded.width, padded.height) != (img.width, img.height)
    {
        recon.crop(img.width, img.height).unwrap()
    } else {
        recon
    };
    (qcoef, recon, padded.width, padded.height)
}

#[test]
fn gray_bit_identical_on_both_lanes() {
    for variant in VARIANTS {
        for quality in QUALITIES {
            for (i, &(w, h)) in SIZES.iter().enumerate() {
                let img = synthetic::lena_like(w, h, i as u64 + 1);
                let qt = effective_qtable(quality);
                let (ref_q, ref_r, pw, ph) =
                    reference_compress(variant, &qt, &img);

                let label = format!(
                    "{} q{quality} {w}x{h}",
                    variant.as_str()
                );
                let serial =
                    CpuPipeline::new(variant, quality).compress(&img);
                assert_eq!(serial.qcoef, ref_q, "serial qcoef {label}");
                assert_eq!(serial.recon, ref_r, "serial recon {label}");
                assert_eq!(
                    (serial.padded_width, serial.padded_height),
                    (pw, ph)
                );

                let par = ParallelCpuPipeline::with_workers(
                    variant, quality, 3,
                )
                .compress(&img);
                assert_eq!(par.qcoef, ref_q, "parallel qcoef {label}");
                assert_eq!(par.recon, ref_r, "parallel recon {label}");
            }
        }
    }
}

#[test]
fn gray_decode_bit_identical_on_both_lanes() {
    // decode half alone: batched dequantize + lane IDCT vs seed scalar
    let img = synthetic::cablecar_like(100, 24, 5);
    for variant in VARIANTS {
        let qt = effective_qtable(50);
        let (ref_q, ref_r, pw, ph) = reference_compress(variant, &qt, &img);
        let serial = CpuPipeline::new(variant, 50).decode_coefficients(
            &ref_q, pw, ph, 100, 24,
        );
        assert_eq!(serial, ref_r, "serial decode {}", variant.as_str());
        let par = ParallelCpuPipeline::with_workers(variant, 50, 2)
            .decode_coefficients(&ref_q, pw, ph, 100, 24);
        assert_eq!(par, ref_r, "parallel decode {}", variant.as_str());
    }
}

#[test]
fn color_bit_identical_on_both_lanes() {
    for variant in VARIANTS {
        for quality in QUALITIES {
            for (w, h) in [(48, 40), (30, 21)] {
                let img = synthetic::lena_like_rgb(w, h, 9);
                for (lane, pipe) in [
                    (
                        "serial",
                        ColorPipeline::new(
                            variant,
                            quality,
                            Subsampling::S420,
                        ),
                    ),
                    (
                        "parallel",
                        ColorPipeline::parallel(
                            variant,
                            quality,
                            Subsampling::S420,
                            3,
                        ),
                    ),
                ] {
                    let label = format!(
                        "{lane} {} q{quality} {w}x{h}",
                        variant.as_str()
                    );
                    let out = pipe.compress(&img);
                    // per-plane reference: luma table on Y, chroma on
                    // Cb/Cr, each through the seed scalar path
                    let (y, cb, cr) = pipe.split_planes(&img);
                    let lq = effective_qtable(quality);
                    let cq = effective_qtable_chroma(quality);
                    let (qy, ry, _, _) =
                        reference_compress(variant, &lq, &y);
                    let (qcb, rcb, _, _) =
                        reference_compress(variant, &cq, &cb);
                    let (qcr, rcr, _, _) =
                        reference_compress(variant, &cq, &cr);
                    assert_eq!(out.planes[0].qcoef, qy, "Y {label}");
                    assert_eq!(out.planes[1].qcoef, qcb, "Cb {label}");
                    assert_eq!(out.planes[2].qcoef, qcr, "Cr {label}");
                    assert_eq!(out.recon_y, ry, "recon Y {label}");
                    assert_eq!(out.recon_cb, rcb, "recon Cb {label}");
                    assert_eq!(out.recon_cr, rcr, "recon Cr {label}");
                    // and the assembled RGB (upsample + BT.601 back)
                    let cb_full = ycbcr::upsample(
                        &rcb,
                        Subsampling::S420,
                        w,
                        h,
                    );
                    let cr_full = ycbcr::upsample(
                        &rcr,
                        Subsampling::S420,
                        w,
                        h,
                    );
                    let rgb =
                        ycbcr::ycbcr_to_rgb(&ry, &cb_full, &cr_full)
                            .unwrap();
                    assert_eq!(out.recon, rgb, "recon RGB {label}");
                }
            }
        }
    }
}

#[test]
fn gather_scatter_roundtrip_proptest() {
    // pixel gather -> scatter is the identity on u8 images, including
    // tail batches (n < LANES), and never bleeds across lanes
    check(
        40,
        |rng| {
            (
                (gen::dim8(rng, 6), gen::dim8(rng, 3)),
                rng.below(1000) as usize,
            )
        },
        |&((w, h), seed)| {
            let img = synthetic::lena_like(w, h, seed as u64);
            let (gw, gh) = grid_dims(w, h);
            let mut out = GrayImage::new(w, h);
            let mut batch = BlockBatch8::zeroed();
            for by in 0..gh {
                let mut bx = 0;
                while bx < gw {
                    let n = LANES.min(gw - bx);
                    gather(&mut batch, &img, bx, by, n);
                    scatter_blocks(&batch, &mut out, bx, by, n);
                    bx += n;
                }
            }
            if out == img {
                Ok(())
            } else {
                Err(format!("pixel roundtrip diverged at {w}x{h}"))
            }
        },
    );
}

#[test]
fn coef_gather_scatter_roundtrip_proptest() {
    // planar coefficient scatter -> gather is the identity on i16
    // coefficient grids, including tail batches
    check(
        40,
        |rng| {
            (
                (gen::dim8(rng, 6), gen::dim8(rng, 3)),
                rng.below(1 << 31) as usize,
            )
        },
        |&((w, h), seed)| {
            let (gw, gh) = grid_dims(w, h);
            let mut rng =
                cordic_dct::util::prng::Rng::new(seed as u64);
            let mut qb = QBatch8::zeroed();
            let mut buf = vec![0.0f32; w * h];
            let mut want: Vec<Vec<i16>> = Vec::new();
            for by in 0..gh {
                let mut bx = 0;
                while bx < gw {
                    let n = LANES.min(gw - bx);
                    for e in qb.data.iter_mut() {
                        for v in e.iter_mut().take(n) {
                            *v = rng.range_i64(-1024, 1024) as i16;
                        }
                    }
                    scatter_coef(&qb, &mut buf, w, bx, by, n);
                    let mut lanes = Vec::with_capacity(n * 64);
                    for l in 0..n {
                        for e in qb.data.iter() {
                            lanes.push(e[l]);
                        }
                    }
                    want.push(lanes);
                    bx += n;
                }
            }
            // re-gather every batch and compare lane-for-lane
            let mut got: Vec<Vec<i16>> = Vec::new();
            for by in 0..gh {
                let mut bx = 0;
                while bx < gw {
                    let n = LANES.min(gw - bx);
                    gather_coef(&buf, w, bx, by, n, &mut qb);
                    let mut lanes = Vec::with_capacity(n * 64);
                    for l in 0..n {
                        for e in qb.data.iter() {
                            lanes.push(e[l]);
                        }
                    }
                    got.push(lanes);
                    bx += n;
                }
            }
            if got == want {
                Ok(())
            } else {
                Err(format!("coef roundtrip diverged at {w}x{h}"))
            }
        },
    );
}

#[test]
fn naive_variant_also_bit_identical() {
    // the textbook baseline takes the per-lane scalar fallback inside the
    // engine; it must still match the seed path exactly
    let img = synthetic::lena_like(40, 24, 3);
    let qt = effective_qtable(50);
    let (ref_q, ref_r, _, _) =
        reference_compress(Variant::Naive, &qt, &img);
    let out = CpuPipeline::new(Variant::Naive, 50).compress(&img);
    assert_eq!(out.qcoef, ref_q);
    assert_eq!(out.recon, ref_r);
}

#[test]
fn wide_gray_bit_identical_to_reference_and_narrow() {
    // 16-wide engine vs the seed scalar reference AND the 8-wide engine,
    // on grids exercising full 16-batches, pure tails (gw < 16), and
    // full-batch + tail mixes: gw 17, 13, 4, 32.
    for variant in VARIANTS {
        for (i, &(w, h)) in
            [(136, 16), (100, 24), (30, 21), (256, 8)].iter().enumerate()
        {
            let img = synthetic::cablecar_like(w, h, i as u64 + 7);
            let qt = effective_qtable(50);
            let (ref_q, ref_r, pw, ph) =
                reference_compress(variant, &qt, &img);
            let label = format!("{} {w}x{h}", variant.as_str());

            let narrow =
                CpuPipeline::with_config(variant, 50, width_cfg(BatchWidth::W8))
                    .compress(&img);
            assert_eq!(narrow.qcoef, ref_q, "w8 qcoef {label}");
            assert_eq!(narrow.recon, ref_r, "w8 recon {label}");

            let wide = CpuPipeline::with_config(
                variant,
                50,
                width_cfg(BatchWidth::W16),
            )
            .compress(&img);
            assert_eq!(wide.qcoef, ref_q, "w16 qcoef {label}");
            assert_eq!(wide.recon, ref_r, "w16 recon {label}");
            assert_eq!(
                (wide.padded_width, wide.padded_height),
                (pw, ph),
                "w16 dims {label}"
            );

            let par = ParallelCpuPipeline::with_qtable_config(
                variant,
                50,
                3,
                effective_qtable(50),
                width_cfg(BatchWidth::W16),
            )
            .compress(&img);
            assert_eq!(par.qcoef, ref_q, "w16 parallel qcoef {label}");
            assert_eq!(par.recon, ref_r, "w16 parallel recon {label}");

            // decode half alone through the wide engine
            let dec = CpuPipeline::with_config(
                variant,
                50,
                width_cfg(BatchWidth::W16),
            )
            .decode_coefficients(&ref_q, pw, ph, w, h);
            assert_eq!(dec, ref_r, "w16 decode {label}");
        }
    }
}

#[test]
fn wide_color_bit_identical_to_narrow() {
    // color path (luma + subsampled chroma planes) through explicit
    // 8-wide and 16-wide engines on both CPU lanes: everything the
    // compress output carries must agree bit-for-bit
    for variant in VARIANTS {
        let img = synthetic::lena_like_rgb(100, 42, 11);
        let narrow = ColorPipeline::new_with(
            variant,
            50,
            Subsampling::S420,
            width_cfg(BatchWidth::W8),
        )
        .compress(&img);
        for (lane, pipe) in [
            (
                "serial",
                ColorPipeline::new_with(
                    variant,
                    50,
                    Subsampling::S420,
                    width_cfg(BatchWidth::W16),
                ),
            ),
            (
                "parallel",
                ColorPipeline::parallel_with(
                    variant,
                    50,
                    Subsampling::S420,
                    3,
                    width_cfg(BatchWidth::W16),
                ),
            ),
        ] {
            let wide = pipe.compress(&img);
            let label = format!("{lane} {}", variant.as_str());
            for (p, (wp, np)) in
                wide.planes.iter().zip(narrow.planes.iter()).enumerate()
            {
                assert_eq!(wp.qcoef, np.qcoef, "plane {p} qcoef {label}");
            }
            assert_eq!(wide.recon_y, narrow.recon_y, "recon Y {label}");
            assert_eq!(wide.recon_cb, narrow.recon_cb, "recon Cb {label}");
            assert_eq!(wide.recon_cr, narrow.recon_cr, "recon Cr {label}");
            assert_eq!(wide.recon, narrow.recon, "recon RGB {label}");
        }
    }
}
