//! Property-style tests of the serve wire protocol: randomized
//! round-trips and a decode fuzz pass. The invariant under fuzz is the
//! serve path's contract — `decode` may reject, it must never panic —
//! using a tiny deterministic xorshift generator (no dev-dependencies).

use cordic_dct::coordinator::Lane;
use cordic_dct::dct::Variant;
use cordic_dct::image::ycbcr::Subsampling;
use cordic_dct::image::GrayImage;
use cordic_dct::image::color::ColorImage;
use cordic_dct::serve::protocol::{
    REQ_COMPRESS_COLOR, REQ_COMPRESS_GRAY, REQ_DECODE, REQ_HISTEQ,
    REQ_PING, REQ_STATS,
};
use cordic_dct::serve::{RequestMsg, ResponseMsg, ImagePayload};

/// Deterministic xorshift64* PRNG; good enough to spray bytes.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }

    fn bytes(&mut self, len: usize) -> Vec<u8> {
        (0..len).map(|_| self.next() as u8).collect()
    }
}

fn rand_gray(rng: &mut Rng) -> GrayImage {
    let w = 1 + rng.below(48) as usize;
    let h = 1 + rng.below(48) as usize;
    GrayImage::from_vec(w, h, rng.bytes(w * h)).unwrap()
}

fn rand_color(rng: &mut Rng) -> ColorImage {
    let w = 1 + rng.below(24) as usize;
    let h = 1 + rng.below(24) as usize;
    ColorImage::from_vec(w, h, rng.bytes(w * h * 3)).unwrap()
}

const LANES: [Lane; 4] =
    [Lane::Cpu, Lane::CpuParallel, Lane::Gpu, Lane::Auto];
const VARIANTS: [Variant; 3] =
    [Variant::Dct, Variant::Loeffler, Variant::Cordic];
const SUBS: [Subsampling; 3] =
    [Subsampling::S444, Subsampling::S422, Subsampling::S420];

#[test]
fn randomized_request_roundtrips() {
    let mut rng = Rng(0x5eed_0001);
    for i in 0..200 {
        let lane = LANES[rng.below(4) as usize];
        let variant = VARIANTS[rng.below(3) as usize];
        let msg = match i % 5 {
            0 => RequestMsg::CompressGray {
                image: rand_gray(&mut rng),
                variant,
                lane,
                want_psnr: rng.below(2) == 1,
            },
            1 => RequestMsg::CompressColor {
                image: rand_color(&mut rng),
                variant,
                lane,
                subsampling: SUBS[rng.below(3) as usize],
                want_psnr: rng.below(2) == 1,
            },
            2 => RequestMsg::Decode {
                container: rng.bytes(rng.below(256) as usize),
                lane,
            },
            3 => RequestMsg::Histeq {
                image: rand_gray(&mut rng),
                lane,
            },
            _ => RequestMsg::Ping,
        };
        let (k, p) = msg.encode();
        let back = RequestMsg::decode(k, &p)
            .unwrap_or_else(|e| panic!("roundtrip {i} failed: {e:#}"));
        assert_eq!(back, msg, "roundtrip {i} mutated the message");
    }
}

#[test]
fn randomized_response_roundtrips() {
    let mut rng = Rng(0x5eed_0002);
    for i in 0..200 {
        let lane = LANES[rng.below(4) as usize];
        let msg = match i % 4 {
            0 => ResponseMsg::Compressed {
                lane,
                psnr_db: (rng.below(2) == 1)
                    .then(|| rng.below(6000) as f64 / 100.0),
                container: rng.bytes(rng.below(512) as usize),
            },
            1 => ResponseMsg::Image {
                lane,
                image: if rng.below(2) == 1 {
                    ImagePayload::Gray(rand_gray(&mut rng))
                } else {
                    ImagePayload::Color(rand_color(&mut rng))
                },
            },
            2 => ResponseMsg::Error {
                code: rng.below(30) as u16,
                message: format!("failure {}", rng.below(1000)),
            },
            _ => ResponseMsg::Overloaded,
        };
        let (k, p) = msg.encode();
        let back = ResponseMsg::decode(k, &p)
            .unwrap_or_else(|e| panic!("roundtrip {i} failed: {e:#}"));
        assert_eq!(back, msg, "roundtrip {i} mutated the message");
    }
}

#[test]
fn random_payload_fuzz_never_panics() {
    let mut rng = Rng(0x5eed_0003);
    let kinds = [
        REQ_COMPRESS_GRAY,
        REQ_COMPRESS_COLOR,
        REQ_DECODE,
        REQ_HISTEQ,
        REQ_PING,
        REQ_STATS,
    ];
    for _ in 0..2000 {
        let kind = if rng.below(4) == 0 {
            rng.next() as u8 // arbitrary, mostly invalid kinds too
        } else {
            kinds[rng.below(kinds.len() as u64) as usize]
        };
        let payload = rng.bytes(rng.below(96) as usize);
        // Ok or Err are both fine; panicking or aborting is the bug
        let _ = RequestMsg::decode(kind, &payload);
        let _ = ResponseMsg::decode(kind, &payload);
    }
}

#[test]
fn truncation_fuzz_of_every_message_shape() {
    let mut rng = Rng(0x5eed_0004);
    let gray = rand_gray(&mut rng);
    let color = rand_color(&mut rng);
    let msgs = vec![
        RequestMsg::CompressGray {
            image: gray.clone(),
            variant: Variant::Cordic,
            lane: Lane::Auto,
            want_psnr: true,
        },
        RequestMsg::CompressColor {
            image: color.clone(),
            variant: Variant::Loeffler,
            lane: Lane::Cpu,
            subsampling: Subsampling::S420,
            want_psnr: false,
        },
        RequestMsg::Histeq {
            image: gray.clone(),
            lane: Lane::Cpu,
        },
    ];
    for msg in msgs {
        let (k, p) = msg.encode();
        for cut in 0..p.len() {
            assert!(
                RequestMsg::decode(k, &p[..cut]).is_err(),
                "{msg:?} parsed from a {cut}-byte prefix"
            );
        }
    }
    // responses carrying pixels are length-checked the same way
    let (k, p) = ResponseMsg::Image {
        lane: Lane::Cpu,
        image: ImagePayload::Color(color),
    }
    .encode();
    for cut in 0..p.len() {
        assert!(
            ResponseMsg::decode(k, &p[..cut]).is_err(),
            "image response parsed from a {cut}-byte prefix"
        );
    }
}

#[test]
fn bit_flip_fuzz_decodes_or_rejects_consistently() {
    // flipping any single bit of a valid frame must either produce a
    // clean parse error or a still-well-formed message — never a panic,
    // never an out-of-bounds read. A surviving parse may differ from the
    // wire bytes (e.g. a non-canonical bool byte), but its canonical
    // re-encoding must be stable: encode(decode(x)) is a fixed point.
    let mut rng = Rng(0x5eed_0005);
    let msg = RequestMsg::CompressGray {
        image: rand_gray(&mut rng),
        variant: Variant::Cordic,
        lane: Lane::Cpu,
        want_psnr: false,
    };
    let (k, p) = msg.encode();
    for byte in 0..p.len().min(64) {
        for bit in 0..8 {
            let mut q = p.clone();
            q[byte] ^= 1 << bit;
            if let Ok(parsed) = RequestMsg::decode(k, &q) {
                let (k2, p2) = parsed.encode();
                let again = RequestMsg::decode(k2, &p2)
                    .expect("canonical re-encoding must parse");
                assert_eq!(again, parsed);
            }
        }
    }
}
