//! Property-style tests of the serve wire protocol: randomized
//! round-trips and a decode fuzz pass. The invariant under fuzz is the
//! serve path's contract — `decode` may reject, it must never panic —
//! using a tiny deterministic xorshift generator (no dev-dependencies).

use cordic_dct::coordinator::Lane;
use cordic_dct::dct::Variant;
use cordic_dct::image::ycbcr::Subsampling;
use cordic_dct::image::GrayImage;
use cordic_dct::image::color::ColorImage;
use cordic_dct::serve::protocol::{
    decode_v2_busy, decode_v2_request, decode_v2_response,
    encode_v2_busy, encode_v2_request, encode_v2_response, v2_prefix,
    REQ_COMPRESS_COLOR, REQ_COMPRESS_GRAY, REQ_DECODE,
    REQ_DECODE_SALVAGE, REQ_HISTEQ, REQ_PING, REQ_STATS, REQ_V2,
    RESP_V2, RESP_V2_BUSY, V2_PREFIX_LEN,
};
use cordic_dct::serve::{RequestMsg, ResponseMsg, ImagePayload};

/// Deterministic xorshift64* PRNG; good enough to spray bytes.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }

    fn bytes(&mut self, len: usize) -> Vec<u8> {
        (0..len).map(|_| self.next() as u8).collect()
    }
}

fn rand_gray(rng: &mut Rng) -> GrayImage {
    let w = 1 + rng.below(48) as usize;
    let h = 1 + rng.below(48) as usize;
    GrayImage::from_vec(w, h, rng.bytes(w * h)).unwrap()
}

fn rand_color(rng: &mut Rng) -> ColorImage {
    let w = 1 + rng.below(24) as usize;
    let h = 1 + rng.below(24) as usize;
    ColorImage::from_vec(w, h, rng.bytes(w * h * 3)).unwrap()
}

const LANES: [Lane; 4] =
    [Lane::Cpu, Lane::CpuParallel, Lane::Gpu, Lane::Auto];
const VARIANTS: [Variant; 3] =
    [Variant::Dct, Variant::Loeffler, Variant::Cordic];
const SUBS: [Subsampling; 3] =
    [Subsampling::S444, Subsampling::S422, Subsampling::S420];

#[test]
fn randomized_request_roundtrips() {
    let mut rng = Rng(0x5eed_0001);
    for i in 0..200 {
        let lane = LANES[rng.below(4) as usize];
        let variant = VARIANTS[rng.below(3) as usize];
        let msg = match i % 6 {
            0 => RequestMsg::CompressGray {
                image: rand_gray(&mut rng),
                variant,
                lane,
                want_psnr: rng.below(2) == 1,
            },
            1 => RequestMsg::CompressColor {
                image: rand_color(&mut rng),
                variant,
                lane,
                subsampling: SUBS[rng.below(3) as usize],
                want_psnr: rng.below(2) == 1,
            },
            2 => RequestMsg::Decode {
                container: rng.bytes(rng.below(256) as usize),
                lane,
            },
            3 => RequestMsg::Histeq {
                image: rand_gray(&mut rng),
                lane,
            },
            4 => RequestMsg::DecodeSalvage {
                container: rng.bytes(rng.below(256) as usize),
                lane,
            },
            _ => RequestMsg::Ping,
        };
        let (k, p) = msg.encode();
        let back = RequestMsg::decode(k, &p)
            .unwrap_or_else(|e| panic!("roundtrip {i} failed: {e:#}"));
        assert_eq!(back, msg, "roundtrip {i} mutated the message");
    }
}

#[test]
fn randomized_response_roundtrips() {
    let mut rng = Rng(0x5eed_0002);
    for i in 0..200 {
        let lane = LANES[rng.below(4) as usize];
        let msg = match i % 5 {
            0 => ResponseMsg::Compressed {
                lane,
                psnr_db: (rng.below(2) == 1)
                    .then(|| rng.below(6000) as f64 / 100.0),
                container: rng.bytes(rng.below(512) as usize),
            },
            1 => ResponseMsg::Image {
                lane,
                image: if rng.below(2) == 1 {
                    ImagePayload::Gray(rand_gray(&mut rng))
                } else {
                    ImagePayload::Color(rand_color(&mut rng))
                },
            },
            2 => ResponseMsg::Error {
                code: rng.below(30) as u16,
                message: format!("failure {}", rng.below(1000)),
            },
            3 => ResponseMsg::Salvaged {
                lane,
                segments_total: rng.below(64) as u32,
                segments_damaged: rng.below(8) as u32,
                segments_concealed: rng.below(8) as u32,
                bytes_skipped: rng.below(1 << 20),
                image: if rng.below(2) == 1 {
                    ImagePayload::Gray(rand_gray(&mut rng))
                } else {
                    ImagePayload::Color(rand_color(&mut rng))
                },
            },
            _ => ResponseMsg::Overloaded,
        };
        let (k, p) = msg.encode();
        let back = ResponseMsg::decode(k, &p)
            .unwrap_or_else(|e| panic!("roundtrip {i} failed: {e:#}"));
        assert_eq!(back, msg, "roundtrip {i} mutated the message");
    }
}

#[test]
fn random_payload_fuzz_never_panics() {
    let mut rng = Rng(0x5eed_0003);
    let kinds = [
        REQ_COMPRESS_GRAY,
        REQ_COMPRESS_COLOR,
        REQ_DECODE,
        REQ_HISTEQ,
        REQ_PING,
        REQ_STATS,
        REQ_DECODE_SALVAGE,
    ];
    for _ in 0..2000 {
        let kind = if rng.below(4) == 0 {
            rng.next() as u8 // arbitrary, mostly invalid kinds too
        } else {
            kinds[rng.below(kinds.len() as u64) as usize]
        };
        let payload = rng.bytes(rng.below(96) as usize);
        // Ok or Err are both fine; panicking or aborting is the bug
        let _ = RequestMsg::decode(kind, &payload);
        let _ = ResponseMsg::decode(kind, &payload);
    }
}

#[test]
fn truncation_fuzz_of_every_message_shape() {
    let mut rng = Rng(0x5eed_0004);
    let gray = rand_gray(&mut rng);
    let color = rand_color(&mut rng);
    let msgs = vec![
        RequestMsg::CompressGray {
            image: gray.clone(),
            variant: Variant::Cordic,
            lane: Lane::Auto,
            want_psnr: true,
        },
        RequestMsg::CompressColor {
            image: color.clone(),
            variant: Variant::Loeffler,
            lane: Lane::Cpu,
            subsampling: Subsampling::S420,
            want_psnr: false,
        },
        RequestMsg::Histeq {
            image: gray.clone(),
            lane: Lane::Cpu,
        },
    ];
    for msg in msgs {
        let (k, p) = msg.encode();
        for cut in 0..p.len() {
            assert!(
                RequestMsg::decode(k, &p[..cut]).is_err(),
                "{msg:?} parsed from a {cut}-byte prefix"
            );
        }
    }
    // responses carrying pixels are length-checked the same way
    let (k, p) = ResponseMsg::Image {
        lane: Lane::Cpu,
        image: ImagePayload::Color(color),
    }
    .encode();
    for cut in 0..p.len() {
        assert!(
            ResponseMsg::decode(k, &p[..cut]).is_err(),
            "image response parsed from a {cut}-byte prefix"
        );
    }
}

#[test]
fn bit_flip_fuzz_decodes_or_rejects_consistently() {
    // flipping any single bit of a valid frame must either produce a
    // clean parse error or a still-well-formed message — never a panic,
    // never an out-of-bounds read. A surviving parse may differ from the
    // wire bytes (e.g. a non-canonical bool byte), but its canonical
    // re-encoding must be stable: encode(decode(x)) is a fixed point.
    let mut rng = Rng(0x5eed_0005);
    let msg = RequestMsg::CompressGray {
        image: rand_gray(&mut rng),
        variant: Variant::Cordic,
        lane: Lane::Cpu,
        want_psnr: false,
    };
    let (k, p) = msg.encode();
    for byte in 0..p.len().min(64) {
        for bit in 0..8 {
            let mut q = p.clone();
            q[byte] ^= 1 << bit;
            if let Ok(parsed) = RequestMsg::decode(k, &q) {
                let (k2, p2) = parsed.encode();
                let again = RequestMsg::decode(k2, &p2)
                    .expect("canonical re-encoding must parse");
                assert_eq!(again, parsed);
            }
        }
    }
}

#[test]
fn v2_request_id_roundtrips_across_the_id_space() {
    // the id is opaque to the server — every u64 must survive the wire,
    // including the extremes and random draws
    let mut rng = Rng(0x5eed_0006);
    let mut ids = vec![0u64, 1, u64::MAX, u64::MAX - 1, 1 << 63];
    ids.extend((0..100).map(|_| rng.next()));
    for (i, id) in ids.into_iter().enumerate() {
        let lane = LANES[rng.below(4) as usize];
        let msg = match i % 4 {
            0 => RequestMsg::CompressGray {
                image: rand_gray(&mut rng),
                variant: VARIANTS[rng.below(3) as usize],
                lane,
                want_psnr: rng.below(2) == 1,
            },
            1 => RequestMsg::Decode {
                container: rng.bytes(rng.below(128) as usize),
                lane,
            },
            2 => RequestMsg::Stats,
            _ => RequestMsg::Ping,
        };
        let (k, p) = encode_v2_request(id, &msg);
        assert_eq!(k, REQ_V2);
        let (back_id, back) = decode_v2_request(&p)
            .unwrap_or_else(|e| panic!("id {id:#x} roundtrip: {e:#}"));
        assert_eq!(back_id, id);
        assert_eq!(back, msg);
    }
}

#[test]
fn v2_response_and_busy_roundtrip() {
    let mut rng = Rng(0x5eed_0007);
    for i in 0..100 {
        let id = rng.next();
        let msg = match i % 3 {
            0 => ResponseMsg::Compressed {
                lane: LANES[rng.below(4) as usize],
                psnr_db: (rng.below(2) == 1).then(|| 41.5),
                container: rng.bytes(rng.below(256) as usize),
            },
            1 => ResponseMsg::Error {
                code: rng.below(30) as u16,
                message: format!("e{}", rng.below(100)),
            },
            _ => ResponseMsg::Overloaded,
        };
        let (k, p) = encode_v2_response(id, &msg);
        assert_eq!(k, RESP_V2);
        let (back_id, back) = decode_v2_response(&p).unwrap();
        assert_eq!((back_id, back), (id, msg));

        let cap = rng.below(1 << 16) as u32;
        let (k, p) = encode_v2_busy(id, cap);
        assert_eq!(k, RESP_V2_BUSY);
        assert_eq!(decode_v2_busy(&p).unwrap(), (id, cap));
    }
}

#[test]
fn v2_truncation_sweep_over_the_prefix_and_beyond() {
    // every cut inside the 9-byte prefix must fail at the prefix stage;
    // every cut inside the inner payload must fail the inner decode —
    // both as clean errors, never a panic or an out-of-bounds read
    let mut rng = Rng(0x5eed_0008);
    let msg = RequestMsg::CompressGray {
        image: rand_gray(&mut rng),
        variant: Variant::Cordic,
        lane: Lane::Cpu,
        want_psnr: true,
    };
    let (_, p) = encode_v2_request(0xDEAD_BEEF_CAFE_F00D, &msg);
    for cut in 0..V2_PREFIX_LEN {
        assert!(
            v2_prefix(&p[..cut]).is_err(),
            "{cut}-byte prefix parsed"
        );
    }
    for cut in V2_PREFIX_LEN..p.len() {
        // the prefix itself is intact at these cuts...
        let (id, kind, inner) = v2_prefix(&p[..cut]).unwrap();
        assert_eq!(id, 0xDEAD_BEEF_CAFE_F00D);
        assert_eq!(kind, REQ_COMPRESS_GRAY);
        assert_eq!(inner.len(), cut - V2_PREFIX_LEN);
        // ...but the truncated inner request must not parse
        assert!(
            decode_v2_request(&p[..cut]).is_err(),
            "inner request parsed from a {cut}-byte v2 frame"
        );
    }
    assert!(decode_v2_request(&p).is_ok());
}

#[test]
fn v2_header_bit_flip_fuzz_never_panics() {
    // flip every bit of the prefix (and the first inner bytes): decode
    // must answer Ok or Err, never panic. A flipped id byte still
    // parses — with a different id, which is fine: the id is opaque.
    let mut rng = Rng(0x5eed_0009);
    let msg = RequestMsg::CompressGray {
        image: rand_gray(&mut rng),
        variant: Variant::Cordic,
        lane: Lane::Cpu,
        want_psnr: false,
    };
    let (_, p) = encode_v2_request(7, &msg);
    for byte in 0..p.len().min(V2_PREFIX_LEN + 32) {
        for bit in 0..8 {
            let mut q = p.clone();
            q[byte] ^= 1 << bit;
            if let Ok((id, parsed)) = decode_v2_request(&q) {
                // surviving parses must re-encode to a fixed point
                let (_, p2) = encode_v2_request(id, &parsed);
                let (id2, again) = decode_v2_request(&p2)
                    .expect("canonical re-encoding must parse");
                assert_eq!((id2, again), (id, parsed));
            }
        }
    }
    // busy payloads too: 12 bytes, all flips
    let (_, busy) = encode_v2_busy(99, 32);
    for byte in 0..busy.len() {
        for bit in 0..8 {
            let mut q = busy.clone();
            q[byte] ^= 1 << bit;
            let _ = decode_v2_busy(&q);
        }
    }
}

#[test]
fn mixed_v1_v2_frames_do_not_desync_the_decoders() {
    // a v1 payload handed to the v2 decoder (and a v2 payload handed to
    // the v1 decoder) must fail or parse cleanly — the mixed-protocol
    // case a confused client can always produce
    let mut rng = Rng(0x5eed_000a);
    for _ in 0..500 {
        let msg = RequestMsg::CompressGray {
            image: rand_gray(&mut rng),
            variant: VARIANTS[rng.below(3) as usize],
            lane: LANES[rng.below(4) as usize],
            want_psnr: rng.below(2) == 1,
        };
        // v1 payload through the v2 parser: the first 9 bytes become a
        // bogus id + inner kind; must never panic
        let (v1_kind, v1_payload) = msg.encode();
        let _ = decode_v2_request(&v1_payload);
        let _ = decode_v2_response(&v1_payload);
        let _ = decode_v2_busy(&v1_payload);
        // v2 payload through the v1 parsers, under every v1 kind byte
        let (_, v2_payload) = encode_v2_request(rng.next(), &msg);
        for kind in [
            v1_kind,
            REQ_COMPRESS_COLOR,
            REQ_DECODE,
            REQ_HISTEQ,
            REQ_DECODE_SALVAGE,
            REQ_PING,
            REQ_STATS,
        ] {
            let _ = RequestMsg::decode(kind, &v2_payload);
            let _ = ResponseMsg::decode(kind, &v2_payload);
        }
    }
}

mod decode_classification {
    //! Regression tests for the decode-error taxonomy the serve path
    //! maps onto wire error codes: truncation anywhere in a container —
    //! including inside an embedded CDC3 plane's Huffman tables — must
    //! classify as `Truncated`, never as `Corrupt`.

    use cordic_dct::codec::color::{
        self, subsampling_tag, ColorHeader,
    };
    use cordic_dct::codec::{
        classify_decode_error, decoder, encoder, variant_tag,
        DecodeErrorKind, Header,
    };
    use cordic_dct::dct::color::ColorPipeline;
    use cordic_dct::dct::pipeline::CpuPipeline;
    use cordic_dct::dct::Variant;
    use cordic_dct::image::synthetic;
    use cordic_dct::image::ycbcr::Subsampling;

    /// 4-byte magic + w/h/pw/ph (u32 each) + quality + variant.
    const GRAY_HEAD: usize = 22;
    /// 4-byte magic + w/h (u32 each) + quality + variant + subsampling.
    const COLOR_HEAD: usize = 15;

    fn gray_v1() -> Vec<u8> {
        let img = synthetic::lena_like(40, 32, 7);
        let pipe = CpuPipeline::new(Variant::Cordic, 50);
        let scanned = pipe.analyze_scanned(&img);
        let header = Header {
            width: img.width as u32,
            height: img.height as u32,
            padded_width: scanned.padded_width as u32,
            padded_height: scanned.padded_height as u32,
            quality: 50,
            variant: variant_tag(Variant::Cordic),
        };
        encoder::encode_scanned(&header, &scanned).unwrap()
    }

    fn color_container() -> Vec<u8> {
        let img = synthetic::lena_like_rgb(40, 32, 7);
        let pipe = ColorPipeline::new(
            Variant::Cordic,
            50,
            Subsampling::S444,
        );
        let planes = pipe.analyze(&img);
        let header = ColorHeader {
            width: img.width as u32,
            height: img.height as u32,
            quality: 50,
            variant: variant_tag(Variant::Cordic),
            subsampling: subsampling_tag(Subsampling::S444),
        };
        color::encode(&header, &planes).unwrap()
    }

    /// Byte offset where plane `n`'s u32 length prefix starts.
    fn plane_offset(container: &[u8], n: usize) -> usize {
        let mut off = COLOR_HEAD;
        for _ in 0..n {
            let len = u32::from_le_bytes(
                container[off..off + 4].try_into().unwrap(),
            ) as usize;
            off += 4 + len;
        }
        off
    }

    #[test]
    fn gray_truncation_inside_huffman_table_is_truncated() {
        let v1 = gray_v1();
        // every cut from mid-header through mid-table is a truncation
        for cut in [4, GRAY_HEAD - 1, GRAY_HEAD + 3, GRAY_HEAD + 9] {
            let err = decoder::decode(&v1[..cut]).unwrap_err();
            assert_eq!(
                classify_decode_error(&err),
                Some(DecodeErrorKind::Truncated),
                "cut at {cut}: {err:#}"
            );
        }
    }

    #[test]
    fn cdc3_truncated_mid_plane_is_truncated_not_corrupt() {
        let container = color_container();
        // cut inside plane 2's embedded stream, past its length prefix
        let p2 = plane_offset(&container, 2);
        let cut = p2 + 4 + GRAY_HEAD + 5;
        assert!(cut < container.len());
        let err = color::decode(&container[..cut]).unwrap_err();
        assert_eq!(
            classify_decode_error(&err),
            Some(DecodeErrorKind::Truncated),
            "{err:#}"
        );
        assert!(
            format!("{err:#}").contains("plane"),
            "error should name the damaged plane: {err:#}"
        );
    }

    #[test]
    fn cdc3_plane_cut_mid_huffman_table_is_truncated() {
        // shrink plane 2's declared length so its embedded stream ends
        // inside the DC Huffman table while the outer container stays
        // self-consistent — the misclassification the old code hit
        let container = color_container();
        let p2 = plane_offset(&container, 2);
        let inner_len = GRAY_HEAD + 5;
        let mut cut = container[..p2].to_vec();
        cut.extend_from_slice(&(inner_len as u32).to_le_bytes());
        cut.extend_from_slice(
            &container[p2 + 4..p2 + 4 + inner_len],
        );
        let err = color::decode(&cut).unwrap_err();
        assert_eq!(
            classify_decode_error(&err),
            Some(DecodeErrorKind::Truncated),
            "{err:#}"
        );
        assert!(
            format!("{err:#}").contains("plane"),
            "error should name the damaged plane: {err:#}"
        );
    }
}
