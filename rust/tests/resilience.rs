//! Corruption fuzz for the v2 (restart-segment) container. Seeded and
//! exhaustive over segment boundaries rather than random: for every
//! variant and quality tier the suite flips bits, truncates, and
//! splices at each structural offset of a `CDC2` stream and checks the
//! codec's resilience contract — strict decode fails cleanly (tagged,
//! no panic), salvage decode succeeds with an honest damage report, and
//! every intact segment's coefficients survive bit-identically. The v1
//! container must keep round-tripping unchanged alongside it.

use cordic_dct::codec::color::{self, subsampling_tag, ColorHeader};
use cordic_dct::codec::huffman::HuffmanCode;
use cordic_dct::codec::{
    classify_decode_error, decoder, encoder, variant_tag,
    DecodeErrorKind, Header, DEFAULT_RESTART_INTERVAL,
};
use cordic_dct::dct::color::ColorPipeline;
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::Variant;
use cordic_dct::image::synthetic;
use cordic_dct::image::ycbcr::Subsampling;

const VARIANTS: [Variant; 5] = [
    Variant::Dct,
    Variant::Loeffler,
    Variant::Cordic,
    Variant::CordicFxp,
    Variant::Naive,
];
const QUALITIES: [u8; 3] = [10, 50, 90];
/// Marker pair + u32 length + u32 crc32 before each segment payload.
const SEG_HEAD: usize = 10;

/// One encoded grayscale fixture: v1 and v2 streams over the same
/// quantized coefficients.
struct Fixture {
    v1: Vec<u8>,
    v2: Vec<u8>,
    qcoef: Vec<f32>,
    header: Header,
}

fn fixture(variant: Variant, quality: u8, interval: u16) -> Fixture {
    let img = synthetic::lena_like(48, 48, 7);
    let pipe = CpuPipeline::new(variant, quality);
    let (qcoef, pw, ph) = pipe.analyze(&img);
    let header = Header {
        width: img.width as u32,
        height: img.height as u32,
        padded_width: pw as u32,
        padded_height: ph as u32,
        quality,
        variant: variant_tag(variant),
    };
    let v1 = encoder::encode(&header, &qcoef).unwrap();
    let v2 = encoder::encode_v2(&header, &qcoef, interval).unwrap();
    Fixture {
        v1,
        v2,
        qcoef,
        header,
    }
}

/// Parse a v2 head far enough to locate every segment: returns
/// (rows_per_segment, per-segment start offsets, segment lengths).
fn segment_layout(v2: &[u8]) -> (usize, Vec<usize>, Vec<usize>) {
    let (header, mut off) = Header::read_v2(v2).unwrap();
    let interval = u16::from_le_bytes([v2[off], v2[off + 1]]);
    let seg_count = u32::from_le_bytes([
        v2[off + 2],
        v2[off + 3],
        v2[off + 4],
        v2[off + 5],
    ]) as usize;
    off += 6;
    let (_, used) = HuffmanCode::read_table(&v2[off..]).unwrap();
    off += used;
    let (_, used) = HuffmanCode::read_table(&v2[off..]).unwrap();
    off += used;
    let lens: Vec<usize> = (0..seg_count)
        .map(|i| {
            let o = off + i * 4;
            u32::from_le_bytes([
                v2[o],
                v2[o + 1],
                v2[o + 2],
                v2[o + 3],
            ]) as usize
        })
        .collect();
    off += seg_count * 4 + 4; // index + head crc
    let mut starts = Vec::with_capacity(seg_count);
    for &len in &lens {
        starts.push(off);
        off += SEG_HEAD + len;
    }
    assert_eq!(off, v2.len(), "segment layout must tile the container");
    let gh = header.padded_height as usize / 8;
    let rows = if interval == 0 { gh.max(1) } else { interval as usize };
    (rows, starts, lens)
}

/// Assert `got` matches `clean` on every block row outside
/// `damaged_rows` (the salvage decoder may rewrite damaged bands).
fn assert_intact_rows(
    clean: &[f32],
    got: &[f32],
    header: &Header,
    damaged_rows: std::ops::Range<usize>,
    what: &str,
) {
    let pw = header.padded_width as usize;
    let gh = header.padded_height as usize / 8;
    for by in 0..gh {
        if damaged_rows.contains(&by) {
            continue;
        }
        let band = by * 8 * pw..(by + 1) * 8 * pw;
        assert_eq!(
            &clean[band.clone()],
            &got[band],
            "{what}: intact block row {by} changed"
        );
    }
}

#[test]
fn v1_roundtrip_unchanged_across_variants_and_qualities() {
    for variant in VARIANTS {
        for quality in QUALITIES {
            let f = fixture(variant, quality, DEFAULT_RESTART_INTERVAL);
            let dec = decoder::decode(&f.v1).unwrap();
            assert_eq!(dec.header, f.header);
            assert_eq!(dec.qcoef_planar, f.qcoef);
            // salvage of a v1 stream is strict decode + a clean report
            let (sdec, report) = decoder::decode_salvage(&f.v1).unwrap();
            assert!(report.is_clean());
            assert_eq!(report.segments_total, 1);
            assert_eq!(report.bytes_skipped, 0);
            assert_eq!(sdec.qcoef_planar, f.qcoef);
        }
    }
}

#[test]
fn v2_decodes_bit_identical_to_v1_at_all_intervals() {
    for variant in VARIANTS {
        for quality in QUALITIES {
            for interval in [0u16, 1, 2, DEFAULT_RESTART_INTERVAL] {
                let f = fixture(variant, quality, interval);
                let tag = format!(
                    "{} q{quality} interval {interval}",
                    variant.as_str()
                );
                let dec = decoder::decode(&f.v2).unwrap();
                assert_eq!(dec.header, f.header, "{tag}");
                assert_eq!(dec.qcoef_planar, f.qcoef, "{tag}");
                let (sdec, report) =
                    decoder::decode_salvage(&f.v2).unwrap();
                assert!(report.is_clean(), "{tag}: {report:?}");
                assert_eq!(sdec.qcoef_planar, f.qcoef, "{tag}");
            }
        }
    }
}

#[test]
fn bit_flips_at_every_segment_boundary() {
    for variant in VARIANTS {
        for quality in QUALITIES {
            let f = fixture(variant, quality, 2);
            let (rows, starts, lens) = segment_layout(&f.v2);
            let gh = f.header.padded_height as usize / 8;
            assert!(starts.len() > 1, "fixture must be multi-segment");
            for (s, (&start, &len)) in
                starts.iter().zip(&lens).enumerate()
            {
                // marker pair, length field, crc field, first payload
                // byte — each structural field of the segment header
                let mut offsets =
                    vec![start, start + 1, start + 3, start + 7];
                if len > 0 {
                    offsets.push(start + SEG_HEAD);
                }
                for at in offsets {
                    let tag = format!(
                        "{} q{quality} seg {s} byte {at}",
                        variant.as_str()
                    );
                    let mut bad = f.v2.clone();
                    bad[at] ^= 0x01;
                    let err = decoder::decode(&bad).unwrap_err();
                    assert_eq!(
                        classify_decode_error(&err),
                        Some(DecodeErrorKind::Corrupt),
                        "{tag}: {err:#}"
                    );
                    let (dec, report) =
                        decoder::decode_salvage(&bad).unwrap();
                    assert_eq!(dec.header, f.header, "{tag}");
                    assert_eq!(
                        report.segments_total,
                        starts.len() as u32,
                        "{tag}"
                    );
                    assert_eq!(report.segments_damaged, 1, "{tag}");
                    assert_eq!(report.segments_concealed, 1, "{tag}");
                    assert!(report.bytes_skipped > 0, "{tag}");
                    let r0 = s * rows;
                    let r1 = (r0 + rows).min(gh);
                    assert_intact_rows(
                        &f.qcoef,
                        &dec.qcoef_planar,
                        &f.header,
                        r0..r1,
                        &tag,
                    );
                }
            }
        }
    }
}

#[test]
fn truncation_at_every_segment_boundary() {
    for quality in QUALITIES {
        let f = fixture(Variant::Cordic, quality, 2);
        let (rows, starts, lens) = segment_layout(&f.v2);
        let gh = f.header.padded_height as usize / 8;
        let total = starts.len() as u32;
        for (s, (&start, &len)) in starts.iter().zip(&lens).enumerate()
        {
            // cut exactly at the boundary and again mid-payload
            for cut in [start, start + SEG_HEAD + len / 2] {
                let tag =
                    format!("q{quality} seg {s} truncated at {cut}");
                let bad = &f.v2[..cut];
                let err = decoder::decode(bad).unwrap_err();
                assert_eq!(
                    classify_decode_error(&err),
                    Some(DecodeErrorKind::Truncated),
                    "{tag}: {err:#}"
                );
                let (dec, report) =
                    decoder::decode_salvage(bad).unwrap();
                assert_eq!(dec.header, f.header, "{tag}");
                assert_eq!(report.segments_total, total, "{tag}");
                assert_eq!(
                    report.segments_damaged,
                    total - s as u32,
                    "{tag}: every segment from {s} on is lost"
                );
                // concealment needs at least one intact band
                let expect_concealed =
                    if s == 0 { 0 } else { total - s as u32 };
                assert_eq!(
                    report.segments_concealed, expect_concealed,
                    "{tag}"
                );
                assert_intact_rows(
                    &f.qcoef,
                    &dec.qcoef_planar,
                    &f.header,
                    s * rows..gh,
                    &tag,
                );
            }
        }
    }
}

#[test]
fn splice_dropping_a_segment_is_reported_and_contained() {
    let f = fixture(Variant::Cordic, 50, 2);
    let (rows, starts, _) = segment_layout(&f.v2);
    let gh = f.header.padded_height as usize / 8;
    assert!(starts.len() >= 3, "need three segments to splice");
    // cut segment 1 out entirely: [head + seg0] ++ [seg2..]
    let mut bad = f.v2[..starts[1]].to_vec();
    bad.extend_from_slice(&f.v2[starts[2]..]);
    assert!(decoder::decode(&bad).is_err());
    let (dec, report) = decoder::decode_salvage(&bad).unwrap();
    assert_eq!(report.segments_damaged, 1);
    assert_eq!(report.segments_concealed, 1);
    assert_intact_rows(
        &f.qcoef,
        &dec.qcoef_planar,
        &f.header,
        rows..(2 * rows).min(gh),
        "dropped segment 1",
    );
}

#[test]
fn splice_inserting_junk_at_a_boundary_resyncs_exactly() {
    let f = fixture(Variant::Cordic, 50, 2);
    let (_, starts, _) = segment_layout(&f.v2);
    // foreign bytes between segment 0 and segment 1: the marker scan
    // must skip them and recover every coefficient bit-exactly
    let junk = [0x5Au8; 7];
    let mut bad = f.v2[..starts[1]].to_vec();
    bad.extend_from_slice(&junk);
    bad.extend_from_slice(&f.v2[starts[1]..]);
    let (dec, report) = decoder::decode_salvage(&bad).unwrap();
    assert_eq!(report.segments_damaged, 0);
    assert_eq!(report.bytes_skipped, junk.len() as u64);
    assert_eq!(dec.qcoef_planar, f.qcoef);
}

#[test]
fn random_corruption_never_panics_and_reports_are_consistent() {
    // a seeded spray over the whole container, head included: any
    // outcome is fine except a panic or a report that lies about totals
    let f = fixture(Variant::Cordic, 50, 2);
    let (_, starts, _) = segment_layout(&f.v2);
    let mut state = 0x5eed_c2c2_u64;
    let mut next = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };
    for _ in 0..500 {
        let mut bad = f.v2.clone();
        for _ in 0..1 + next() % 4 {
            // spare the 4-byte magic: version confusion is out of
            // scope here, head damage is not
            let at = 4 + (next() % (bad.len() - 4) as u64) as usize;
            bad[at] ^= 1 << (next() % 8);
        }
        if let Ok((dec, report)) = decoder::decode_salvage(&bad) {
            assert_eq!(
                report.segments_total,
                starts.len() as u32
            );
            assert!(
                report.segments_concealed <= report.segments_damaged
            );
            assert_eq!(
                dec.qcoef_planar.len(),
                f.qcoef.len(),
                "salvage must keep the declared geometry"
            );
        }
        // strict decode on the same bytes must never panic either
        let _ = decoder::decode(&bad);
    }
}

#[test]
fn color_v2_round_trips_and_salvages_per_plane() {
    let img = synthetic::cablecar_like_rgb(48, 48, 7);
    let pipe = ColorPipeline::new(Variant::Cordic, 50, Subsampling::S420);
    let planes = pipe.analyze(&img);
    let header = ColorHeader {
        width: img.width as u32,
        height: img.height as u32,
        quality: 50,
        variant: variant_tag(Variant::Cordic),
        subsampling: subsampling_tag(Subsampling::S420),
    };
    let v1 = color::encode(&header, &planes).unwrap();
    let v2 = color::encode_v2(&header, &planes, 2).unwrap();
    // both containers carry identical coefficients
    let d1 = color::decode(&v1).unwrap();
    let d2 = color::decode(&v2).unwrap();
    for i in 0..3 {
        assert_eq!(d1.planes[i].qcoef, d2.planes[i].qcoef, "plane {i}");
    }
    let (ds, report) = color::decode_salvage(&v2).unwrap();
    assert!(report.is_clean());
    assert_eq!(report.per_plane.len(), 3);
    for i in 0..3 {
        assert_eq!(ds.planes[i].qcoef, d2.planes[i].qcoef, "plane {i}");
    }

    // corrupt the luma plane's last segment: chroma must be untouched
    let luma_off = 15 + 4; // ColorHeader bytes + plane 0 length prefix
    let luma_len = u32::from_le_bytes(
        v2[15..19].try_into().unwrap(),
    ) as usize;
    let inner = &v2[luma_off..luma_off + luma_len];
    let (_, starts, lens) = segment_layout(inner);
    let last = starts.len() - 1;
    let mut bad = v2.clone();
    bad[luma_off + starts[last] + SEG_HEAD + lens[last] / 2] ^= 0x10;
    let err = color::decode(&bad).unwrap_err();
    assert_eq!(
        classify_decode_error(&err),
        Some(DecodeErrorKind::Corrupt),
        "{err:#}"
    );
    let (dsal, report) = color::decode_salvage(&bad).unwrap();
    assert_eq!(report.segments_damaged, 1);
    assert_eq!(report.per_plane[0].segments_damaged, 1);
    assert!(report.per_plane[1].is_clean());
    assert!(report.per_plane[2].is_clean());
    assert_eq!(dsal.planes[1].qcoef, d2.planes[1].qcoef);
    assert_eq!(dsal.planes[2].qcoef, d2.planes[2].qcoef);
}
