//! File-oriented codec walkthrough: synthesize a scene, write it as PNG,
//! compress it at several qualities and variants, decompress, and report
//! the rate/distortion table a codec user cares about.
//!
//! ```bash
//! cargo run --release --example compress_cli [out_dir]
//! cargo run --release --example compress_cli -- --color [out_dir]
//! ```
//!
//! `--color` runs the color (YCbCr) path instead: a synthetic RGB image
//! compressed under 4:4:4 / 4:2:2 / 4:2:0 chroma subsampling, with
//! per-channel PSNR per mode and a luma-parity check against the
//! grayscale pipeline (the color pipeline's Y plane must match it to
//! within 0.1 dB — it is bit-identical by construction).

use cordic_dct::codec::{self, color as color_codec, decoder, encoder};
use cordic_dct::dct::color::ColorPipeline;
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::Variant;
use cordic_dct::image::ycbcr::{rgb_to_ycbcr, Subsampling};
use cordic_dct::image::{synthetic, GrayImage};
use cordic_dct::metrics;
use cordic_dct::metrics::color::psnr_color;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let color = args.iter().any(|a| a == "--color");
    let out_dir = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .cloned()
        .unwrap_or_else(|| "/tmp/cordic-dct-demo".to_string());
    std::fs::create_dir_all(&out_dir)?;
    if color {
        color_demo(&out_dir)
    } else {
        gray_demo(&out_dir)
    }
}

fn gray_demo(out_dir: &str) -> anyhow::Result<()> {
    let img = synthetic::cablecar_like(512, 480, 7);
    let src_path = format!("{out_dir}/cablecar.png");
    img.save(&src_path)?;
    println!("source: {src_path} ({} raw bytes)", img.pixels());
    println!(
        "\n{:<10} {:>8} {:>12} {:>9} {:>10} {:>9}",
        "variant", "quality", "bytes", "ratio", "PSNR(dB)", "SSIM"
    );

    for variant in [Variant::Dct, Variant::Cordic] {
        for quality in [10u8, 50, 90] {
            let pipe = CpuPipeline::new(variant, quality);
            let out = pipe.compress(&img);
            let header = codec::Header {
                width: img.width as u32,
                height: img.height as u32,
                padded_width: out.padded_width as u32,
                padded_height: out.padded_height as u32,
                quality,
                variant: codec::variant_tag(variant),
            };
            let bytes = encoder::encode(&header, &out.qcoef)?;
            let cdc_path = format!(
                "{out_dir}/cablecar_{}_q{quality}.cdc",
                variant.as_str()
            );
            std::fs::write(&cdc_path, &bytes)?;

            // full read-back path, as a downstream decoder would run it
            let read = std::fs::read(&cdc_path)?;
            let dec = decoder::decode(&read)?;
            let rec: GrayImage = pipe.decode_coefficients(
                &dec.qcoef_planar,
                dec.header.padded_width as usize,
                dec.header.padded_height as usize,
                img.width,
                img.height,
            );
            rec.save(format!(
                "{out_dir}/cablecar_{}_q{quality}.png",
                variant.as_str()
            ))?;
            println!(
                "{:<10} {:>8} {:>12} {:>8.1}x {:>10.2} {:>9.4}",
                variant.as_str(),
                quality,
                bytes.len(),
                metrics::compression_ratio(img.pixels(), bytes.len()),
                metrics::psnr(&img, &rec),
                metrics::ssim(&img, &rec),
            );
        }
    }
    println!("\nwrote sources, .cdc files and reconstructions to {out_dir}");
    Ok(())
}

fn color_demo(out_dir: &str) -> anyhow::Result<()> {
    let quality = 50u8;
    let variant = Variant::Cordic;
    let img = synthetic::cablecar_like_rgb(512, 480, 7);
    let src_path = format!("{out_dir}/cablecar_rgb.png");
    img.save(&src_path)?;
    println!("source: {src_path} ({} raw RGB bytes)", img.bytes());

    // grayscale baseline on the image's own luma plane: the parity
    // reference the color pipeline must match
    let (y_plane, _, _) = rgb_to_ycbcr(&img);
    let gray_recon =
        CpuPipeline::new(variant, quality).compress(&y_plane).recon;
    let gray_luma_psnr = metrics::psnr(&y_plane, &gray_recon);

    println!(
        "\n{:<8} {:>10} {:>8} {:>8} {:>8} {:>8} {:>8} {:>9} {:>9}",
        "mode", "bytes", "R(dB)", "G(dB)", "B(dB)", "Y(dB)", "wtd",
        "ratio", "dY(gray)"
    );
    for mode in Subsampling::ALL {
        let pipe = ColorPipeline::new(variant, quality, mode);
        let out = pipe.compress(&img);
        let header = color_codec::ColorHeader {
            width: img.width as u32,
            height: img.height as u32,
            quality,
            variant: codec::variant_tag(variant),
            subsampling: color_codec::subsampling_tag(mode),
        };
        let bytes = color_codec::encode(&header, &out.planes)?;
        std::fs::write(
            format!("{out_dir}/cablecar_{}_q{quality}.cdc", mode.tag()),
            &bytes,
        )?;
        out.recon.save(format!(
            "{out_dir}/cablecar_{}_q{quality}.png",
            mode.tag()
        ))?;
        let p = psnr_color(&img, &out.recon);
        let luma_psnr = metrics::psnr(&y_plane, &out.recon_y);
        let delta = (luma_psnr - gray_luma_psnr).abs();
        println!(
            "{:<8} {:>10} {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>8.2} \
             {:>8.1}x {:>9.4}",
            mode.as_str(),
            bytes.len(),
            p.r,
            p.g,
            p.b,
            p.y,
            p.weighted,
            metrics::compression_ratio(img.bytes(), bytes.len()),
            delta,
        );
        assert!(
            delta < 0.1,
            "{} luma PSNR {luma_psnr:.4} drifted from grayscale \
             pipeline {gray_luma_psnr:.4}",
            mode.as_str()
        );
    }
    println!(
        "\nluma parity holds: every mode's Y plane matches the \
         grayscale pipeline ({gray_luma_psnr:.2} dB) within 0.1 dB"
    );
    println!("wrote color sources, .cdc files and reconstructions to {out_dir}");
    Ok(())
}
