//! File-oriented codec walkthrough: synthesize a scene, write it as PNG,
//! compress it at several qualities and variants, decompress, and report
//! the rate/distortion table a codec user cares about.
//!
//! ```bash
//! cargo run --release --example compress_cli [out_dir]
//! ```

use cordic_dct::codec::{self, decoder, encoder};
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::Variant;
use cordic_dct::image::{synthetic, GrayImage};
use cordic_dct::metrics;

fn main() -> anyhow::Result<()> {
    let out_dir = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "/tmp/cordic-dct-demo".to_string());
    std::fs::create_dir_all(&out_dir)?;

    let img = synthetic::cablecar_like(512, 480, 7);
    let src_path = format!("{out_dir}/cablecar.png");
    img.save(&src_path)?;
    println!("source: {src_path} ({} raw bytes)", img.pixels());
    println!(
        "\n{:<10} {:>8} {:>12} {:>9} {:>10} {:>9}",
        "variant", "quality", "bytes", "ratio", "PSNR(dB)", "SSIM"
    );

    for variant in [Variant::Dct, Variant::Cordic] {
        for quality in [10u8, 50, 90] {
            let pipe = CpuPipeline::new(variant, quality);
            let out = pipe.compress(&img);
            let header = codec::Header {
                width: img.width as u32,
                height: img.height as u32,
                padded_width: out.padded_width as u32,
                padded_height: out.padded_height as u32,
                quality,
                variant: codec::variant_tag(variant),
            };
            let bytes = encoder::encode(&header, &out.qcoef)?;
            let cdc_path = format!(
                "{out_dir}/cablecar_{}_q{quality}.cdc",
                variant.as_str()
            );
            std::fs::write(&cdc_path, &bytes)?;

            // full read-back path, as a downstream decoder would run it
            let read = std::fs::read(&cdc_path)?;
            let dec = decoder::decode(&read)?;
            let rec: GrayImage = pipe.decode_coefficients(
                &dec.qcoef_planar,
                dec.header.padded_width as usize,
                dec.header.padded_height as usize,
                img.width,
                img.height,
            );
            rec.save(format!(
                "{out_dir}/cablecar_{}_q{quality}.png",
                variant.as_str()
            ))?;
            println!(
                "{:<10} {:>8} {:>12} {:>8.1}x {:>10.2} {:>9.4}",
                variant.as_str(),
                quality,
                bytes.len(),
                metrics::compression_ratio(img.pixels(), bytes.len()),
                metrics::psnr(&img, &rec),
                metrics::ssim(&img, &rec),
            );
        }
    }
    println!("\nwrote sources, .cdc files and reconstructions to {out_dir}");
    Ok(())
}
