//! End-to-end serving driver (DESIGN.md E2E mandate): run the coordinator
//! on a realistic mixed workload — both scenes, several paper sizes, both
//! transform variants — through the full stack (router -> batcher ->
//! worker pool -> PJRT/CPU lanes -> entropy codec), and report
//! throughput, latency percentiles and quality. Results for EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example serve_batch [n_requests]
//! ```

use cordic_dct::coordinator::{
    Backpressure, Lane, Service, ServiceConfig,
};
use cordic_dct::dct::Variant;
use cordic_dct::image::synthetic;
use cordic_dct::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(64);

    let cfg = ServiceConfig {
        queue_capacity: 128,
        backpressure: Backpressure::Block,
        ..Default::default()
    };
    let svc = Service::start(cfg)?;
    println!(
        "coordinator up: gpu lane {}, submitting {n} mixed requests",
        if svc.has_gpu_lane() { "ON" } else { "OFF (make artifacts)" }
    );

    // mixed workload: scenes x sizes x variants, weighted toward small
    // sizes like a real thumbnailing service
    let sizes = [(200usize, 200usize), (320, 288), (512, 512), (576, 720)];
    let mut rng = Rng::new(2013);
    let t0 = std::time::Instant::now();
    let mut handles = Vec::with_capacity(n);
    let mut submitted_px = 0usize;
    for i in 0..n {
        let (w, h) = *rng.choose(&sizes);
        let scene = if rng.chance(0.5) { "lena" } else { "cablecar" };
        let variant = if rng.chance(0.5) {
            Variant::Dct
        } else {
            Variant::Cordic
        };
        let img = synthetic::by_name(scene, w, h, i as u64).unwrap();
        submitted_px += img.pixels();
        handles.push((
            variant,
            svc.compress(img, variant, Lane::Auto)?,
        ));
    }
    let submit_s = t0.elapsed().as_secs_f64();

    let mut lat = Vec::with_capacity(n);
    let mut psnr_by_variant = std::collections::BTreeMap::new();
    let mut bytes_total = 0usize;
    let mut lanes = std::collections::BTreeMap::new();
    for (variant, h) in handles {
        let resp = h.wait();
        let out = resp.result?;
        lat.push(resp.queue_ms + resp.process_ms);
        *lanes.entry(format!("{:?}", resp.lane)).or_insert(0u32) += 1;
        bytes_total += out.compressed_bytes.unwrap_or(0);
        psnr_by_variant
            .entry(variant.as_str())
            .or_insert_with(Vec::new)
            .push(out.psnr_db.unwrap_or(f64::NAN));
    }
    let wall = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lat[((p / 100.0) * (lat.len() - 1) as f64) as usize];

    println!("\n== serve_batch report ==");
    println!(
        "requests: {n} ({:.1} MPixel) in {wall:.2}s (submit {submit_s:.2}s)",
        submitted_px as f64 / 1e6
    );
    println!(
        "throughput: {:.1} req/s, {:.1} MPixel/s",
        n as f64 / wall,
        submitted_px as f64 / 1e6 / wall
    );
    println!(
        "latency ms: p50 {:.1}  p95 {:.1}  p99 {:.1}  max {:.1}",
        pct(50.0),
        pct(95.0),
        pct(99.0),
        lat.last().unwrap()
    );
    println!("lanes: {lanes:?}");
    for (v, ps) in &psnr_by_variant {
        let mean = ps.iter().sum::<f64>() / ps.len() as f64;
        println!(
            "quality [{v}]: mean PSNR {mean:.2} dB over {} jobs",
            ps.len()
        );
    }
    println!(
        "compressed: {:.1} KiB total ({:.2} bits/pixel mean)",
        bytes_total as f64 / 1024.0,
        bytes_total as f64 * 8.0 / submitted_px as f64
    );
    let stats = svc.stats();
    println!(
        "service: queue wait mean {:.2} ms / p95 {:.2} ms; \
         process mean {:.1} ms; {} PJRT executables compiled",
        stats.queue_wait.1, stats.queue_wait.2, stats.process.1,
        stats.compiled_executables
    );
    // the paper's headline property: the parallel lane must beat serial
    if let Some(gpu_jobs) = lanes.get("Gpu") {
        println!(
            "gpu lane handled {gpu_jobs}/{n} jobs (auto routing active)"
        );
    }
    svc.shutdown();
    Ok(())
}
