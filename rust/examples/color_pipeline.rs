//! The color-workload walkthrough: paper-style PSNR tables per chroma
//! subsampling mode, DCT vs Cordic-Loeffler, plus the rate side the
//! paper never showed (bytes per mode at equal quality).
//!
//! ```bash
//! cargo run --release --example color_pipeline
//! ```
//!
//! Set `CORDIC_DCT_BENCH_QUICK=1` to shrink the sweep for CI.

use cordic_dct::codec::{self, color as color_codec};
use cordic_dct::dct::color::{ColorPipeline, PlaneCoef};
use cordic_dct::dct::Variant;
use cordic_dct::image::synthetic;
use cordic_dct::image::ycbcr::{rgb_to_ycbcr, Subsampling};
use cordic_dct::metrics;
use cordic_dct::metrics::color::{psnr_color, ssim_color};

/// Container size of already-computed plane coefficients (reuses the
/// planes `compress` just produced — no second forward transform).
fn encoded_size(
    pipe: &ColorPipeline,
    w: usize,
    h: usize,
    planes: &[PlaneCoef; 3],
) -> anyhow::Result<usize> {
    let header = color_codec::ColorHeader {
        width: w as u32,
        height: h as u32,
        quality: pipe.quality,
        variant: codec::variant_tag(pipe.variant),
        subsampling: color_codec::subsampling_tag(pipe.subsampling),
    };
    Ok(color_codec::encode(&header, planes)?.len())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("CORDIC_DCT_BENCH_QUICK").is_ok();
    let (w, h) = if quick { (192, 160) } else { (512, 480) };
    let qualities: &[u8] = if quick {
        &[10, 50, 90]
    } else {
        &[10, 30, 50, 70, 90]
    };
    let img = synthetic::lena_like_rgb(w, h, 3287);
    let (y_src, _, _) = rgb_to_ycbcr(&img);
    println!(
        "color pipeline on a {w}x{h} Lena-like RGB image \
         ({} raw bytes)",
        img.bytes()
    );

    for variant in [Variant::Dct, Variant::Cordic] {
        println!(
            "\n=== {} — PSNR (dB) / SSIM / bytes per subsampling mode ===",
            variant.as_str()
        );
        println!(
            "{:<8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>10}",
            "quality", "mode", "R", "G", "B", "Y", "wtd", "ssimY",
            "bytes"
        );
        for &quality in qualities {
            for mode in Subsampling::ALL {
                let pipe = ColorPipeline::new(variant, quality, mode);
                let out = pipe.compress(&img);
                let p = psnr_color(&img, &out.recon);
                let s = ssim_color(&img, &out.recon);
                // plane-level luma PSNR: exactly mode-invariant
                let psnr_y = metrics::psnr(&y_src, &out.recon_y);
                let bytes = encoded_size(
                    &pipe,
                    img.width,
                    img.height,
                    &out.planes,
                )?;
                println!(
                    "{:<8} {:>8} {:>8.2} {:>8.2} {:>8.2} {:>8.2} \
                     {:>8.2} {:>8.4} {:>10}",
                    quality,
                    mode.as_str(),
                    p.r,
                    p.g,
                    p.b,
                    psnr_y,
                    p.weighted,
                    s.y,
                    bytes
                );
            }
        }
    }

    println!(
        "\nreading the table: the Y column is constant across modes at \
         a given quality (chroma decimation never touches luma), while \
         4:2:0 cuts the encoded size — the classic JPEG trade the color \
         lane reproduces on top of the paper's grayscale pipeline."
    );
    Ok(())
}
