//! Quickstart: compress one image through the public API, on both lanes.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use cordic_dct::codec::{self, decoder, encoder};
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::Variant;
use cordic_dct::image::synthetic;
use cordic_dct::metrics;
use cordic_dct::runtime::{Executor, Runtime};

fn main() -> anyhow::Result<()> {
    // 1. A test image (the Lena stand-in; see DESIGN.md on substitution).
    let img = synthetic::lena_like(512, 512, 42);
    println!("image: 512x512, mean {:.1}, sd {:.1}", img.mean(), img.stddev());

    // 2. CPU lane: the paper's serial pipeline with the Cordic-Loeffler DCT.
    let pipe = CpuPipeline::new(Variant::Cordic, 50);
    let t0 = std::time::Instant::now();
    let out = pipe.compress(&img);
    let cpu_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "cpu lane ({}): {:.1} ms, PSNR {:.2} dB",
        pipe.transform_name(),
        cpu_ms,
        metrics::psnr(&img, &out.recon)
    );

    // 3. Entropy-code to an actual compressed file.
    let header = codec::Header {
        width: 512,
        height: 512,
        padded_width: out.padded_width as u32,
        padded_height: out.padded_height as u32,
        quality: 50,
        variant: codec::variant_tag(Variant::Cordic),
    };
    let bytes = encoder::encode(&header, &out.qcoef)?;
    println!(
        "compressed: {} bytes ({:.1}x ratio, {:.2} bpp)",
        bytes.len(),
        metrics::compression_ratio(img.pixels(), bytes.len()),
        metrics::bits_per_pixel(bytes.len(), img.pixels())
    );

    // 4. Decode the file back and verify.
    let dec = decoder::decode(&bytes)?;
    let back = pipe.decode_coefficients(
        &dec.qcoef_planar,
        dec.header.padded_width as usize,
        dec.header.padded_height as usize,
        512,
        512,
    );
    assert_eq!(back, out.recon, "file round-trip is exact");
    println!("file round-trip: exact");

    // 5. GPU lane (PJRT artifacts), if built.
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let rt = std::sync::Arc::new(Runtime::new("artifacts")?);
        let ex = Executor::new(rt);
        let t0 = std::time::Instant::now();
        let gpu = ex.compress(&img, "cordic")?;
        let total_ms = t0.elapsed().as_secs_f64() * 1e3;
        println!(
            "gpu lane (PJRT): {:.1} ms total ({:.1} ms execute, first call \
             includes compile), PSNR {:.2} dB",
            total_ms,
            gpu.execute_ms,
            metrics::psnr(&img, &gpu.recon)
        );
        let cross = metrics::psnr(&gpu.recon, &out.recon);
        println!("lane agreement: {cross:.1} dB (higher = closer)");
        // warm second call shows the serving cost
        let t0 = std::time::Instant::now();
        let _ = ex.compress(&img, "cordic")?;
        println!(
            "gpu lane warm: {:.1} ms",
            t0.elapsed().as_secs_f64() * 1e3
        );
    } else {
        println!("gpu lane skipped: run `make artifacts` first");
    }
    Ok(())
}
