//! Reproduce the paper's full experimental narrative in one run:
//!
//! * Figures 2/7   — the original grayscale test images (stand-ins)
//! * Figures 3-4/8-9 — CPU-processed and GPU-processed reconstructions
//! * Tables 1-2    — CPU vs GPU timing sweeps (quick subset by default)
//! * Tables 3-4    — PSNR: exact DCT vs Cordic-based Loeffler
//!
//! Images land in `paper_out/`; tables print to stdout (full-size sweeps
//! run via `cargo bench` — this example keeps sizes CI-friendly unless
//! `--full` is passed).
//!
//! ```bash
//! cargo run --release --example paper_pipeline [--full]
//! ```

use cordic_dct::bench::tables::{
    self, render_paper_comparison, render_psnr_table, render_speedup_figure,
    speedup_series,
};
use cordic_dct::bench::{render_table, rows_to_json, save_results};
use cordic_dct::dct::parallel::ParallelCpuPipeline;
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::Variant;
use cordic_dct::image::synthetic;
use cordic_dct::metrics;
use cordic_dct::runtime::{Executor, Runtime};
use cordic_dct::util::timer::Bench;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    if !full {
        std::env::set_var("CORDIC_DCT_BENCH_QUICK", "1");
    }
    let out = std::path::Path::new("paper_out");
    std::fs::create_dir_all(out)?;

    // --- Figures 2 and 7: the "original" images ------------------------
    let lena = synthetic::lena_like(512, 512, 0xD_C7);
    let cable = synthetic::cablecar_like(512, 544, 0xD_C7); // 544x512 (HxW)
    lena.save(out.join("fig2_lena_original.png"))?;
    cable.save(out.join("fig7_cablecar_original.png"))?;
    println!("fig 2/7 originals -> paper_out/");

    // --- Figures 3-4 and 8-9: CPU vs GPU processed ----------------------
    let cpu_pipe = CpuPipeline::new(Variant::Cordic, 50);
    let lena_cpu = cpu_pipe.compress(&lena).recon;
    let cable_cpu = cpu_pipe.compress(&cable).recon;
    lena_cpu.save(out.join("fig3_lena_cpu.png"))?;
    cable_cpu.save(out.join("fig8_cablecar_cpu.png"))?;
    let runtime_available =
        std::path::Path::new("artifacts/manifest.json").exists();
    if runtime_available {
        let rt = std::sync::Arc::new(Runtime::new("artifacts")?);
        let ex = Executor::new(rt);
        let lena_gpu = ex.compress(&lena, "cordic")?.recon;
        let cable_gpu = ex.compress(&cable, "cordic")?.recon;
        lena_gpu.save(out.join("fig4_lena_gpu.png"))?;
        cable_gpu.save(out.join("fig9_cablecar_gpu.png"))?;
        println!(
            "fig 3/4 lena: CPU PSNR {:.2} dB, GPU PSNR {:.2} dB, \
             cross-lane {:.1} dB",
            metrics::psnr(&lena, &lena_cpu),
            metrics::psnr(&lena, &lena_gpu),
            metrics::psnr(&lena_cpu, &lena_gpu)
        );
        println!(
            "fig 8/9 cable-car: CPU PSNR {:.2} dB, GPU PSNR {:.2} dB",
            metrics::psnr(&cable, &cable_cpu),
            metrics::psnr(&cable, &cable_gpu)
        );
    } else {
        println!("(GPU figures skipped: run `make artifacts`)");
    }

    // --- Serial vs parallel CPU lane ------------------------------------
    // The paper only had one CPU number (serial); the parallel lane shows
    // what the same arithmetic does across cores, next to the CPU-vs-GPU
    // tables below.
    {
        let par_pipe = ParallelCpuPipeline::new(Variant::Cordic, 50);
        let t0 = std::time::Instant::now();
        let serial_out = cpu_pipe.compress(&lena);
        let serial_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t0 = std::time::Instant::now();
        let par_out = par_pipe.compress(&lena);
        let par_ms = t0.elapsed().as_secs_f64() * 1e3;
        assert_eq!(
            serial_out.qcoef, par_out.qcoef,
            "parallel lane must be bit-identical"
        );
        println!(
            "cpu lanes on 512x512 lena: serial {serial_ms:.1} ms vs \
             parallel {par_ms:.1} ms ({} workers) = {:.2}x speedup, \
             outputs bit-identical",
            par_pipe.workers(),
            serial_ms / par_ms.max(1e-9)
        );
    }

    // --- Tables 1-2: timing sweeps --------------------------------------
    let bench = if full {
        Bench::default()
    } else {
        Bench::quick()
    };
    for (name, title, scene, sizes, paper) in [
        (
            "table1_lena",
            "Table 1 (Lena, grayscale pipeline timing)",
            "lena",
            tables::LENA_SIZES,
            tables::PAPER_TABLE1,
        ),
        (
            "table2_cablecar",
            "Table 2 (Cable-car, grayscale pipeline timing)",
            "cablecar",
            tables::CABLECAR_SIZES,
            tables::PAPER_TABLE2,
        ),
    ] {
        let sizes = tables::maybe_trim(sizes);
        let rows =
            tables::timing_table(scene, &sizes, Variant::Cordic, bench)?;
        let mut text = render_table(title, &rows);
        text += &render_paper_comparison(title, &rows, paper);
        text += &render_speedup_figure(
            &format!("{title}: speedup"),
            &speedup_series(&rows),
        );
        println!("{text}");
        save_results(name, &text, &rows_to_json(name, &rows));
    }

    // --- Tables 3-4: PSNR ------------------------------------------------
    for (name, title, scene, sizes) in [
        (
            "table3_psnr_lena",
            "Table 3 (Lena PSNR: DCT vs Cordic-Loeffler)",
            "lena",
            tables::LENA_PSNR_SIZES,
        ),
        (
            "table4_psnr_cablecar",
            "Table 4 (Cable-car PSNR: DCT vs Cordic-Loeffler)",
            "cablecar",
            tables::CABLECAR_PSNR_SIZES,
        ),
    ] {
        let sizes = tables::maybe_trim(sizes);
        let rows = tables::psnr_table(scene, &sizes)?;
        let text = render_psnr_table(title, &rows);
        println!("{text}");
        save_results(name, &text, &rows_to_json(name, &rows));
    }

    println!("figures in paper_out/, table data in bench_results/");
    Ok(())
}
