//! The serving coordinator: request router + dynamic batcher + worker
//! pool, dispatching image-compression jobs to one of three lanes — the
//! PJRT ("GPU") lane, the serial Rust ("CPU") lane, or the block-parallel
//! Rust ("CPU-parallel") lane.
//!
//! Shape (vLLM-router-flavored, scaled to this paper's workload):
//!
//! ```text
//!  submit() ──► bounded RequestQueue (backpressure: Block | Reject)
//!                      │
//!                 Batcher: drains the queue, groups jobs by
//!                 (shape, variant, lane) up to the head lane's
//!                 max_batch / linger (max 1 => no coalescing)
//!                      │
//!              ┌───────┴────────┐
//!        worker 0 ..      worker N-1     (std threads)
//!        GPU lane:          runtime::Executor (cached PJRT executables)
//!        CPU lane:          dct::pipeline::CpuPipeline (serial scalar)
//!        CPU-parallel lane: dct::parallel::ParallelCpuPipeline
//!                           (row-band tiles over scoped threads)
//!                      │
//!              per-job result channel ──► JobHandle::wait()
//! ```
//!
//! Batching matters on the GPU lane for the same reason it does in the
//! paper's CUDA setting: per-dispatch overhead (executable lookup, literal
//! marshaling) is amortized across same-shape jobs that reuse one cached
//! executable; the ablation bench (`ablation_batching`) measures it.
//!
//! Workers run every job under a panic guard: a panicked job answers
//! its waiter with a structured [`JobError::WorkerPanic`] instead of
//! poisoning the queue, and the supervisor loop in [`service`] respawns
//! the worker (fresh pipeline cache), counting the restart into
//! [`ServiceStats::worker_restarts`].

pub mod batcher;
pub mod request;
pub mod service;
pub mod worker;

pub use request::{
    Backpressure, JobError, JobHandle, JobImage, JobOutput, Lane, Request,
    RequestKind, RequestQueue, Response, JOB_PANIC_TAG,
};
pub use service::{Service, ServiceConfig, ServiceStats};
