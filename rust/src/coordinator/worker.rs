//! Worker loop: drains batches from the request queue and runs each job
//! on its lane, replying over the per-job channel.
//!
//! Each worker keeps a [`PipelineCache`] across jobs: CPU-lane pipelines
//! (and with them their batch-engine scratch arenas) are built once per
//! construction key and reused for every subsequent request, instead of
//! re-allocating transform tables and block scratch per job.

use std::any::Any;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::codec::encoder::ScanCoefs;
use crate::codec::{color as color_codec, encoder, variant_tag, Header};
use crate::dct::batch::EngineConfig;
use crate::dct::color::ColorPipeline;
use crate::dct::parallel::ParallelCpuPipeline;
use crate::dct::pipeline::CpuPipeline;
use crate::dct::Variant;
use crate::image::color::ColorImage;
use crate::image::ycbcr::Subsampling;
use crate::image::{histeq, GrayImage};
use crate::metrics::{color::psnr_color, psnr, stats::SharedHistogram};
use crate::runtime::Executor;

use super::batcher::BatchPolicy;
use super::request::{
    JobError, JobImage, JobOutput, Lane, QueuedJob, Request, RequestKind,
    RequestQueue, Response,
};
use crate::faults::FaultInjector;

/// Shared worker context.
pub struct WorkerCtx {
    pub queue: Arc<RequestQueue>,
    /// None when running CPU-only (no artifacts available).
    pub executor: Option<Arc<Executor>>,
    pub policy: BatchPolicy,
    pub quality: u8,
    /// Thread count for each `CpuParallel`-lane job (already resolved by
    /// the service: explicit config or machine-default / worker-count).
    pub parallel_workers: usize,
    /// Batch-engine configuration (lane width + fxp precision) applied
    /// to every CPU pipeline this worker builds. Fixed per service, so
    /// it is not part of the cache keys.
    pub engine: EngineConfig,
    pub queue_hist: Arc<SharedHistogram>,
    pub process_hist: Arc<SharedHistogram>,
    /// Worker-side fault injection (chaos testing): seeded panics and
    /// artificial job latency, applied inside the per-job panic guard.
    /// `None` in production — one `Option` check per job.
    pub faults: Option<Arc<FaultInjector>>,
    /// Restart interval (block rows per segment) of the v2 containers
    /// every compress lane emits; 0 = a single segment per plane.
    pub restart_interval: u16,
    /// Shared decode-resilience counters, surfaced through
    /// `ServiceStats` and the serve stats frame.
    pub decode_counters: Arc<DecodeCounters>,
}

/// Decode-resilience counters shared by all workers of a service.
#[derive(Debug, Default)]
pub struct DecodeCounters {
    /// Strict decode jobs that failed with any `DecodeErrorKind`.
    pub strict_failures: AtomicU64,
    /// Salvage decode jobs that found — and tolerated — damage.
    pub salvaged: AtomicU64,
    /// Segments concealed across all salvage decodes.
    pub segments_concealed: AtomicU64,
}

/// Per-worker cache of CPU-lane pipelines, keyed by everything that
/// feeds their construction (quality and worker count are fixed per
/// service today, but they are part of the key so a cache hit can never
/// return a pipeline built with different parameters). Reusing the
/// pipeline reuses its transform tables *and* its batch engine's
/// `BlockScratch` arena across jobs.
#[derive(Default)]
pub struct PipelineCache {
    serial: HashMap<(Variant, u8), CpuPipeline>,
    parallel: HashMap<(Variant, u8, usize), ParallelCpuPipeline>,
    /// Color pipelines keyed by (variant, subsampling, parallel?,
    /// quality, workers).
    color: HashMap<(Variant, Subsampling, bool, u8, usize), ColorPipeline>,
}

impl PipelineCache {
    pub fn new() -> PipelineCache {
        PipelineCache::default()
    }

    fn serial(
        &mut self,
        variant: Variant,
        quality: u8,
        cfg: EngineConfig,
    ) -> &CpuPipeline {
        self.serial
            .entry((variant, quality))
            .or_insert_with(|| CpuPipeline::with_config(variant, quality, cfg))
    }

    fn parallel(
        &mut self,
        variant: Variant,
        quality: u8,
        workers: usize,
        cfg: EngineConfig,
    ) -> &ParallelCpuPipeline {
        self.parallel.entry((variant, quality, workers)).or_insert_with(
            || {
                ParallelCpuPipeline::with_qtable_config(
                    variant,
                    quality,
                    workers,
                    crate::dct::quant::effective_qtable(quality),
                    cfg,
                )
            },
        )
    }

    fn color(
        &mut self,
        variant: Variant,
        quality: u8,
        subsampling: Subsampling,
        parallel: bool,
        workers: usize,
        cfg: EngineConfig,
    ) -> &ColorPipeline {
        self.color
            .entry((variant, subsampling, parallel, quality, workers))
            .or_insert_with(|| {
                if parallel {
                    ColorPipeline::parallel_with(
                        variant,
                        quality,
                        subsampling,
                        workers,
                        cfg,
                    )
                } else {
                    ColorPipeline::new_with(variant, quality, subsampling,
                                            cfg)
                }
            })
    }
}

/// Why the worker loop returned — the supervisor in
/// [`super::service`] keys its respawn decision on this.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunExit {
    /// The queue closed: normal shutdown, do not respawn.
    QueueClosed,
    /// A job panicked. Its waiter was already answered with a
    /// structured [`JobError::WorkerPanic`] and the rest of the batch
    /// was processed; the loop exits so the supervisor can re-enter it
    /// with a fresh [`PipelineCache`] (the old one may have been
    /// mid-mutation when the panic unwound through it).
    JobPanicked,
}

/// Run the worker loop until the queue closes or a job panics.
pub fn run(ctx: &WorkerCtx) -> RunExit {
    let mut cache = PipelineCache::new();
    loop {
        // the head job's lane picks the batch cap, so a max-1 lane (serial
        // CPU by default) never coalesces stragglers
        let Some(batch) = ctx.queue.pop_batch_with(
            |r| ctx.policy.max_for(r.lane),
            ctx.policy.linger,
        ) else {
            return RunExit::QueueClosed;
        };
        // One cached-executable resolve serves the whole same-key batch —
        // the batching win the ablation measures.
        let mut panicked = false;
        for job in batch {
            panicked |= process_job(ctx, &mut cache, job);
        }
        // finish the whole batch first — every popped job must be
        // answered — then hand control back to the supervisor
        if panicked {
            return RunExit::JobPanicked;
        }
    }
}

/// Process one job, always answering its reply channel. Returns `true`
/// when the job panicked (the reply then carries
/// [`JobError::WorkerPanic`]).
fn process_job(
    ctx: &WorkerCtx,
    cache: &mut PipelineCache,
    job: QueuedJob,
) -> bool {
    let queue_ms = job.enqueued.elapsed().as_secs_f64() * 1e3;
    ctx.queue_hist.record_us(queue_ms * 1e3);
    let t0 = Instant::now();
    let lane = resolve_lane(ctx, &job.request);
    let caught = catch_unwind(AssertUnwindSafe(|| {
        if let Some(f) = &ctx.faults {
            if let Some(d) = f.job_latency() {
                std::thread::sleep(d);
            }
            if f.worker_panic() {
                panic!("injected worker fault");
            }
        }
        run_job(ctx, cache, &job.request, lane)
    }));
    let panicked = caught.is_err();
    let result = caught.unwrap_or_else(|payload| {
        Err(anyhow::Error::from(JobError::WorkerPanic {
            detail: panic_message(payload.as_ref()),
        }))
    });
    let process_ms = t0.elapsed().as_secs_f64() * 1e3;
    ctx.process_hist.record_us(process_ms * 1e3);
    // receiver may have given up (dropped handle): ignore send failure
    let _ = job.reply.send(Response {
        id: job.request.id,
        result,
        queue_ms,
        process_ms,
        lane,
    });
    panicked
}

/// Best-effort extraction of a panic payload's message (`&str` and
/// `String` cover `panic!` in practice).
fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Auto routing: GPU when the executor exists and its backend covers the
/// job — for gray jobs an artifact (or stub kind) at the padded shape,
/// for color jobs coverage of all three padded plane shapes (the
/// planar-batch path) — else serial CPU.
fn resolve_lane(ctx: &WorkerCtx, req: &Request) -> Lane {
    match req.lane {
        Lane::Cpu => Lane::Cpu,
        Lane::CpuParallel => Lane::CpuParallel,
        Lane::Gpu => Lane::Gpu,
        // Decode is CPU-only work (entropy decode + IDCT); the GPU lane
        // has no executable for it.
        Lane::Auto if req.kind == RequestKind::Decode => Lane::Cpu,
        Lane::Auto if req.image.is_color() => match &ctx.executor {
            Some(ex)
                if req.kind == RequestKind::Compress
                    && ex.supports_color(
                        req.image.width(),
                        req.image.height(),
                        req.variant.as_str(),
                        req.subsampling,
                    ) =>
            {
                Lane::Gpu
            }
            _ => Lane::Cpu,
        },
        Lane::Auto => match &ctx.executor {
            Some(ex) => {
                let ph = crate::dct::blocks::align8(req.image.height());
                let pw = crate::dct::blocks::align8(req.image.width());
                let kind = match req.kind {
                    RequestKind::Compress => "compress",
                    RequestKind::Histeq => "histeq",
                    RequestKind::Decode => {
                        unreachable!("decode routed to CPU above")
                    }
                };
                let variant = match req.kind {
                    RequestKind::Compress => Some(req.variant.as_str()),
                    RequestKind::Histeq | RequestKind::Decode => None,
                };
                if ex.rt.supports(kind, variant, ph, pw) {
                    Lane::Gpu
                } else {
                    Lane::Cpu
                }
            }
            None => Lane::Cpu,
        },
    }
}

/// Entropy-code + package the payload all gray compress lanes share —
/// fed straight from the fused zigzag output, no planar round-trip.
/// `recon: None` is the recon-free fast path: no PSNR, no image.
fn compress_output(
    original: &GrayImage,
    recon: Option<GrayImage>,
    scanned: &ScanCoefs,
    variant: Variant,
    quality: u8,
    restart_interval: u16,
) -> Result<JobOutput> {
    let bytes = entropy_encode(original, scanned, variant, quality,
                               restart_interval)?;
    Ok(JobOutput {
        psnr_db: recon.as_ref().map(|r| psnr(original, r)),
        image: recon,
        color_image: None,
        compressed_bytes: Some(bytes.len()),
        container: Some(bytes),
        salvage: None,
    })
}

fn run_job(
    ctx: &WorkerCtx,
    cache: &mut PipelineCache,
    req: &Request,
    lane: Lane,
) -> Result<JobOutput> {
    match &req.image {
        JobImage::Gray(img) => run_gray_job(ctx, cache, req, img, lane),
        JobImage::Color(img) => run_color_job(ctx, cache, req, img, lane),
        JobImage::Encoded(bytes) => {
            run_decode_job(ctx, cache, req, bytes, lane)
        }
    }
}

/// Decode a CDC1/CDC3 container back to pixels. Every header field is
/// validated by the codec before any allocation; hostile input comes
/// back as a tagged `Err` the serve layer maps to an error frame.
fn run_decode_job(
    ctx: &WorkerCtx,
    cache: &mut PipelineCache,
    req: &Request,
    bytes: &[u8],
    lane: Lane,
) -> Result<JobOutput> {
    if lane == Lane::Gpu {
        bail!("decode runs on the CPU lanes");
    }
    let parallel = lane == Lane::CpuParallel;
    if color_codec::is_color_container(bytes) {
        let (dec, report) = if req.salvage {
            let (dec, report) = color_codec::decode_salvage(bytes)?;
            account_salvage(ctx, &report);
            (dec, Some(report))
        } else {
            match color_codec::decode(bytes) {
                Ok(dec) => (dec, None),
                Err(e) => {
                    ctx.decode_counters
                        .strict_failures
                        .fetch_add(1, Ordering::Relaxed);
                    return Err(e);
                }
            }
        };
        let variant = crate::codec::tag_variant(dec.header.variant)?;
        let sub = color_codec::tag_subsampling(dec.header.subsampling)?;
        let pipe = cache.color(
            variant,
            dec.header.quality,
            sub,
            parallel,
            ctx.parallel_workers,
            ctx.engine,
        );
        let img = pipe.decode_coefficients(&dec.planes);
        return Ok(JobOutput {
            image: None,
            color_image: Some(img),
            compressed_bytes: None,
            container: None,
            psnr_db: None,
            salvage: report,
        });
    }
    let (dec, report) = if req.salvage {
        let (dec, report) = crate::codec::decoder::decode_salvage(bytes)?;
        account_salvage(ctx, &report);
        (dec, Some(report))
    } else {
        match crate::codec::decoder::decode(bytes) {
            Ok(dec) => (dec, None),
            Err(e) => {
                ctx.decode_counters
                    .strict_failures
                    .fetch_add(1, Ordering::Relaxed);
                return Err(e);
            }
        }
    };
    let h = &dec.header;
    let variant = crate::codec::tag_variant(h.variant)?;
    let (pw, ph) = (h.padded_width as usize, h.padded_height as usize);
    let (w, hh) = (h.width as usize, h.height as usize);
    let recon = if parallel {
        cache
            .parallel(variant, h.quality, ctx.parallel_workers, ctx.engine)
            .decode_coefficients(&dec.qcoef_planar, pw, ph, w, hh)
    } else {
        cache
            .serial(variant, h.quality, ctx.engine)
            .decode_coefficients(&dec.qcoef_planar, pw, ph, w, hh)
    };
    Ok(JobOutput {
        image: Some(recon),
        color_image: None,
        compressed_bytes: None,
        container: None,
        psnr_db: None,
        salvage: report,
    })
}

/// Bump the shared salvage counters for one completed salvage decode.
fn account_salvage(ctx: &WorkerCtx, report: &crate::codec::SalvageReport) {
    if !report.is_clean() {
        ctx.decode_counters.salvaged.fetch_add(1, Ordering::Relaxed);
        ctx.decode_counters
            .segments_concealed
            .fetch_add(report.segments_concealed as u64, Ordering::Relaxed);
    }
}

/// Color jobs: the `color: true` request path. Both CPU lanes run the
/// per-plane [`ColorPipeline`]; the GPU lane consumes the same job as a
/// planar batch (Y/Cb/Cr planes in parallel) through the executor.
fn run_color_job(
    ctx: &WorkerCtx,
    cache: &mut PipelineCache,
    req: &Request,
    img: &ColorImage,
    lane: Lane,
) -> Result<JobOutput> {
    if req.kind != RequestKind::Compress {
        bail!("only compress serves color images");
    }
    // the container header must record the quality the lane actually
    // quantized at: the GPU backend's own quality (the PJRT manifest's;
    // the stub is built at ctx.quality, so they agree there)
    let quality = match (lane, &ctx.executor) {
        (Lane::Gpu, Some(ex)) => ex.rt.quality(),
        _ => ctx.quality,
    };
    let header = color_codec::ColorHeader {
        width: img.width as u32,
        height: img.height as u32,
        quality,
        variant: variant_tag(req.variant),
        subsampling: color_codec::subsampling_tag(req.subsampling),
    };
    if lane == Lane::Gpu {
        let ex = ctx
            .executor
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("no GPU lane configured"))?;
        let out =
            ex.compress_color(img, req.variant, req.subsampling)?;
        let bytes = color_codec::encode_scanned_v2(
            &header,
            &out.scanned,
            ctx.restart_interval,
        )?;
        return Ok(JobOutput {
            psnr_db: Some(psnr_color(img, &out.recon).weighted),
            image: Some(out.recon_y),
            color_image: Some(out.recon),
            compressed_bytes: Some(bytes.len()),
            container: Some(bytes),
            salvage: None,
        });
    }
    let pipe = cache.color(
        req.variant,
        ctx.quality,
        req.subsampling,
        lane == Lane::CpuParallel,
        ctx.parallel_workers,
        ctx.engine,
    );
    if !req.want_psnr {
        // recon-free fast path: zigzag coefficients straight to the
        // entropy coder, no IDCT, no upsample/reassemble
        let scanned = pipe.analyze_scanned(img);
        let bytes = color_codec::encode_scanned_v2(
            &header,
            &scanned,
            ctx.restart_interval,
        )?;
        return Ok(JobOutput {
            psnr_db: None,
            image: None,
            color_image: None,
            compressed_bytes: Some(bytes.len()),
            container: Some(bytes),
            salvage: None,
        });
    }
    let out = pipe.compress_fused(img);
    let bytes = color_codec::encode_scanned_v2(
        &header,
        &out.scanned,
        ctx.restart_interval,
    )?;
    Ok(JobOutput {
        psnr_db: Some(psnr_color(img, &out.recon).weighted),
        image: Some(out.recon_y),
        color_image: Some(out.recon),
        compressed_bytes: Some(bytes.len()),
        container: Some(bytes),
        salvage: None,
    })
}

fn run_gray_job(
    ctx: &WorkerCtx,
    cache: &mut PipelineCache,
    req: &Request,
    img: &GrayImage,
    lane: Lane,
) -> Result<JobOutput> {
    match (req.kind, lane) {
        (RequestKind::Compress, Lane::Gpu) => {
            let ex = ctx
                .executor
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("no GPU lane configured"))?;
            let out = ex.compress(img, req.variant.as_str())?;
            // header records the backend's quantization quality, which
            // on PJRT is the manifest's, not necessarily ctx.quality
            // (the backend computes the recon regardless, so want_psnr
            // costs nothing to honor here)
            compress_output(
                img,
                Some(out.recon),
                &out.scanned,
                req.variant,
                ex.rt.quality(),
                ctx.restart_interval,
            )
        }
        (RequestKind::Compress, Lane::CpuParallel) => {
            let pipe = cache.parallel(
                req.variant,
                ctx.quality,
                ctx.parallel_workers,
                ctx.engine,
            );
            if req.want_psnr {
                let out = pipe.compress_fused(img);
                compress_output(
                    img,
                    Some(out.recon),
                    &out.scanned,
                    req.variant,
                    ctx.quality,
                    ctx.restart_interval,
                )
            } else {
                let scanned = pipe.analyze_scanned(img);
                compress_output(
                    img,
                    None,
                    &scanned,
                    req.variant,
                    ctx.quality,
                    ctx.restart_interval,
                )
            }
        }
        (RequestKind::Compress, _) => {
            let pipe = cache.serial(req.variant, ctx.quality, ctx.engine);
            if req.want_psnr {
                let out = pipe.compress_fused(img);
                compress_output(
                    img,
                    Some(out.recon),
                    &out.scanned,
                    req.variant,
                    ctx.quality,
                    ctx.restart_interval,
                )
            } else {
                let scanned = pipe.analyze_scanned(img);
                compress_output(
                    img,
                    None,
                    &scanned,
                    req.variant,
                    ctx.quality,
                    ctx.restart_interval,
                )
            }
        }
        (RequestKind::Histeq, Lane::Gpu) => {
            let ex = ctx
                .executor
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("no GPU lane configured"))?;
            let (out, _ms) = ex.histeq(img)?;
            Ok(JobOutput {
                image: Some(out),
                color_image: None,
                compressed_bytes: None,
                container: None,
                psnr_db: None,
                salvage: None,
            })
        }
        (RequestKind::Histeq, _) => Ok(JobOutput {
            image: Some(histeq::histeq(img)),
            color_image: None,
            compressed_bytes: None,
            container: None,
            psnr_db: None,
            salvage: None,
        }),
        (RequestKind::Decode, _) => {
            bail!("decode jobs carry an encoded payload, not pixels")
        }
    }
}

fn entropy_encode(
    original: &GrayImage,
    scanned: &ScanCoefs,
    variant: Variant,
    quality: u8,
    restart_interval: u16,
) -> Result<Vec<u8>> {
    let header = Header {
        width: original.width as u32,
        height: original.height as u32,
        padded_width: scanned.padded_width as u32,
        padded_height: scanned.padded_height as u32,
        quality,
        variant: variant_tag(variant),
    };
    encoder::encode_scanned_v2(&header, scanned, restart_interval)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Backpressure;
    use crate::image::synthetic;

    fn cpu_ctx(capacity: usize) -> WorkerCtx {
        WorkerCtx {
            queue: Arc::new(RequestQueue::new(
                capacity,
                Backpressure::Block,
            )),
            executor: None,
            policy: BatchPolicy::default(),
            quality: 50,
            parallel_workers: 2,
            engine: EngineConfig::default(),
            queue_hist: Arc::new(SharedHistogram::default()),
            process_hist: Arc::new(SharedHistogram::default()),
            faults: None,
            restart_interval: crate::codec::DEFAULT_RESTART_INTERVAL,
            decode_counters: Arc::new(DecodeCounters::default()),
        }
    }

    #[test]
    fn pipeline_cache_builds_one_pipeline_per_key() {
        let mut cache = PipelineCache::new();
        let cfg = EngineConfig::default();
        cache.serial(Variant::Dct, 50, cfg);
        cache.serial(Variant::Dct, 50, cfg);
        cache.serial(Variant::Cordic, 50, cfg);
        cache.parallel(Variant::Dct, 50, 2, cfg);
        cache.parallel(Variant::Dct, 50, 2, cfg);
        cache.color(Variant::Dct, 50, Subsampling::S420, false, 2, cfg);
        cache.color(Variant::Dct, 50, Subsampling::S420, true, 2, cfg);
        cache.color(Variant::Dct, 50, Subsampling::S420, true, 2, cfg);
        assert_eq!(cache.serial.len(), 2);
        assert_eq!(cache.parallel.len(), 1);
        assert_eq!(cache.color.len(), 2);
        // construction parameters are part of the key: a different
        // quality must never reuse a cached pipeline
        cache.serial(Variant::Dct, 90, cfg);
        assert_eq!(cache.serial.len(), 3);
        assert_eq!(cache.serial(Variant::Dct, 90, cfg).quality, 90);
    }

    #[test]
    fn cpu_worker_processes_compress() {
        let ctx = Arc::new(cpu_ctx(8));
        let img = synthetic::lena_like(32, 32, 1);
        let handle = ctx
            .queue
            .submit(Request::compress(7, img.clone(), Variant::Dct,
                                      Lane::Cpu))
            .unwrap();
        let ctx2 = Arc::clone(&ctx);
        let t = std::thread::spawn(move || run(&ctx2));
        let resp = handle.wait();
        ctx.queue.close();
        t.join().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.lane, Lane::Cpu);
        let out = resp.result.unwrap();
        assert_eq!(out.image.as_ref().unwrap().width, 32);
        assert!(out.psnr_db.unwrap() > 28.0);
        assert!(out.compressed_bytes.unwrap() > 0);
        assert_eq!(
            out.container.unwrap().len(),
            out.compressed_bytes.unwrap()
        );
    }

    #[test]
    fn parallel_lane_matches_serial_lane() {
        let ctx = Arc::new(cpu_ctx(8));
        let img = synthetic::lena_like(48, 40, 2);
        let h_ser = ctx
            .queue
            .submit(Request::compress(1, img.clone(), Variant::Cordic,
                                      Lane::Cpu))
            .unwrap();
        let h_par = ctx
            .queue
            .submit(Request::compress(2, img.clone(), Variant::Cordic,
                                      Lane::CpuParallel))
            .unwrap();
        let ctx2 = Arc::clone(&ctx);
        let t = std::thread::spawn(move || run(&ctx2));
        let r_ser = h_ser.wait();
        let r_par = h_par.wait();
        ctx.queue.close();
        t.join().unwrap();
        assert_eq!(r_par.lane, Lane::CpuParallel);
        let o_ser = r_ser.result.unwrap();
        let o_par = r_par.result.unwrap();
        // bit-identical pipeline => identical reconstruction and size
        assert_eq!(o_par.image, o_ser.image);
        assert_eq!(o_par.compressed_bytes, o_ser.compressed_bytes);
        assert_eq!(o_par.psnr_db, o_ser.psnr_db);
    }

    #[test]
    fn auto_without_executor_routes_cpu() {
        let ctx = cpu_ctx(4);
        let req = Request::compress(
            1,
            synthetic::lena_like(16, 16, 2),
            Variant::Dct,
            Lane::Auto,
        );
        assert_eq!(resolve_lane(&ctx, &req), Lane::Cpu);
        let par = Request::compress(
            2,
            synthetic::lena_like(16, 16, 2),
            Variant::Dct,
            Lane::CpuParallel,
        );
        assert_eq!(resolve_lane(&ctx, &par), Lane::CpuParallel);
    }

    #[test]
    fn histeq_job_works() {
        let ctx = Arc::new(cpu_ctx(4));
        let img = synthetic::cablecar_like(24, 24, 3);
        let handle = ctx
            .queue
            .submit(Request {
                id: 1,
                kind: RequestKind::Histeq,
                image: JobImage::Gray(img.clone()),
                variant: Variant::Dct,
                lane: Lane::Cpu,
                subsampling: crate::image::ycbcr::Subsampling::S420,
                want_psnr: true,
                salvage: false,
            })
            .unwrap();
        let ctx2 = Arc::clone(&ctx);
        let t = std::thread::spawn(move || run(&ctx2));
        let resp = handle.wait();
        ctx.queue.close();
        t.join().unwrap();
        let out = resp.result.unwrap();
        assert_eq!(out.image.unwrap(), histeq::histeq(&img));
        assert!(out.compressed_bytes.is_none());
    }

    #[test]
    fn color_job_runs_on_both_cpu_lanes() {
        use crate::image::ycbcr::Subsampling;
        let ctx = Arc::new(cpu_ctx(8));
        let img = synthetic::lena_like_rgb(40, 32, 4);
        let h_ser = ctx
            .queue
            .submit(Request::compress_color(
                1,
                img.clone(),
                Variant::Dct,
                Lane::Cpu,
                Subsampling::S420,
            ))
            .unwrap();
        let h_par = ctx
            .queue
            .submit(Request::compress_color(
                2,
                img.clone(),
                Variant::Dct,
                Lane::CpuParallel,
                Subsampling::S420,
            ))
            .unwrap();
        let ctx2 = Arc::clone(&ctx);
        let t = std::thread::spawn(move || run(&ctx2));
        let r_ser = h_ser.wait();
        let r_par = h_par.wait();
        ctx.queue.close();
        t.join().unwrap();
        let o_ser = r_ser.result.unwrap();
        let o_par = r_par.result.unwrap();
        // per-plane pipelines are bit-identical across CPU lanes
        let ser_rgb = o_ser.color_image.as_ref().unwrap();
        let par_rgb = o_par.color_image.as_ref().unwrap();
        assert_eq!(ser_rgb, par_rgb);
        assert_eq!(o_ser.image, o_par.image); // luma plane
        assert_eq!(o_ser.compressed_bytes, o_par.compressed_bytes);
        assert!(o_ser.psnr_db.unwrap() > 25.0);
        assert_eq!((ser_rgb.width, ser_rgb.height), (40, 32));
    }

    #[test]
    fn decode_job_roundtrips_compress_output() {
        let ctx = Arc::new(cpu_ctx(8));
        let img = synthetic::lena_like(32, 32, 1);
        let h = ctx
            .queue
            .submit(Request::compress(1, img.clone(), Variant::Dct,
                                      Lane::Cpu))
            .unwrap();
        let ctx2 = Arc::clone(&ctx);
        let t = std::thread::spawn(move || run(&ctx2));
        let container = h.wait().result.unwrap().container.unwrap();
        let h2 = ctx
            .queue
            .submit(Request::decode(2, container, Lane::Auto))
            .unwrap();
        let resp = h2.wait();
        ctx.queue.close();
        t.join().unwrap();
        assert_eq!(resp.lane, Lane::Cpu, "decode auto-routes to CPU");
        let out = resp.result.unwrap();
        let recon = out.image.unwrap();
        assert_eq!((recon.width, recon.height), (32, 32));
        assert!(crate::metrics::psnr(&img, &recon) > 28.0);
    }

    #[test]
    fn salvage_decode_job_conceals_damage_and_counts_it() {
        let ctx = Arc::new(cpu_ctx(8));
        let img = synthetic::lena_like(48, 48, 9);
        let h = ctx
            .queue
            .submit(Request::compress(1, img, Variant::Dct, Lane::Cpu))
            .unwrap();
        let ctx2 = Arc::clone(&ctx);
        let t = std::thread::spawn(move || run(&ctx2));
        let container = h.wait().result.unwrap().container.unwrap();
        assert!(crate::codec::is_v2_container(&container));
        // flip a bit deep in the segment payloads
        let mut bad = container.clone();
        let n = bad.len();
        bad[n - n / 8] ^= 0x10;
        let h_strict = ctx
            .queue
            .submit(Request::decode(2, bad.clone(), Lane::Cpu))
            .unwrap();
        let h_salv = ctx
            .queue
            .submit(Request::decode_salvage(3, bad, Lane::Cpu))
            .unwrap();
        let h_clean = ctx
            .queue
            .submit(Request::decode_salvage(4, container, Lane::Cpu))
            .unwrap();
        let strict = h_strict.wait();
        let salv = h_salv.wait();
        let clean = h_clean.wait();
        ctx.queue.close();
        t.join().unwrap();
        assert!(strict.result.is_err(), "strict decode must fail fast");
        let out = salv.result.unwrap();
        let report = out.salvage.unwrap();
        assert_eq!(report.segments_damaged, 1);
        assert_eq!(report.segments_concealed, 1);
        assert!(out.image.is_some());
        // undamaged container: clean report, no salvaged counter bump
        let clean_report = clean.result.unwrap().salvage.unwrap();
        assert!(clean_report.is_clean());
        assert!(clean_report.segments_total > 1);
        let c = &ctx.decode_counters;
        assert_eq!(c.strict_failures.load(Ordering::Relaxed), 1);
        assert_eq!(c.salvaged.load(Ordering::Relaxed), 1);
        assert_eq!(c.segments_concealed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn hostile_container_is_job_error_not_panic() {
        use crate::codec::{classify_decode_error, DecodeErrorKind};
        let ctx = Arc::new(cpu_ctx(4));
        // real magic, hostile header: tiny image, huge padded grid
        let mut evil = Vec::new();
        Header {
            width: 1,
            height: 1,
            padded_width: 4096,
            padded_height: 4096,
            quality: 50,
            variant: 0,
        }
        .write(&mut evil);
        evil.extend_from_slice(&[0u8; 64]);
        let h = ctx
            .queue
            .submit(Request::decode(1, evil, Lane::Cpu))
            .unwrap();
        let ctx2 = Arc::clone(&ctx);
        let t = std::thread::spawn(move || run(&ctx2));
        let resp = h.wait();
        ctx.queue.close();
        t.join().unwrap();
        let err = resp.result.unwrap_err();
        assert_eq!(
            classify_decode_error(&err),
            Some(DecodeErrorKind::BadHeader),
            "{err:#}"
        );
    }

    #[test]
    fn no_psnr_fast_path_skips_recon_same_container() {
        let ctx = Arc::new(cpu_ctx(8));
        let img = synthetic::lena_like(40, 24, 3);
        let h_full = ctx
            .queue
            .submit(Request::compress(1, img.clone(), Variant::Cordic,
                                      Lane::Cpu))
            .unwrap();
        let h_fast = ctx
            .queue
            .submit(
                Request::compress(2, img, Variant::Cordic, Lane::Cpu)
                    .no_psnr(),
            )
            .unwrap();
        let ctx2 = Arc::clone(&ctx);
        let t = std::thread::spawn(move || run(&ctx2));
        let full = h_full.wait().result.unwrap();
        let fast = h_fast.wait().result.unwrap();
        ctx.queue.close();
        t.join().unwrap();
        assert!(fast.image.is_none());
        assert!(fast.psnr_db.is_none());
        assert!(full.image.is_some() && full.psnr_db.is_some());
        // the fast path emits byte-identical container output
        assert_eq!(fast.container, full.container);
    }

    #[test]
    fn color_auto_routes_to_cpu_and_gpu_rejected() {
        use crate::image::ycbcr::Subsampling;
        let ctx = cpu_ctx(4);
        let img = synthetic::lena_like_rgb(16, 16, 1);
        let auto = Request::compress_color(
            1,
            img.clone(),
            Variant::Dct,
            Lane::Auto,
            Subsampling::S444,
        );
        assert_eq!(resolve_lane(&ctx, &auto), Lane::Cpu);
        let gpu = Request::compress_color(
            2,
            img,
            Variant::Dct,
            Lane::Gpu,
            Subsampling::S444,
        );
        let mut cache = PipelineCache::new();
        assert!(run_job(&ctx, &mut cache, &gpu, Lane::Gpu).is_err());
    }

    #[test]
    fn queue_close_exits_with_queue_closed() {
        let ctx = cpu_ctx(2);
        ctx.queue.close();
        assert_eq!(run(&ctx), RunExit::QueueClosed);
    }

    #[test]
    fn injected_panic_answers_structured_error_and_exits() {
        use crate::coordinator::JOB_PANIC_TAG;
        use crate::faults::{FaultInjector, FaultPlan};

        let plan = FaultPlan::parse("seed=1,panic=1.0").unwrap();
        let mut ctx = cpu_ctx(4);
        ctx.faults = Some(Arc::new(FaultInjector::new(plan)));
        let ctx = Arc::new(ctx);
        let img = synthetic::lena_like(16, 16, 1);
        let handle = ctx
            .queue
            .submit(Request::compress(1, img, Variant::Dct, Lane::Cpu))
            .unwrap();
        let ctx2 = Arc::clone(&ctx);
        let t = std::thread::spawn(move || run(&ctx2));
        // the panicked job still answers its waiter, structured
        let resp = handle.wait();
        let err = resp.result.unwrap_err();
        let chain = format!("{err:#}");
        assert!(chain.contains(JOB_PANIC_TAG), "untagged: {chain}");
        assert!(chain.contains("injected worker fault"), "{chain}");
        // and the loop hands control back for a supervised respawn
        assert_eq!(t.join().unwrap(), RunExit::JobPanicked);
        ctx.queue.close();
    }
}
