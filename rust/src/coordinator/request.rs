//! Request/response types and the bounded request queue (the
//! backpressure boundary of the service).

use std::collections::VecDeque;
use std::fmt;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::codec::SalvageReport;
use crate::dct::Variant;
use crate::image::color::ColorImage;
use crate::image::ycbcr::Subsampling;
use crate::image::GrayImage;

/// Which execution lane a request targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Lane {
    /// Serial scalar Rust (the paper's "CPU serial code").
    Cpu,
    /// Block-parallel Rust over a scoped thread pool
    /// (`dct::parallel::ParallelCpuPipeline`).
    CpuParallel,
    /// The runtime backend (the paper's CUDA lane): AOT PJRT
    /// executables, or the host-side stub backend when configured.
    /// Accepts gray and — since the planar-batch rework — color jobs.
    Gpu,
    /// Router decides: GPU when the backend covers the job — for gray,
    /// the artifact (or stub kind) at the padded shape; for color, all
    /// three padded plane shapes — else serial CPU.
    Auto,
}

impl Lane {
    pub fn parse(s: &str) -> Option<Lane> {
        match s.to_ascii_lowercase().as_str() {
            "cpu" => Some(Lane::Cpu),
            "cpu-parallel" | "cpu_parallel" | "cpupar" | "parallel" => {
                Some(Lane::CpuParallel)
            }
            "gpu" | "pjrt" | "xla" => Some(Lane::Gpu),
            "auto" => Some(Lane::Auto),
            _ => None,
        }
    }
}

/// What to do with the image.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RequestKind {
    /// Full pipeline; response carries reconstruction + entropy-coded size.
    Compress,
    /// Histogram equalization (the Tables 1-2 caption workload).
    Histeq,
    /// Decode a CDC1/CDC3 container back to pixels (the serve path's
    /// read side). Runs on the CPU lanes; header validation errors come
    /// back as structured job failures, never worker panics.
    Decode,
}

/// Pixel payload of a request: the grayscale paper workload, the color
/// (YCbCr) extension, or — for [`RequestKind::Decode`] — an encoded
/// container whose pixels do not exist yet.
#[derive(Clone, Debug)]
pub enum JobImage {
    Gray(GrayImage),
    Color(ColorImage),
    /// An untrusted CDC1/CDC3 byte stream to decode. Dimensions report 0
    /// (the header is not trusted before validation), so encoded jobs
    /// never share a batch key with pixel jobs.
    Encoded(Vec<u8>),
}

impl JobImage {
    pub fn width(&self) -> usize {
        match self {
            JobImage::Gray(g) => g.width,
            JobImage::Color(c) => c.width,
            JobImage::Encoded(_) => 0,
        }
    }

    pub fn height(&self) -> usize {
        match self {
            JobImage::Gray(g) => g.height,
            JobImage::Color(c) => c.height,
            JobImage::Encoded(_) => 0,
        }
    }

    pub fn is_color(&self) -> bool {
        match self {
            JobImage::Color(_) => true,
            JobImage::Gray(_) => false,
            JobImage::Encoded(b) => {
                crate::codec::color::is_color_container(b)
            }
        }
    }
}

/// One job submitted to the service.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub kind: RequestKind,
    pub image: JobImage,
    pub variant: Variant,
    pub lane: Lane,
    /// Chroma subsampling for color jobs (ignored for grayscale).
    pub subsampling: Subsampling,
    /// Compute PSNR (and with it the reconstruction) for compress jobs.
    /// `false` runs the recon-free fused path — serve traffic that only
    /// wants the container bytes never pays for the decoder half.
    pub want_psnr: bool,
    /// For [`RequestKind::Decode`]: tolerate damage via the salvage
    /// decoder (per-segment CRC re-sync + concealment on v2 streams)
    /// instead of failing fast. The response's [`JobOutput::salvage`]
    /// then carries the damage report.
    pub salvage: bool,
}

impl Request {
    pub fn compress(id: u64, image: GrayImage, variant: Variant,
                    lane: Lane) -> Request {
        Request {
            id,
            kind: RequestKind::Compress,
            image: JobImage::Gray(image),
            variant,
            lane,
            subsampling: Subsampling::S420,
            want_psnr: true,
            salvage: false,
        }
    }

    /// A color compression job (the `color: true` request shape; served
    /// by every lane — the GPU lane consumes it as a planar batch).
    pub fn compress_color(
        id: u64,
        image: ColorImage,
        variant: Variant,
        lane: Lane,
        subsampling: Subsampling,
    ) -> Request {
        Request {
            id,
            kind: RequestKind::Compress,
            image: JobImage::Color(image),
            variant,
            lane,
            subsampling,
            want_psnr: true,
            salvage: false,
        }
    }

    /// A container-decode job. The variant recorded here is a
    /// placeholder — the (validated) container header carries the real
    /// one.
    pub fn decode(id: u64, container: Vec<u8>, lane: Lane) -> Request {
        Request {
            id,
            kind: RequestKind::Decode,
            image: JobImage::Encoded(container),
            variant: Variant::Dct,
            lane,
            subsampling: Subsampling::S420,
            want_psnr: false,
            salvage: false,
        }
    }

    /// Builder-style switch to damage-tolerant decoding: strict-decode
    /// failures on v2 containers become concealed regions plus a
    /// [`SalvageReport`] instead of errors.
    pub fn with_salvage(mut self) -> Request {
        self.salvage = true;
        self
    }

    /// A damage-tolerant container-decode job (see [`Request::decode`]).
    pub fn decode_salvage(
        id: u64,
        container: Vec<u8>,
        lane: Lane,
    ) -> Request {
        Request::decode(id, container, lane).with_salvage()
    }

    /// A histogram-equalization job (the Tables 1-2 caption workload).
    pub fn histeq(id: u64, image: GrayImage, lane: Lane) -> Request {
        Request {
            id,
            kind: RequestKind::Histeq,
            image: JobImage::Gray(image),
            variant: Variant::Dct,
            lane,
            subsampling: Subsampling::S420,
            want_psnr: false,
            salvage: false,
        }
    }

    /// Builder-style switch to the recon-free fast path (no PSNR, no
    /// reconstructed image in the output).
    pub fn no_psnr(mut self) -> Request {
        self.want_psnr = false;
        self
    }

    /// Batching key: jobs with equal keys share an executable.
    #[allow(clippy::type_complexity)]
    pub fn batch_key(
        &self,
    ) -> (RequestKind, usize, usize, Variant, Lane, bool, Subsampling) {
        (
            self.kind,
            self.image.width(),
            self.image.height(),
            self.variant,
            self.lane,
            self.image.is_color(),
            self.subsampling,
        )
    }
}

/// Completed job.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub result: Result<JobOutput>,
    /// Queue wait (submit -> worker pickup), ms.
    pub queue_ms: f64,
    /// Processing time on the lane, ms.
    pub process_ms: f64,
    /// Which lane actually ran it (Auto resolves here).
    pub lane: Lane,
}

/// Tag prefix [`JobError::WorkerPanic`] renders into error chains so
/// the serve layer can classify panics without downcasting (the
/// vendored `anyhow` flattens errors to a message chain; this mirrors
/// the codec's `[decode:*]` tagging idiom).
pub const JOB_PANIC_TAG: &str = "[job:panic]";

/// Structured job-failure classes that cross the worker boundary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobError {
    /// The job panicked inside a worker. The supervisor respawns the
    /// worker loop (with a fresh pipeline cache) and the reply still
    /// arrives — a panicking job never poisons the queue or strands
    /// its waiter.
    WorkerPanic {
        /// The panic payload's message, when it carried one.
        detail: String,
    },
}

impl fmt::Display for JobError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JobError::WorkerPanic { detail } => {
                write!(f, "{JOB_PANIC_TAG} worker panicked: {detail}")
            }
        }
    }
}

impl std::error::Error for JobError {}

/// Successful output payload.
#[derive(Debug)]
pub struct JobOutput {
    /// Grayscale result; for color jobs this is the reconstructed
    /// full-resolution luma plane. `None` on the recon-free fast path
    /// (`want_psnr: false`) and for color decode jobs.
    pub image: Option<GrayImage>,
    /// Reconstructed RGB image (color Compress/Decode only).
    pub color_image: Option<ColorImage>,
    /// Entropy-coded size in bytes (Compress only).
    pub compressed_bytes: Option<usize>,
    /// The container bytes themselves (Compress jobs; what the serve
    /// layer ships back to the client).
    pub container: Option<Vec<u8>>,
    /// PSNR vs the input (Compress only; luma-weighted for color).
    pub psnr_db: Option<f64>,
    /// Damage report for salvage-decode jobs (`None` for everything
    /// else, including strict decodes).
    pub salvage: Option<SalvageReport>,
}

/// In-flight job: wait for its response.
pub struct JobHandle {
    pub id: u64,
    rx: mpsc::Receiver<Response>,
}

impl JobHandle {
    pub fn wait(self) -> Response {
        self.rx
            .recv()
            .unwrap_or_else(|_| panic!("worker dropped job {}", self.id))
    }

    pub fn wait_timeout(self, d: Duration) -> Option<Response> {
        self.rx.recv_timeout(d).ok()
    }
}

/// Queue-full policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backpressure {
    /// submit() blocks until space frees up.
    Block,
    /// submit() returns an error immediately.
    Reject,
}

pub(crate) struct QueuedJob {
    pub request: Request,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Response>,
}

/// Bounded MPMC queue with condvar wakeups and close semantics.
pub struct RequestQueue {
    inner: Mutex<QueueInner>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
    policy: Backpressure,
}

struct QueueInner {
    jobs: VecDeque<QueuedJob>,
    closed: bool,
}

impl RequestQueue {
    pub fn new(capacity: usize, policy: Backpressure) -> RequestQueue {
        assert!(capacity >= 1);
        RequestQueue {
            inner: Mutex::new(QueueInner {
                jobs: VecDeque::new(),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
            policy,
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Submit a request; returns a handle to await the response.
    pub fn submit(&self, request: Request) -> Result<JobHandle> {
        let (tx, rx) = mpsc::channel();
        let id = request.id;
        self.submit_with_reply(request, tx)?;
        Ok(JobHandle { id, rx })
    }

    /// Submit a request whose response goes to a caller-supplied sender.
    /// Many in-flight jobs can share one channel, so a single consumer
    /// observes completions in completion order — the primitive under
    /// the serve layer's pipelined (v2) connections.
    pub fn submit_with_reply(
        &self,
        request: Request,
        reply: mpsc::Sender<Response>,
    ) -> Result<()> {
        let job = QueuedJob {
            request,
            enqueued: Instant::now(),
            reply,
        };
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            bail!("queue closed");
        }
        while inner.jobs.len() >= self.capacity {
            match self.policy {
                Backpressure::Reject => {
                    bail!(
                        "queue full ({} jobs): backpressure",
                        self.capacity
                    )
                }
                Backpressure::Block => {
                    inner = self.not_full.wait(inner).unwrap();
                    if inner.closed {
                        bail!("queue closed");
                    }
                }
            }
        }
        inner.jobs.push_back(job);
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop of up to `max` same-key jobs (FIFO head defines the
    /// key). Convenience wrapper over [`RequestQueue::pop_batch_with`]
    /// with a lane-independent cap.
    pub(crate) fn pop_batch(&self, max: usize, linger: Duration)
                            -> Option<Vec<QueuedJob>> {
        self.pop_batch_with(|_| max, linger)
    }

    /// Blocking pop of jobs sharing one batch key (FIFO head defines the
    /// key; non-matching jobs stay queued). The per-batch cap comes from
    /// `max_for(head_request)` so each lane's policy applies — the worker
    /// passes `BatchPolicy::max_for(lane)` here.
    ///
    /// Edge-case contract (exercised by the batcher tests):
    /// * `max_for` of 1 bypasses straggler coalescing entirely — the head
    ///   job returns alone, immediately, even if same-key jobs are queued
    ///   behind it and a linger is configured.
    /// * `linger == Duration::ZERO` never sleeps: whatever is contiguously
    ///   queued is taken, nothing is waited for (and no deadline clock is
    ///   read).
    ///
    /// Returns None when the queue is closed and drained.
    pub(crate) fn pop_batch_with<F>(&self, max_for: F, linger: Duration)
                                    -> Option<Vec<QueuedJob>>
    where
        F: Fn(&Request) -> usize,
    {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if !inner.jobs.is_empty() {
                break;
            }
            if inner.closed {
                return None;
            }
            inner = self.not_empty.wait(inner).unwrap();
        }
        let head = inner.jobs.pop_front().unwrap();
        let key = head.request.batch_key();
        let max = max_for(&head.request).max(1);
        let mut batch = vec![head];
        // max == 1: no coalescing at all — return the head job alone.
        if max > 1 {
            // lazily initialized so a zero linger never reads the clock
            let mut deadline: Option<Instant> = None;
            loop {
                // take contiguous same-key jobs from the head
                while batch.len() < max {
                    match inner.jobs.front() {
                        Some(j) if j.request.batch_key() == key => {
                            batch.push(inner.jobs.pop_front().unwrap());
                        }
                        _ => break,
                    }
                }
                if batch.len() >= max || inner.closed || linger.is_zero() {
                    break;
                }
                // a non-matching job at the head also ends the batch
                if !inner.jobs.is_empty() {
                    break;
                }
                let now = Instant::now();
                let dl = *deadline.get_or_insert_with(|| now + linger);
                if now >= dl {
                    break;
                }
                // The pops above freed capacity: release blocked
                // producers *before* sleeping, or a full `Block`-policy
                // queue deadlocks the linger against the very producer
                // whose job it is waiting for (it would only wake at the
                // deadline).
                self.not_full.notify_all();
                let (next, timeout) = self
                    .not_empty
                    .wait_timeout(inner, dl - now)
                    .unwrap();
                inner = next;
                if timeout.timed_out() {
                    break;
                }
            }
        }
        // A linger woken by a non-matching job consumed that job's
        // `not_empty` notification without taking the job. Hand the
        // wakeup to an idle worker, or the job sits queued until the
        // next unrelated pop.
        let leftover = !inner.jobs.is_empty();
        drop(inner);
        self.not_full.notify_all();
        if leftover {
            self.not_empty.notify_one();
        }
        Some(batch)
    }

    /// Close the queue: submits fail, workers drain then exit.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synthetic;

    fn req(id: u64, w: usize) -> Request {
        Request::compress(
            id,
            synthetic::lena_like(w, 16, id),
            Variant::Dct,
            Lane::Cpu,
        )
    }

    #[test]
    fn fifo_order_within_key() {
        let q = RequestQueue::new(16, Backpressure::Reject);
        let _h1 = q.submit(req(1, 16)).unwrap();
        let _h2 = q.submit(req(2, 16)).unwrap();
        let batch = q.pop_batch(8, Duration::ZERO).unwrap();
        assert_eq!(
            batch.iter().map(|j| j.request.id).collect::<Vec<_>>(),
            vec![1, 2]
        );
    }

    #[test]
    fn batch_splits_on_key_change() {
        let q = RequestQueue::new(16, Backpressure::Reject);
        let _hs: Vec<_> = [req(1, 16), req(2, 16), req(3, 24), req(4, 16)]
            .into_iter()
            .map(|r| q.submit(r).unwrap())
            .collect();
        let b1 = q.pop_batch(8, Duration::ZERO).unwrap();
        assert_eq!(b1.len(), 2); // ids 1,2 (16-wide)
        let b2 = q.pop_batch(8, Duration::ZERO).unwrap();
        assert_eq!(b2.len(), 1); // id 3 (24-wide)
        let b3 = q.pop_batch(8, Duration::ZERO).unwrap();
        assert_eq!(b3[0].request.id, 4);
    }

    #[test]
    fn max_batch_respected() {
        let q = RequestQueue::new(32, Backpressure::Reject);
        for i in 0..10 {
            let _ = q.submit(req(i, 16)).unwrap();
        }
        let b = q.pop_batch(4, Duration::ZERO).unwrap();
        assert_eq!(b.len(), 4);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn reject_backpressure() {
        let q = RequestQueue::new(2, Backpressure::Reject);
        let _a = q.submit(req(1, 16)).unwrap();
        let _b = q.submit(req(2, 16)).unwrap();
        assert!(q.submit(req(3, 16)).is_err());
    }

    #[test]
    fn block_backpressure_unblocks_on_pop() {
        use std::sync::Arc;
        let q = Arc::new(RequestQueue::new(1, Backpressure::Block));
        let _a = q.submit(req(1, 16)).unwrap();
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || {
            // blocks until main thread pops
            q2.submit(req(2, 16)).unwrap();
        });
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(q.len(), 1, "submitter still blocked");
        let _ = q.pop_batch(1, Duration::ZERO).unwrap();
        t.join().unwrap();
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn close_drains_then_none() {
        let q = RequestQueue::new(4, Backpressure::Reject);
        let _h = q.submit(req(1, 16)).unwrap();
        q.close();
        assert!(q.submit(req(2, 16)).is_err());
        assert!(q.pop_batch(4, Duration::ZERO).is_some());
        assert!(q.pop_batch(4, Duration::ZERO).is_none());
    }

    #[test]
    fn zero_linger_never_sleeps() {
        let q = RequestQueue::new(16, Backpressure::Reject);
        let _h = q.submit(req(1, 16)).unwrap();
        let t0 = std::time::Instant::now();
        let b = q.pop_batch(8, Duration::ZERO).unwrap();
        assert_eq!(b.len(), 1);
        assert!(
            t0.elapsed() < Duration::from_millis(50),
            "zero linger slept {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn max_one_bypasses_coalescing() {
        // two same-key jobs queued, a long linger configured: max 1 must
        // return the head alone, immediately.
        let q = RequestQueue::new(16, Backpressure::Reject);
        let _h1 = q.submit(req(1, 16)).unwrap();
        let _h2 = q.submit(req(2, 16)).unwrap();
        let t0 = std::time::Instant::now();
        let b = q.pop_batch(1, Duration::from_secs(5)).unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].request.id, 1);
        assert!(
            t0.elapsed() < Duration::from_millis(100),
            "max=1 lingered {:?}",
            t0.elapsed()
        );
        assert_eq!(q.len(), 1, "second job stays queued");
    }

    #[test]
    fn per_request_max_applies_to_head_lane() {
        // head job's lane decides the cap: Cpu head capped at 1 leaves the
        // rest queued even though the global pop could take 8.
        let q = RequestQueue::new(16, Backpressure::Reject);
        for id in 1..=4 {
            let _ = q.submit(req(id, 16)).unwrap();
        }
        let cap = |r: &Request| match r.lane {
            Lane::Cpu => 1usize,
            _ => 8,
        };
        let b = q.pop_batch_with(cap, Duration::ZERO).unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn color_jobs_batch_separately() {
        let gray = req(1, 16);
        let rgb = ColorImage::from_gray(&synthetic::lena_like(
            16, 16, 1,
        ));
        let color = Request::compress_color(
            2,
            rgb.clone(),
            Variant::Dct,
            Lane::Cpu,
            Subsampling::S420,
        );
        assert_ne!(gray.batch_key(), color.batch_key());
        let color444 = Request::compress_color(
            3,
            rgb,
            Variant::Dct,
            Lane::Cpu,
            Subsampling::S444,
        );
        assert_ne!(color.batch_key(), color444.batch_key());
        assert!(color.image.is_color());
        assert_eq!(color.image.width(), 16);
    }

    #[test]
    fn parse_cpu_parallel_lane() {
        assert_eq!(Lane::parse("cpu-parallel"), Some(Lane::CpuParallel));
        assert_eq!(Lane::parse("CPU_PARALLEL"), Some(Lane::CpuParallel));
        assert_eq!(Lane::parse("parallel"), Some(Lane::CpuParallel));
        assert_eq!(Lane::parse("cpu"), Some(Lane::Cpu));
        assert_eq!(Lane::parse("bogus"), None);
    }

    #[test]
    fn blocked_producer_unblocks_during_linger() {
        // Regression: a capacity-1 Block queue whose popper lingers for
        // stragglers must release the blocked producer as soon as the
        // head pops — the producer's job is the straggler being lingered
        // for. Before the fix the producer slept until the deadline.
        use std::sync::Arc;
        let q = Arc::new(RequestQueue::new(1, Backpressure::Block));
        let _h1 = q.submit(req(1, 16)).unwrap();
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || {
            let t0 = std::time::Instant::now();
            let h = q2.submit(req(2, 16)).unwrap();
            std::mem::forget(h); // keep reply channel alive
            t0.elapsed()
        });
        std::thread::sleep(Duration::from_millis(30));
        let b = q.pop_batch(8, Duration::from_secs(3)).unwrap();
        let blocked_for = t.join().unwrap();
        assert_eq!(b.len(), 2, "freed producer's job joins the batch");
        assert!(
            blocked_for < Duration::from_secs(2),
            "producer blocked through the whole linger: {blocked_for:?}"
        );
    }

    #[test]
    fn close_mid_linger_returns_partial_batch() {
        use std::sync::Arc;
        let q = Arc::new(RequestQueue::new(8, Backpressure::Reject));
        let _h = q.submit(req(1, 16)).unwrap();
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            q2.close();
        });
        let t0 = std::time::Instant::now();
        let b = q.pop_batch(8, Duration::from_secs(5)).unwrap();
        t.join().unwrap();
        assert_eq!(b.len(), 1);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "close must end the linger, waited {:?}",
            t0.elapsed()
        );
        assert!(q.pop_batch(8, Duration::ZERO).is_none());
    }

    #[test]
    fn linger_break_hands_wakeup_to_idle_worker() {
        // Regression: submit() notifies exactly one waiter. If that
        // wakeup lands on a lingering popper whose key does not match,
        // the popper breaks out — and must re-notify so an idle worker
        // picks the job up instead of it sitting queued.
        use std::sync::Arc;
        let q = Arc::new(RequestQueue::new(8, Backpressure::Reject));
        let _h1 = q.submit(req(1, 16)).unwrap();
        let qa = Arc::clone(&q);
        let a = std::thread::spawn(move || {
            qa.pop_batch(8, Duration::from_secs(2)).unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        let qb = Arc::clone(&q);
        let b = std::thread::spawn(move || {
            qb.pop_batch(8, Duration::from_secs(2)).unwrap()
        });
        std::thread::sleep(Duration::from_millis(30));
        let h = q.submit(req(2, 24)).unwrap(); // different batch key
        std::mem::forget(h);
        let mut ids: Vec<u64> = a
            .join()
            .unwrap()
            .iter()
            .chain(b.join().unwrap().iter())
            .map(|j| j.request.id)
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2], "both jobs served, neither stranded");
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn decode_requests_batch_apart_from_pixel_jobs() {
        let gray = req(1, 16);
        let dec = Request::decode(2, vec![1, 2, 3], Lane::Auto);
        assert_ne!(gray.batch_key(), dec.batch_key());
        assert_eq!(dec.image.width(), 0);
        assert!(!dec.image.is_color());
        assert!(!dec.want_psnr);
        let mut cdc3 = b"CDC3".to_vec();
        cdc3.extend_from_slice(&[0u8; 16]);
        assert!(JobImage::Encoded(cdc3).is_color());
        // want_psnr is not part of the batch key: fast-path and full
        // jobs share executables
        assert_eq!(gray.batch_key(), req(1, 16).no_psnr().batch_key());
    }

    #[test]
    fn linger_collects_late_arrivals() {
        use std::sync::Arc;
        let q = Arc::new(RequestQueue::new(16, Backpressure::Reject));
        let _h1 = q.submit(req(1, 16)).unwrap();
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            let _h = q2.submit(req(2, 16)).unwrap();
            std::mem::forget(_h); // keep channel alive past thread exit
        });
        let b = q.pop_batch(8, Duration::from_millis(300)).unwrap();
        t.join().unwrap();
        assert_eq!(b.len(), 2, "linger should catch the second job");
    }
}
