//! Dynamic batching policy: how many same-key jobs to coalesce per
//! dispatch and how long to linger for stragglers.
//!
//! The queue does the mechanical grouping
//! ([`RequestQueue::pop_batch_with`](super::request::RequestQueue)); this
//! module owns the *policy* (sizes/linger per lane) and the batch
//! bookkeeping that the ablation bench sweeps. The worker passes
//! [`BatchPolicy::max_for`] into the queue so the cap of the head job's
//! lane — not a global maximum — bounds each batch; a lane with max 1
//! (the serial CPU default) bypasses straggler coalescing entirely.

use std::time::Duration;

use super::request::Lane;

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max jobs per GPU-lane dispatch group.
    pub gpu_max_batch: usize,
    /// Max jobs per serial-CPU-lane group (CPU jobs are independent;
    /// grouping only amortizes queue locking).
    pub cpu_max_batch: usize,
    /// Max jobs per parallel-CPU-lane group. Parallel-lane jobs already
    /// saturate the cores one at a time, so grouping buys queue-lock
    /// amortization only; keep it small.
    pub cpu_parallel_max_batch: usize,
    /// How long to wait for same-key stragglers after the first job.
    pub linger: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            gpu_max_batch: 8,
            cpu_max_batch: 1,
            cpu_parallel_max_batch: 1,
            linger: Duration::from_micros(200),
        }
    }
}

impl BatchPolicy {
    /// No batching at all (the ablation baseline).
    pub fn unbatched() -> Self {
        BatchPolicy {
            gpu_max_batch: 1,
            cpu_max_batch: 1,
            cpu_parallel_max_batch: 1,
            linger: Duration::ZERO,
        }
    }

    pub fn max_for(&self, lane: Lane) -> usize {
        match lane {
            Lane::Gpu | Lane::Auto => self.gpu_max_batch.max(1),
            Lane::Cpu => self.cpu_max_batch.max(1),
            Lane::CpuParallel => self.cpu_parallel_max_batch.max(1),
        }
    }

    /// The queue-level pop size: the largest any lane allows (the head
    /// job's key then constrains the actual group).
    pub fn pop_max(&self) -> usize {
        self.gpu_max_batch
            .max(self.cpu_max_batch)
            .max(self.cpu_parallel_max_batch)
            .max(1)
    }
}

/// Running batch statistics for the service metrics endpoint.
#[derive(Debug, Default, Clone)]
pub struct BatchStats {
    pub batches: u64,
    pub jobs: u64,
    pub max_batch_seen: usize,
}

impl BatchStats {
    pub fn record(&mut self, batch_len: usize) {
        self.batches += 1;
        self.jobs += batch_len as u64;
        self.max_batch_seen = self.max_batch_seen.max(batch_len);
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.jobs as f64 / self.batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_policy_sane() {
        let p = BatchPolicy::default();
        assert!(p.gpu_max_batch >= 1);
        assert_eq!(p.max_for(Lane::Cpu), 1);
        assert_eq!(p.max_for(Lane::CpuParallel), 1);
        assert_eq!(p.max_for(Lane::Gpu), p.gpu_max_batch);
        assert_eq!(p.pop_max(), p.gpu_max_batch);
    }

    #[test]
    fn unbatched_is_single() {
        let p = BatchPolicy::unbatched();
        assert_eq!(p.pop_max(), 1);
        assert_eq!(p.linger, Duration::ZERO);
    }

    #[test]
    fn stats_accumulate() {
        let mut s = BatchStats::default();
        s.record(4);
        s.record(2);
        s.record(6);
        assert_eq!(s.batches, 3);
        assert_eq!(s.jobs, 12);
        assert_eq!(s.mean_batch(), 4.0);
        assert_eq!(s.max_batch_seen, 6);
    }

    #[test]
    fn zero_max_clamped() {
        let p = BatchPolicy {
            gpu_max_batch: 0,
            cpu_max_batch: 0,
            cpu_parallel_max_batch: 0,
            linger: Duration::ZERO,
        };
        assert_eq!(p.max_for(Lane::Gpu), 1);
        assert_eq!(p.max_for(Lane::CpuParallel), 1);
        assert_eq!(p.pop_max(), 1);
    }

    #[test]
    fn parallel_lane_has_its_own_arm() {
        let p = BatchPolicy {
            cpu_parallel_max_batch: 3,
            ..Default::default()
        };
        assert_eq!(p.max_for(Lane::CpuParallel), 3);
        assert_eq!(p.max_for(Lane::Cpu), 1);
        assert_eq!(p.pop_max(), p.gpu_max_batch.max(3));
    }
}
