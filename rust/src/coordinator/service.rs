//! The service facade: owns the queue, worker threads, optional PJRT
//! runtime, and metrics; this is what the launcher and examples talk to.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::dct::batch::{BatchWidth, EngineConfig};
use crate::dct::cordic_fxp::FxpPrecision;
use crate::dct::Variant;
use crate::faults::{FaultInjector, FaultPlan};
use crate::image::color::ColorImage;
use crate::image::ycbcr::Subsampling;
use crate::image::GrayImage;
use crate::log_info;
use crate::metrics::stats::SharedHistogram;
use crate::runtime::{Executor, Runtime};

use super::batcher::BatchPolicy;
use super::request::{
    Backpressure, JobHandle, Lane, Request, RequestKind, RequestQueue,
};
use super::worker::{self, DecodeCounters, RunExit, WorkerCtx};

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Worker thread count.
    pub workers: usize,
    /// Threads each `CpuParallel`-lane job fans out over. `0` = machine
    /// default divided by the worker count, so a fully busy worker pool
    /// running parallel-lane jobs does not oversubscribe the cores; set
    /// explicitly (e.g. to the core count) for lone-job deployments.
    pub cpu_parallel_workers: usize,
    /// Request queue capacity (backpressure boundary).
    pub queue_capacity: usize,
    pub backpressure: Backpressure,
    pub batch: BatchPolicy,
    /// IJG quality for compression jobs (must match the artifacts').
    pub quality: u8,
    /// Artifact directory; None disables the GPU lane.
    pub artifact_dir: Option<std::path::PathBuf>,
    /// Serve the GPU lane with the host-side stub backend
    /// ([`Runtime::stub`]) when no artifact manifest is found. The stub
    /// computes every artifact kind bit-identically to the CPU lanes, so
    /// the whole GPU-lane path (planar batches, plane-parallel color,
    /// fused entropy feed) exercises end-to-end in offline builds and CI.
    pub stub_gpu: bool,
    /// Batch-engine lane width for the CPU lanes (`Auto` = env override
    /// or hardware detection; outputs are bit-identical either way).
    pub batch_width: BatchWidth,
    /// Precision of the fixed-point CORDIC lane (`--variant cordic-fxp`
    /// jobs); ignored by the f32 variants.
    pub precision: FxpPrecision,
    /// Worker-side fault-injection plan (chaos testing: seeded panics +
    /// artificial job latency). `None` — the default — keeps the worker
    /// hot path at a single skipped `Option` check.
    pub faults: Option<FaultPlan>,
    /// Restart interval of the CDC2 containers the compress lanes emit:
    /// block rows per independently decodable segment. `0` collapses
    /// each plane to a single segment (minimal overhead, no partial
    /// recovery).
    pub restart_interval: u16,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: crate::util::threadpool::ThreadPool::default_size(),
            cpu_parallel_workers: 0,
            queue_capacity: 256,
            backpressure: Backpressure::Block,
            batch: BatchPolicy::default(),
            quality: 50,
            artifact_dir: Some(std::path::PathBuf::from("artifacts")),
            stub_gpu: false,
            batch_width: BatchWidth::default(),
            precision: FxpPrecision::default(),
            faults: None,
            restart_interval: crate::codec::DEFAULT_RESTART_INTERVAL,
        }
    }
}

/// Aggregate service statistics snapshot.
#[derive(Debug, Clone)]
pub struct ServiceStats {
    pub submitted: u64,
    pub queue_depth: usize,
    pub queue_wait: (u64, f64, f64, f64), // count, mean, p95, max (ms)
    pub process: (u64, f64, f64, f64),
    pub compiled_executables: usize,
    /// Times a worker loop was re-entered after a panicked job (or an
    /// escaped panic) — the supervision signal of the resilience layer.
    pub worker_restarts: u64,
    /// Strict decode jobs that failed on damaged or hostile input.
    pub decode_strict_failures: u64,
    /// Salvage decode jobs that found (and tolerated) damage.
    pub decode_salvaged: u64,
    /// Segments concealed across all salvage decodes.
    pub segments_concealed_total: u64,
}

/// The running service.
pub struct Service {
    queue: Arc<RequestQueue>,
    workers: Vec<JoinHandle<()>>,
    runtime: Option<Arc<Runtime>>,
    next_id: AtomicU64,
    quality: u8,
    queue_hist: Arc<SharedHistogram>,
    process_hist: Arc<SharedHistogram>,
    restarts: Arc<AtomicU64>,
    decode_counters: Arc<DecodeCounters>,
}

impl Service {
    pub fn start(cfg: ServiceConfig) -> Result<Service> {
        let runtime = match &cfg.artifact_dir {
            Some(dir) if dir.join("manifest.json").exists() => {
                match Runtime::new(dir) {
                    Ok(rt) => Some(Arc::new(rt)),
                    // stub_gpu means "serve the GPU lane no matter
                    // what": a manifest without a working PJRT client
                    // (the vendored offline build) falls back too
                    Err(e) if cfg.stub_gpu => {
                        log_info!(
                            "service",
                            "PJRT unavailable ({e:#}); serving the GPU \
                             lane with the stub backend"
                        );
                        Some(Arc::new(Runtime::stub(cfg.quality)))
                    }
                    Err(e) => {
                        return Err(e).with_context(|| {
                            format!(
                                "loading artifacts from {}",
                                dir.display()
                            )
                        })
                    }
                }
            }
            _ if cfg.stub_gpu => {
                Some(Arc::new(Runtime::stub(cfg.quality)))
            }
            _ => None,
        };
        let queue = Arc::new(RequestQueue::new(
            cfg.queue_capacity,
            cfg.backpressure,
        ));
        let queue_hist = Arc::new(SharedHistogram::default());
        let process_hist = Arc::new(SharedHistogram::default());
        // resolve the parallel-lane fan-out: divide the machine across the
        // worker pool unless the config pins an explicit count
        let parallel_workers = if cfg.cpu_parallel_workers > 0 {
            cfg.cpu_parallel_workers
        } else {
            (crate::util::threadpool::ThreadPool::default_size()
                / cfg.workers.max(1))
            .max(1)
        };
        // one root injector per service; each worker forks its own
        // deterministic stream keyed by its index
        let faults_root =
            cfg.faults.as_ref().map(|p| FaultInjector::new(p.clone()));
        let restarts = Arc::new(AtomicU64::new(0));
        let decode_counters = Arc::new(DecodeCounters::default());
        let mut workers = Vec::with_capacity(cfg.workers);
        for i in 0..cfg.workers.max(1) {
            let ctx = WorkerCtx {
                queue: Arc::clone(&queue),
                executor: runtime
                    .as_ref()
                    .map(|rt| Arc::new(Executor::new(Arc::clone(rt)))),
                policy: cfg.batch,
                quality: cfg.quality,
                parallel_workers,
                engine: EngineConfig {
                    width: cfg.batch_width,
                    precision: cfg.precision,
                },
                queue_hist: Arc::clone(&queue_hist),
                process_hist: Arc::clone(&process_hist),
                faults: faults_root
                    .as_ref()
                    .map(|r| Arc::new(r.fork(i as u64))),
                restart_interval: cfg.restart_interval,
                decode_counters: Arc::clone(&decode_counters),
            };
            let restarts = Arc::clone(&restarts);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("coordinator-worker-{i}"))
                    // supervisor trampoline: re-enter the worker loop
                    // after a panicked job (the reply was already sent
                    // structured) instead of bleeding pool capacity
                    .spawn(move || loop {
                        match catch_unwind(AssertUnwindSafe(|| {
                            worker::run(&ctx)
                        })) {
                            Ok(RunExit::QueueClosed) => break,
                            Ok(RunExit::JobPanicked) => {
                                restarts.fetch_add(1, Ordering::Relaxed);
                            }
                            // a panic escaped the per-job guard (a bug
                            // in the loop itself): still recover
                            Err(_) => {
                                restarts.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    })
                    .context("spawning worker")?,
            );
        }
        log_info!(
            "service",
            "started: {} workers, queue {}, gpu lane {}",
            workers.len(),
            cfg.queue_capacity,
            if runtime.is_some() { "on" } else { "off" }
        );
        Ok(Service {
            queue,
            workers,
            runtime,
            next_id: AtomicU64::new(1),
            quality: cfg.quality,
            queue_hist,
            process_hist,
            restarts,
            decode_counters,
        })
    }

    pub fn has_gpu_lane(&self) -> bool {
        self.runtime.is_some()
    }

    pub fn quality(&self) -> u8 {
        self.quality
    }

    pub fn runtime(&self) -> Option<&Arc<Runtime>> {
        self.runtime.as_ref()
    }

    /// Submit a grayscale compression job.
    pub fn compress(&self, image: GrayImage, variant: Variant, lane: Lane)
                    -> Result<JobHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.queue
            .submit(Request::compress(id, image, variant, lane))
    }

    /// Submit a grayscale compression job, optionally skipping the
    /// reconstruction + PSNR work (`want_psnr: false` is the serve fast
    /// path: the response then carries only the container bytes).
    pub fn compress_opts(
        &self,
        image: GrayImage,
        variant: Variant,
        lane: Lane,
        want_psnr: bool,
    ) -> Result<JobHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request::compress(id, image, variant, lane);
        self.queue
            .submit(if want_psnr { req } else { req.no_psnr() })
    }

    /// Submit a color compression job with an explicit PSNR switch
    /// (see [`Service::compress_opts`]).
    pub fn compress_color_opts(
        &self,
        image: ColorImage,
        variant: Variant,
        lane: Lane,
        subsampling: Subsampling,
        want_psnr: bool,
    ) -> Result<JobHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req =
            Request::compress_color(id, image, variant, lane, subsampling);
        self.queue
            .submit(if want_psnr { req } else { req.no_psnr() })
    }

    /// Submit a decode job for a CDC1/CDC3 container. Decode always runs
    /// on the CPU lanes; `Lane::Auto` and `Lane::Gpu` resolve to
    /// [`Lane::Cpu`] / fail inside the worker respectively.
    pub fn decode(&self, container: Vec<u8>, lane: Lane)
                  -> Result<JobHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.queue.submit(Request::decode(id, container, lane))
    }

    /// Submit a salvage decode job: damaged CDC2 segments are concealed
    /// instead of failing the job, and the response's
    /// [`JobOutput::salvage`](super::request::JobOutput::salvage) report
    /// says exactly how much was lost. Undamaged input decodes
    /// bit-identically to [`Service::decode`].
    pub fn decode_salvage(&self, container: Vec<u8>, lane: Lane)
                          -> Result<JobHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.queue
            .submit(Request::decode_salvage(id, container, lane))
    }

    /// Submit a color (YCbCr) compression job — the `color: true`
    /// request shape, served by either CPU lane or (since the
    /// planar-batch rework) the GPU lane.
    ///
    /// # Examples
    ///
    /// Serve one 4:2:0 color job on the stub-backed GPU lane:
    ///
    /// ```
    /// use cordic_dct::coordinator::{Lane, Service, ServiceConfig};
    /// use cordic_dct::dct::Variant;
    /// use cordic_dct::image::synthetic;
    /// use cordic_dct::image::ycbcr::Subsampling;
    ///
    /// let svc = Service::start(ServiceConfig {
    ///     workers: 1,
    ///     artifact_dir: None,
    ///     stub_gpu: true, // GPU lane served host-side, bit-identical
    ///     ..Default::default()
    /// })
    /// .unwrap();
    /// let img = synthetic::lena_like_rgb(32, 24, 1);
    /// let resp = svc
    ///     .compress_color(img, Variant::Cordic, Lane::Gpu,
    ///                     Subsampling::S420)
    ///     .unwrap()
    ///     .wait();
    /// assert_eq!(resp.lane, Lane::Gpu);
    /// let out = resp.result.unwrap();
    /// assert!(out.psnr_db.unwrap() > 25.0);
    /// assert!(out.color_image.unwrap().width == 32);
    /// svc.shutdown();
    /// ```
    pub fn compress_color(
        &self,
        image: ColorImage,
        variant: Variant,
        lane: Lane,
        subsampling: Subsampling,
    ) -> Result<JobHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.queue.submit(Request::compress_color(
            id,
            image,
            variant,
            lane,
            subsampling,
        ))
    }

    /// Submit a histogram-equalization job.
    pub fn histeq(&self, image: GrayImage, lane: Lane) -> Result<JobHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.queue.submit(Request {
            id,
            kind: RequestKind::Histeq,
            image: super::request::JobImage::Gray(image),
            variant: Variant::Dct,
            lane,
            subsampling: Subsampling::S420,
            want_psnr: false,
            salvage: false,
        })
    }

    /// Submit a job whose response is delivered to a caller-supplied
    /// channel instead of a per-job [`JobHandle`]. The closure receives
    /// the allocated job id and builds the request; many jobs can share
    /// one sender, so a single consumer sees completions in completion
    /// order — this is what the serve layer's pipelined (v2) connections
    /// fan out through. Returns the job id on successful enqueue.
    pub fn submit_with_reply(
        &self,
        build: impl FnOnce(u64) -> Request,
        reply: std::sync::mpsc::Sender<super::request::Response>,
    ) -> Result<u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.queue.submit_with_reply(build(id), reply)?;
        Ok(id)
    }

    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            submitted: self.next_id.load(Ordering::Relaxed) - 1,
            queue_depth: self.queue.len(),
            queue_wait: self.queue_hist.snapshot(),
            process: self.process_hist.snapshot(),
            compiled_executables: self
                .runtime
                .as_ref()
                .map(|r| r.cached_count())
                .unwrap_or(0),
            worker_restarts: self.restarts.load(Ordering::Relaxed),
            decode_strict_failures: self
                .decode_counters
                .strict_failures
                .load(Ordering::Relaxed),
            decode_salvaged: self
                .decode_counters
                .salvaged
                .load(Ordering::Relaxed),
            segments_concealed_total: self
                .decode_counters
                .segments_concealed
                .load(Ordering::Relaxed),
        }
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synthetic;

    fn cpu_only_config(workers: usize) -> ServiceConfig {
        ServiceConfig {
            workers,
            artifact_dir: None,
            ..Default::default()
        }
    }

    #[test]
    fn end_to_end_cpu_only() {
        let svc = Service::start(cpu_only_config(2)).unwrap();
        assert!(!svc.has_gpu_lane());
        let img = synthetic::lena_like(48, 48, 1);
        let h = svc
            .compress(img.clone(), Variant::Dct, Lane::Auto)
            .unwrap();
        let resp = h.wait();
        assert_eq!(resp.lane, Lane::Cpu);
        let out = resp.result.unwrap();
        assert!(out.psnr_db.unwrap() > 28.0);
        svc.shutdown();
    }

    #[test]
    fn many_jobs_all_return_exactly_once() {
        let svc = Service::start(cpu_only_config(4)).unwrap();
        let handles: Vec<_> = (0..40)
            .map(|i| {
                let img = synthetic::lena_like(16 + (i % 3) * 8, 16, i as u64);
                svc.compress(img, Variant::Dct, Lane::Cpu).unwrap()
            })
            .collect();
        let mut ids: Vec<u64> =
            handles.into_iter().map(|h| h.wait().id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 40, "every job returns exactly once");
        let stats = svc.stats();
        assert_eq!(stats.submitted, 40);
        assert_eq!(stats.process.0, 40);
        svc.shutdown();
    }

    #[test]
    fn parallel_lane_end_to_end() {
        let svc = Service::start(ServiceConfig {
            workers: 2,
            cpu_parallel_workers: 4,
            artifact_dir: None,
            ..Default::default()
        })
        .unwrap();
        let img = synthetic::lena_like(64, 48, 3);
        let a = svc
            .compress(img.clone(), Variant::Dct, Lane::Cpu)
            .unwrap()
            .wait();
        let b = svc
            .compress(img, Variant::Dct, Lane::CpuParallel)
            .unwrap()
            .wait();
        assert_eq!(a.lane, Lane::Cpu);
        assert_eq!(b.lane, Lane::CpuParallel);
        let (oa, ob) = (a.result.unwrap(), b.result.unwrap());
        // three-lane invariant: the parallel lane is bit-identical
        assert_eq!(oa.image, ob.image);
        assert_eq!(oa.compressed_bytes, ob.compressed_bytes);
        svc.shutdown();
    }

    #[test]
    fn variant_affects_output() {
        let svc = Service::start(cpu_only_config(2)).unwrap();
        let img = synthetic::lena_like(64, 64, 9);
        let d = svc
            .compress(img.clone(), Variant::Dct, Lane::Cpu)
            .unwrap()
            .wait()
            .result
            .unwrap();
        let c = svc
            .compress(img, Variant::Cordic, Lane::Cpu)
            .unwrap()
            .wait()
            .result
            .unwrap();
        assert!(c.psnr_db.unwrap() < d.psnr_db.unwrap());
        svc.shutdown();
    }

    #[test]
    fn stub_gpu_lane_serves_gray_and_color() {
        use crate::coordinator::request::Lane;
        let svc = Service::start(ServiceConfig {
            workers: 2,
            artifact_dir: None,
            stub_gpu: true,
            ..Default::default()
        })
        .unwrap();
        assert!(svc.has_gpu_lane());
        let gray = synthetic::lena_like(30, 21, 4);
        let g = svc
            .compress(gray, Variant::Cordic, Lane::Gpu)
            .unwrap()
            .wait();
        assert_eq!(g.lane, Lane::Gpu);
        assert!(g.result.unwrap().psnr_db.unwrap() > 25.0);
        // Auto now routes color to the stub-backed GPU lane
        let rgb = synthetic::lena_like_rgb(30, 21, 4);
        let c = svc
            .compress_color(
                rgb,
                Variant::Cordic,
                Lane::Auto,
                Subsampling::S420,
            )
            .unwrap()
            .wait();
        assert_eq!(c.lane, Lane::Gpu);
        let out = c.result.unwrap();
        assert!(out.psnr_db.unwrap() > 25.0);
        assert_eq!(out.color_image.unwrap().height, 21);
        svc.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_via_drop() {
        let svc = Service::start(cpu_only_config(1)).unwrap();
        drop(svc); // close + join without panic
    }

    #[test]
    fn supervised_pool_survives_injected_panics() {
        use crate::coordinator::JOB_PANIC_TAG;
        // seed 3 mixes panics and successes over 16 draws at p=0.5
        let svc = Service::start(ServiceConfig {
            workers: 1,
            artifact_dir: None,
            faults: Some(FaultPlan::parse("seed=3,panic=0.5").unwrap()),
            ..Default::default()
        })
        .unwrap();
        let img = synthetic::lena_like(24, 24, 1);
        let (mut ok, mut panicked) = (0u64, 0u64);
        for _ in 0..16 {
            let resp = svc
                .compress(img.clone(), Variant::Dct, Lane::Cpu)
                .unwrap()
                .wait();
            match resp.result {
                Ok(out) => {
                    assert!(out.container.is_some());
                    ok += 1;
                }
                Err(e) => {
                    let chain = format!("{e:#}");
                    assert!(
                        chain.contains(JOB_PANIC_TAG),
                        "untagged job failure: {chain}"
                    );
                    panicked += 1;
                }
            }
        }
        assert!(ok > 0, "pool must keep serving between panics");
        assert!(panicked > 0, "seeded plan must fire");
        // sequential submit+wait on one worker: every panicked job is
        // exactly one supervised respawn
        assert_eq!(svc.stats().worker_restarts, panicked);
        svc.shutdown();
    }

    #[test]
    fn color_end_to_end_both_lanes() {
        let svc = Service::start(ServiceConfig {
            workers: 2,
            cpu_parallel_workers: 2,
            artifact_dir: None,
            ..Default::default()
        })
        .unwrap();
        let img = synthetic::cablecar_like_rgb(48, 40, 6);
        let a = svc
            .compress_color(
                img.clone(),
                Variant::Cordic,
                Lane::Cpu,
                Subsampling::S420,
            )
            .unwrap()
            .wait();
        let b = svc
            .compress_color(
                img,
                Variant::Cordic,
                Lane::CpuParallel,
                Subsampling::S420,
            )
            .unwrap()
            .wait();
        assert_eq!(a.lane, Lane::Cpu);
        assert_eq!(b.lane, Lane::CpuParallel);
        let (oa, ob) = (a.result.unwrap(), b.result.unwrap());
        assert_eq!(oa.color_image, ob.color_image);
        assert_eq!(oa.compressed_bytes, ob.compressed_bytes);
        assert!(oa.psnr_db.unwrap() > 25.0);
        svc.shutdown();
    }

    #[test]
    fn decode_and_fast_path_through_service() {
        let svc = Service::start(cpu_only_config(2)).unwrap();
        let img = synthetic::lena_like(40, 24, 7);
        let full = svc
            .compress(img.clone(), Variant::Dct, Lane::Cpu)
            .unwrap()
            .wait()
            .result
            .unwrap();
        let fast = svc
            .compress_opts(img, Variant::Dct, Lane::Cpu, false)
            .unwrap()
            .wait()
            .result
            .unwrap();
        // the fast path skips recon/PSNR but ships identical bytes
        assert!(fast.psnr_db.is_none() && fast.image.is_none());
        assert_eq!(fast.container, full.container);
        let dec = svc
            .decode(full.container.clone().unwrap(), Lane::Auto)
            .unwrap()
            .wait();
        assert_eq!(dec.lane, Lane::Cpu, "decode resolves Auto to Cpu");
        let rec = dec.result.unwrap().image.unwrap();
        assert_eq!((rec.width, rec.height), (40, 24));
        svc.shutdown();
    }

    #[test]
    fn salvage_decode_through_service_updates_stats() {
        let svc = Service::start(cpu_only_config(1)).unwrap();
        let img = synthetic::cablecar_like(48, 48, 11);
        let container = svc
            .compress(img, Variant::Dct, Lane::Cpu)
            .unwrap()
            .wait()
            .result
            .unwrap()
            .container
            .unwrap();
        let mut bad = container.clone();
        let n = bad.len();
        bad[n - n / 6] ^= 0x40;
        assert!(svc
            .decode(bad.clone(), Lane::Cpu)
            .unwrap()
            .wait()
            .result
            .is_err());
        let out = svc
            .decode_salvage(bad, Lane::Cpu)
            .unwrap()
            .wait()
            .result
            .unwrap();
        let report = out.salvage.unwrap();
        assert_eq!(report.segments_damaged, 1);
        assert!(out.image.is_some());
        // the clean container salvage-decodes bit-identically to strict
        let strict = svc
            .decode(container.clone(), Lane::Cpu)
            .unwrap()
            .wait()
            .result
            .unwrap();
        let clean = svc
            .decode_salvage(container, Lane::Cpu)
            .unwrap()
            .wait()
            .result
            .unwrap();
        assert_eq!(strict.image, clean.image);
        assert!(clean.salvage.unwrap().is_clean());
        let stats = svc.stats();
        assert_eq!(stats.decode_strict_failures, 1);
        assert_eq!(stats.decode_salvaged, 1);
        assert_eq!(stats.segments_concealed_total, 1);
        svc.shutdown();
    }
}
