//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parsed with the in-crate JSON substrate.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One AOT artifact as described by manifest.json.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    /// Path to the HLO text file (absolute, resolved against the manifest
    /// directory).
    pub path: PathBuf,
    pub kind: String,
    pub variant: Option<String>,
    pub quality: Option<u8>,
    pub height: usize,
    pub width: usize,
    /// Input shapes, row-major (H, W).
    pub inputs: Vec<(usize, usize)>,
    pub outputs: Vec<String>,
}

/// Parsed manifest with lookup indices.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub quality: u8,
    by_name: BTreeMap<String, Artifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let quality = j
            .get("quality")
            .and_then(Json::as_f64)
            .unwrap_or(50.0) as u8;
        let mut by_name = BTreeMap::new();
        for a in j
            .req("artifacts")?
            .as_arr()
            .ok_or_else(|| anyhow!("artifacts must be an array"))?
        {
            let name = a
                .req("name")?
                .as_str()
                .ok_or_else(|| anyhow!("artifact name must be string"))?
                .to_string();
            let file = a
                .req("file")?
                .as_str()
                .ok_or_else(|| anyhow!("artifact file must be string"))?;
            let mut inputs = Vec::new();
            for inp in a
                .req("inputs")?
                .as_arr()
                .ok_or_else(|| anyhow!("inputs must be array"))?
            {
                let shape = inp
                    .req("shape")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("shape must be array"))?;
                if shape.len() != 2 {
                    bail!("artifact {name}: only rank-2 inputs supported");
                }
                inputs.push((
                    shape[0]
                        .as_usize()
                        .ok_or_else(|| anyhow!("bad shape dim"))?,
                    shape[1]
                        .as_usize()
                        .ok_or_else(|| anyhow!("bad shape dim"))?,
                ));
            }
            let outputs = a
                .get("outputs")
                .and_then(Json::as_arr)
                .map(|v| {
                    v.iter()
                        .filter_map(Json::as_str)
                        .map(str::to_string)
                        .collect()
                })
                .unwrap_or_default();
            let art = Artifact {
                path: dir.join(file),
                kind: a
                    .get("kind")
                    .and_then(Json::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
                variant: a
                    .get("variant")
                    .and_then(Json::as_str)
                    .map(str::to_string),
                quality: a
                    .get("quality")
                    .and_then(Json::as_f64)
                    .map(|q| q as u8),
                height: a
                    .get("height")
                    .and_then(Json::as_usize)
                    .unwrap_or(inputs.first().map(|s| s.0).unwrap_or(0)),
                width: a
                    .get("width")
                    .and_then(Json::as_usize)
                    .unwrap_or(inputs.first().map(|s| s.1).unwrap_or(0)),
                name: name.clone(),
                inputs,
                outputs,
            };
            by_name.insert(name, art);
        }
        if by_name.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(Manifest {
            dir,
            quality,
            by_name,
        })
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&Artifact> {
        self.by_name.get(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.by_name.keys().map(String::as_str)
    }

    /// Find an artifact by kind/variant for an exact padded shape.
    pub fn find(
        &self,
        kind: &str,
        variant: Option<&str>,
        height: usize,
        width: usize,
    ) -> Option<&Artifact> {
        self.by_name.values().find(|a| {
            a.kind == kind
                && a.height == height
                && a.width == width
                && variant
                    .map(|v| a.variant.as_deref() == Some(v))
                    .unwrap_or(true)
        })
    }

    /// All supported (height, width) shapes for a kind.
    pub fn shapes(&self, kind: &str) -> Vec<(usize, usize)> {
        let mut v: Vec<_> = self
            .by_name
            .values()
            .filter(|a| a.kind == kind)
            .map(|a| (a.height, a.width))
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "quality": 50, "dtype": "f32",
      "artifacts": [
        {"name": "compress_dct_512x512", "file": "compress_dct_512x512.hlo.txt",
         "kind": "compress", "variant": "dct", "quality": 50,
         "height": 512, "width": 512,
         "inputs": [{"shape": [512, 512], "dtype": "f32"}],
         "outputs": ["recon", "qcoef"]},
        {"name": "psnr_512x512", "file": "psnr_512x512.hlo.txt",
         "kind": "psnr", "height": 512, "width": 512,
         "inputs": [{"shape": [512, 512], "dtype": "f32"},
                     {"shape": [512, 512], "dtype": "f32"}],
         "outputs": ["psnr_db"]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m.quality, 50);
        let a = m.get("compress_dct_512x512").unwrap();
        assert_eq!(a.kind, "compress");
        assert_eq!(a.variant.as_deref(), Some("dct"));
        assert_eq!(a.inputs, vec![(512, 512)]);
        assert_eq!(a.path, PathBuf::from("/tmp/a/compress_dct_512x512.hlo.txt"));
    }

    #[test]
    fn find_by_kind_variant_shape() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/x")).unwrap();
        assert!(m.find("compress", Some("dct"), 512, 512).is_some());
        assert!(m.find("compress", Some("cordic"), 512, 512).is_none());
        assert!(m.find("psnr", None, 512, 512).is_some());
        assert!(m.find("compress", Some("dct"), 256, 256).is_none());
    }

    #[test]
    fn shapes_listing() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/x")).unwrap();
        assert_eq!(m.shapes("compress"), vec![(512, 512)]);
        assert!(m.shapes("histeq").is_empty());
    }

    #[test]
    fn rejects_empty_and_garbage() {
        assert!(Manifest::parse("{}", PathBuf::new()).is_err());
        assert!(Manifest::parse("{\"artifacts\": []}", PathBuf::new())
            .is_err());
        assert!(Manifest::parse("not json", PathBuf::new()).is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // integration: parse the actual artifacts/manifest.json when built
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.len() >= 40, "expected full artifact set");
            assert!(m.find("compress", Some("dct"), 200, 200).is_some());
            assert!(m.find("compress", Some("cordic"), 3072, 3072).is_some());
            assert!(m.find("histeq", None, 320, 288).is_some());
        }
    }
}
