//! PJRT client wrapper + compiled-executable cache, behind a backend
//! switch.
//!
//! One `Runtime` per process. Two backends present the same artifact
//! surface (`run_f32` over named kinds):
//!
//! * **PJRT** ([`Runtime::new`]) — holds the PJRT CPU client and lazily
//!   compiles artifacts on first use (HLO text -> HloModuleProto ->
//!   XlaComputation -> PjRtLoadedExecutable), caching by artifact name.
//!   Executables are shared across worker threads via `Arc`.
//! * **Stub** ([`Runtime::stub`]) — the host-side
//!   [`StubBackend`](super::stub::StubBackend): every kind computed with
//!   the CPU lanes' batched engine, bit-identical to the CPU pipelines.
//!   This is what serves the GPU lane when no artifacts exist (offline
//!   builds, CI) and what the parity suite locks against.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::log_debug;

use super::manifest::{Artifact, Manifest};
use super::stub::StubBackend;

/// A compiled artifact ready to execute.
pub struct Executable {
    pub artifact: Artifact,
    exe: xla::PjRtLoadedExecutable,
    /// Wall time spent compiling (ms) — surfaced in `info` output.
    pub compile_ms: f64,
}

impl Executable {
    /// Execute with rank-2 f32 inputs; returns the flat f32 buffers of
    /// each tuple element.
    pub fn run_f32(&self, inputs: &[(&[f32], usize, usize)])
                   -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, h, w) in inputs {
            anyhow::ensure!(
                buf.len() == h * w,
                "input buffer {} != {h}x{w}",
                buf.len()
            );
            literals.push(
                xla::Literal::vec1(buf)
                    .reshape(&[*h as i64, *w as i64])
                    .context("reshaping input literal")?,
            );
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("PJRT execute")?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = result.to_tuple().context("untupling result")?;
        parts
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }
}

/// PJRT half of the runtime: client + manifest + executable cache.
struct PjrtBackend {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

enum Backend {
    Pjrt(PjrtBackend),
    Stub(StubBackend),
}

/// The process-wide runtime: artifact surface over one of two backends.
pub struct Runtime {
    backend: Backend,
}

impl Runtime {
    /// Create a PJRT runtime over an artifact directory (requires the
    /// real PJRT bindings and `make artifacts` output).
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            backend: Backend::Pjrt(PjrtBackend {
                client,
                manifest,
                cache: Mutex::new(HashMap::new()),
            }),
        })
    }

    /// Create the host-side stub runtime: no artifacts needed, every
    /// kind computed bit-identically to the CPU lanes at the given IJG
    /// quality. This is the offline stand-in for the GPU lane.
    pub fn stub(quality: u8) -> Runtime {
        Runtime {
            backend: Backend::Stub(StubBackend::new(quality)),
        }
    }

    /// The PJRT runtime when `artifact_dir` holds a loadable manifest,
    /// else the stub backend at `quality` — the shared fallback the
    /// CLI's `--lane gpu` paths and the benches use (the coordinator's
    /// `ServiceConfig::stub_gpu` applies its own flag-gated policy).
    pub fn new_or_stub(
        artifact_dir: impl AsRef<std::path::Path>,
        quality: u8,
    ) -> Runtime {
        let dir = artifact_dir.as_ref();
        if dir.join("manifest.json").exists() {
            // the vendored offline build has no real PJRT client even
            // with a manifest present: fall through to the stub
            match Runtime::new(dir) {
                Ok(rt) => return rt,
                Err(e) => crate::log_info!(
                    "runtime",
                    "PJRT unavailable ({e:#}); using the stub backend"
                ),
            }
        }
        Runtime::stub(quality)
    }

    /// Is this the host-side stub backend (no PJRT underneath)?
    pub fn is_stub(&self) -> bool {
        matches!(self.backend, Backend::Stub(_))
    }

    /// The stub backend, when active (the executor's fast path).
    pub(crate) fn stub_backend(&self) -> Option<&StubBackend> {
        match &self.backend {
            Backend::Stub(s) => Some(s),
            Backend::Pjrt(_) => None,
        }
    }

    /// The artifact manifest (PJRT backend only — the stub needs none).
    pub fn manifest(&self) -> Option<&Manifest> {
        match &self.backend {
            Backend::Pjrt(p) => Some(&p.manifest),
            Backend::Stub(_) => None,
        }
    }

    /// IJG quality the backend's compress path quantizes at.
    pub fn quality(&self) -> u8 {
        match &self.backend {
            Backend::Pjrt(p) => p.manifest.quality,
            Backend::Stub(s) => s.quality,
        }
    }

    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Pjrt(p) => p.client.platform_name(),
            Backend::Stub(_) => "stub".to_string(),
        }
    }

    pub fn device_count(&self) -> usize {
        match &self.backend {
            Backend::Pjrt(p) => p.client.device_count(),
            // the stub computes on the host: one "device"
            Backend::Stub(_) => 1,
        }
    }

    /// Number of executables compiled (PJRT) or host pipelines built
    /// (stub) so far.
    pub fn cached_count(&self) -> usize {
        match &self.backend {
            Backend::Pjrt(p) => p.cache.lock().unwrap().len(),
            Backend::Stub(s) => s.cached_count(),
        }
    }

    /// Does the backend cover `kind`/`variant` at the padded shape? The
    /// stub covers every kind it implements at any 8-aligned shape; the
    /// PJRT backend requires an exact manifest hit.
    pub fn supports(
        &self,
        kind: &str,
        variant: Option<&str>,
        height: usize,
        width: usize,
    ) -> bool {
        match &self.backend {
            Backend::Pjrt(p) => {
                p.manifest.find(kind, variant, height, width).is_some()
            }
            Backend::Stub(_) => matches!(
                kind,
                "compress" | "compress_chroma" | "psnr" | "histeq" | "dct"
            ),
        }
    }

    /// Get (compiling if needed) the executable for a named artifact
    /// (PJRT backend only).
    pub fn executable(&self, name: &str) -> Result<Arc<Executable>> {
        let p = match &self.backend {
            Backend::Pjrt(p) => p,
            Backend::Stub(_) => {
                bail!("stub backend has no compiled executables")
            }
        };
        if let Some(e) = p.cache.lock().unwrap().get(name) {
            return Ok(Arc::clone(e));
        }
        let artifact = p
            .manifest
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?
            .clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            artifact
                .path
                .to_str()
                .context("artifact path not UTF-8")?,
        )
        .with_context(|| format!("parsing {}", artifact.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = p
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        log_debug!("runtime", "compiled {name} in {compile_ms:.1}ms");
        let e = Arc::new(Executable {
            artifact,
            exe,
            compile_ms,
        });
        // racing threads may have compiled concurrently; first in wins
        Ok(Arc::clone(
            p.cache
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_insert(e),
        ))
    }

    /// Find-and-compile by kind/variant/shape (PJRT backend only).
    pub fn executable_for(
        &self,
        kind: &str,
        variant: Option<&str>,
        height: usize,
        width: usize,
    ) -> Result<Arc<Executable>> {
        let p = match &self.backend {
            Backend::Pjrt(p) => p,
            Backend::Stub(_) => {
                bail!("stub backend has no compiled executables")
            }
        };
        let name = p
            .manifest
            .find(kind, variant, height, width)
            .map(|a| a.name.clone())
            .with_context(|| {
                format!(
                    "no artifact kind={kind} variant={variant:?} \
                     shape={height}x{width}; available shapes: {:?}",
                    p.manifest.shapes(kind)
                )
            })?;
        self.executable(&name)
    }

    /// Run one artifact kind over rank-2 f32 inputs — the uniform
    /// backend surface: PJRT resolves and executes the compiled
    /// artifact for the first input's shape; the stub computes host-side
    /// with the CPU lanes' exact arithmetic.
    ///
    /// # Examples
    ///
    /// ```
    /// use cordic_dct::runtime::Runtime;
    ///
    /// let rt = Runtime::stub(50);
    /// let a = vec![10.0f32; 64];
    /// let b = vec![12.0f32; 64];
    /// let outs = rt
    ///     .run_f32("psnr", None, &[(&a, 8, 8), (&b, 8, 8)])
    ///     .unwrap();
    /// // PSNR of two flat fields differing by 2 everywhere
    /// assert!((outs[0][0] - 42.11).abs() < 0.01);
    /// ```
    pub fn run_f32(
        &self,
        kind: &str,
        variant: Option<&str>,
        inputs: &[(&[f32], usize, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        match &self.backend {
            Backend::Pjrt(_) => {
                let (_, h, w) = *inputs
                    .first()
                    .context("run_f32 needs at least one input")?;
                self.executable_for(kind, variant, h, w)?.run_f32(inputs)
            }
            Backend::Stub(s) => s.run_f32(kind, variant, inputs),
        }
    }

    /// Warm the cache for a set of artifacts (serving cold-start
    /// control; a no-op on the stub backend, which has nothing to
    /// compile).
    pub fn warmup(&self, names: &[&str]) -> Result<f64> {
        let t0 = Instant::now();
        if self.is_stub() {
            return Ok(t0.elapsed().as_secs_f64() * 1e3);
        }
        for n in names {
            self.executable(n)?;
        }
        Ok(t0.elapsed().as_secs_f64() * 1e3)
    }
}

// PJRT clients and executables are internally synchronized (the stub
// backend is ordinary Send + Sync Rust data).
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn compile_and_cache() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = Runtime::new(dir).unwrap();
        assert_eq!(rt.cached_count(), 0);
        let e1 = rt.executable("compress_dct_200x200").unwrap();
        assert_eq!(rt.cached_count(), 1);
        let e2 = rt.executable("compress_dct_200x200").unwrap();
        assert!(Arc::ptr_eq(&e1, &e2), "second lookup must hit cache");
    }

    #[test]
    fn execute_compress_artifact() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let rt = Runtime::new(dir).unwrap();
        let exe = rt.executable("compress_dct_200x200").unwrap();
        let img: Vec<f32> =
            (0..200 * 200).map(|i| (i % 251) as f32).collect();
        let outs = exe.run_f32(&[(&img, 200, 200)]).unwrap();
        assert_eq!(outs.len(), 2, "recon + qcoef");
        assert_eq!(outs[0].len(), 200 * 200);
        // reconstruction stays in pixel range
        assert!(outs[0].iter().all(|&v| (0.0..=255.0).contains(&v)));
        // quantized coefficients are integers
        assert!(outs[1].iter().all(|&v| v.fract() == 0.0));
    }

    #[test]
    fn missing_artifact_errors() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let rt = Runtime::new(dir).unwrap();
        assert!(rt.executable("no_such_artifact").is_err());
        assert!(rt.executable_for("compress", Some("dct"), 7, 7).is_err());
    }

    #[test]
    fn stub_runtime_surface() {
        let rt = Runtime::stub(50);
        assert!(rt.is_stub());
        assert_eq!(rt.platform(), "stub");
        assert_eq!(rt.device_count(), 1);
        assert_eq!(rt.quality(), 50);
        assert!(rt.manifest().is_none());
        // the stub covers every implemented kind at any 8-aligned shape
        assert!(rt.supports("compress", Some("cordic"), 8, 8));
        assert!(rt.supports("compress", Some("cordic"), 3072, 3072));
        assert!(rt.supports("psnr", None, 200, 200));
        assert!(!rt.supports("unknown_kind", None, 8, 8));
        // no compiled executables exist on the stub
        assert!(rt.executable("compress_dct_200x200").is_err());
        assert!(rt
            .executable_for("compress", Some("dct"), 200, 200)
            .is_err());
        // warmup is a harmless no-op, never an error, on the stub
        assert!(rt.warmup(&["compress_dct_200x200"]).is_ok());
    }

    #[test]
    fn new_or_stub_falls_back_without_artifacts() {
        let rt = Runtime::new_or_stub("no_such_artifact_dir", 42);
        assert!(rt.is_stub());
        assert_eq!(rt.quality(), 42);
    }

    #[test]
    fn stub_run_f32_matches_cpu_lane() {
        use crate::dct::pipeline::CpuPipeline;
        use crate::dct::Variant;
        use crate::image::synthetic;
        let rt = Runtime::stub(50);
        let img = synthetic::lena_like(24, 16, 3);
        let outs = rt
            .run_f32("compress", Some("dct"), &[(&img.to_f32(), 16, 24)])
            .unwrap();
        let cpu = CpuPipeline::new(Variant::Dct, 50).compress(&img);
        assert_eq!(outs[0], cpu.recon.to_f32());
        assert_eq!(outs[1], cpu.qcoef);
        assert_eq!(rt.cached_count(), 1);
    }
}
