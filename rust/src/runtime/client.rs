//! PJRT client wrapper + compiled-executable cache.
//!
//! One `Runtime` per process: holds the PJRT CPU client and lazily
//! compiles artifacts on first use (HLO text -> HloModuleProto ->
//! XlaComputation -> PjRtLoadedExecutable), caching by artifact name.
//! Executables are shared across worker threads via `Arc`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::log_debug;

use super::manifest::{Artifact, Manifest};

/// A compiled artifact ready to execute.
pub struct Executable {
    pub artifact: Artifact,
    exe: xla::PjRtLoadedExecutable,
    /// Wall time spent compiling (ms) — surfaced in `info` output.
    pub compile_ms: f64,
}

impl Executable {
    /// Execute with rank-2 f32 inputs; returns the flat f32 buffers of
    /// each tuple element.
    pub fn run_f32(&self, inputs: &[(&[f32], usize, usize)])
                   -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, h, w) in inputs {
            anyhow::ensure!(
                buf.len() == h * w,
                "input buffer {} != {h}x{w}",
                buf.len()
            );
            literals.push(
                xla::Literal::vec1(buf)
                    .reshape(&[*h as i64, *w as i64])
                    .context("reshaping input literal")?,
            );
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("PJRT execute")?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = result.to_tuple().context("untupling result")?;
        parts
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }
}

/// The process-wide runtime: PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Create a CPU PJRT runtime over an artifact directory.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Number of executables compiled so far.
    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Get (compiling if needed) the executable for a named artifact.
    pub fn executable(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(Arc::clone(e));
        }
        let artifact = self
            .manifest
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?
            .clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            artifact
                .path
                .to_str()
                .context("artifact path not UTF-8")?,
        )
        .with_context(|| format!("parsing {}", artifact.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let compile_ms = t0.elapsed().as_secs_f64() * 1e3;
        log_debug!("runtime", "compiled {name} in {compile_ms:.1}ms");
        let e = Arc::new(Executable {
            artifact,
            exe,
            compile_ms,
        });
        // racing threads may have compiled concurrently; first in wins
        Ok(Arc::clone(
            self.cache
                .lock()
                .unwrap()
                .entry(name.to_string())
                .or_insert(e),
        ))
    }

    /// Find-and-compile by kind/variant/shape.
    pub fn executable_for(
        &self,
        kind: &str,
        variant: Option<&str>,
        height: usize,
        width: usize,
    ) -> Result<Arc<Executable>> {
        let name = self
            .manifest
            .find(kind, variant, height, width)
            .map(|a| a.name.clone())
            .with_context(|| {
                format!(
                    "no artifact kind={kind} variant={variant:?} \
                     shape={height}x{width}; available shapes: {:?}",
                    self.manifest.shapes(kind)
                )
            })?;
        self.executable(&name)
    }

    /// Warm the cache for a set of artifacts (serving cold-start control).
    pub fn warmup(&self, names: &[&str]) -> Result<f64> {
        let t0 = Instant::now();
        for n in names {
            self.executable(n)?;
        }
        Ok(t0.elapsed().as_secs_f64() * 1e3)
    }
}

// PJRT clients and executables are internally synchronized.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn compile_and_cache() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let rt = Runtime::new(dir).unwrap();
        assert_eq!(rt.cached_count(), 0);
        let e1 = rt.executable("compress_dct_200x200").unwrap();
        assert_eq!(rt.cached_count(), 1);
        let e2 = rt.executable("compress_dct_200x200").unwrap();
        assert!(Arc::ptr_eq(&e1, &e2), "second lookup must hit cache");
    }

    #[test]
    fn execute_compress_artifact() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let rt = Runtime::new(dir).unwrap();
        let exe = rt.executable("compress_dct_200x200").unwrap();
        let img: Vec<f32> =
            (0..200 * 200).map(|i| (i % 251) as f32).collect();
        let outs = exe.run_f32(&[(&img, 200, 200)]).unwrap();
        assert_eq!(outs.len(), 2, "recon + qcoef");
        assert_eq!(outs[0].len(), 200 * 200);
        // reconstruction stays in pixel range
        assert!(outs[0].iter().all(|&v| (0.0..=255.0).contains(&v)));
        // quantized coefficients are integers
        assert!(outs[1].iter().all(|&v| v.fract() == 0.0));
    }

    #[test]
    fn missing_artifact_errors() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let rt = Runtime::new(dir).unwrap();
        assert!(rt.executable("no_such_artifact").is_err());
        assert!(rt.executable_for("compress", Some("dct"), 7, 7).is_err());
    }
}
