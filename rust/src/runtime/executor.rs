//! Typed executor: the image-level API over the runtime backends.
//! Owns pad-to-artifact-shape / crop-back and literal marshaling; this is
//! the boundary the coordinator's GPU lane talks to.
//!
//! Since the planar-batch rework every compression job — gray or color —
//! is a [`PlanarBatch`] of 1 or 3 planes. [`Executor::compress_planar`]
//! runs the planes (in parallel when there are three: Y/Cb/Cr are
//! independent until reassembly), each through the backend's artifact
//! surface: the PJRT backend resolves one executable per padded plane
//! shape (`compress` for luma, `compress_chroma` for chroma); the stub
//! backend computes each plane bit-identically to the CPU lanes.
//! [`Executor::compress_color`] adds the RGB reassembly on top.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::codec::encoder::ScanCoefs;
use crate::dct::blocks::align8;
use crate::dct::color::PlaneCoef;
use crate::dct::planar::{Plane, PlanarBatch, PlaneRole};
use crate::dct::Variant;
use crate::image::color::ColorImage;
use crate::image::ycbcr::Subsampling;
use crate::image::GrayImage;
use crate::metrics::PSNR_CAP_DB;

use super::client::Runtime;

/// Artifact kind a plane role resolves to on the PJRT backend.
fn kind_for(role: PlaneRole) -> &'static str {
    match role {
        PlaneRole::Luma => "compress",
        PlaneRole::Chroma => "compress_chroma",
    }
}

/// Result of compressing one plane of a planar batch.
pub struct PlaneOutcome {
    /// Reconstruction cropped to the plane's pre-padding size.
    pub recon: GrayImage,
    /// Planar quantized coefficients at the padded plane shape (the
    /// f32 interchange layout).
    pub qcoef: Vec<f32>,
    /// The same coefficients in entropy-coding order — what the encoder
    /// consumes directly.
    pub scanned: ScanCoefs,
    pub padded_width: usize,
    pub padded_height: usize,
    /// Pure execute wall time for this plane (ms).
    pub execute_ms: f64,
}

impl PlaneOutcome {
    /// Split into (reconstruction, planar-interchange coefficients,
    /// scan-ordered coefficients) by move — no clone of the plane-sized
    /// buffers on the serving path.
    pub fn into_parts(self) -> (GrayImage, PlaneCoef, ScanCoefs) {
        let coef = PlaneCoef {
            qcoef: self.qcoef,
            width: self.scanned.width,
            height: self.scanned.height,
            padded_width: self.padded_width,
            padded_height: self.padded_height,
        };
        (self.recon, coef, self.scanned)
    }
}

/// Result of a planar-batch compression: one outcome per plane, in batch
/// order (Y or Y/Cb/Cr).
pub struct PlanarOutcome {
    pub planes: Vec<PlaneOutcome>,
    /// Wall time of the whole (possibly plane-parallel) execute section.
    pub execute_ms: f64,
}

/// Result of a GPU-lane grayscale compression.
pub struct CompressOutcome {
    /// Reconstruction cropped to the input size.
    pub recon: GrayImage,
    /// Planar quantized coefficients at the padded artifact shape.
    pub qcoef: Vec<f32>,
    /// Zigzag-ordered coefficients for the entropy encoder.
    pub scanned: ScanCoefs,
    pub padded_width: usize,
    pub padded_height: usize,
    /// Pure execute wall time (excludes padding/marshaling), ms.
    pub execute_ms: f64,
}

/// Result of a GPU-lane color compression — mirrors
/// `dct::color::ColorCompressOutput` so the coordinator emits identical
/// payloads regardless of lane.
pub struct ColorCompressOutcome {
    /// Reconstructed RGB image at the original size.
    pub recon: ColorImage,
    /// Full-resolution reconstructed luma plane.
    pub recon_y: GrayImage,
    /// Reconstructed chroma planes at their subsampled resolution.
    pub recon_cb: GrayImage,
    pub recon_cr: GrayImage,
    /// Planar interchange coefficients per plane, Y/Cb/Cr order.
    pub planes: [PlaneCoef; 3],
    /// Zigzag-ordered coefficients per plane for the entropy encoder.
    pub scanned: [ScanCoefs; 3],
    pub execute_ms: f64,
}

/// Image-level operations over the runtime.
pub struct Executor {
    pub rt: Arc<Runtime>,
}

impl Executor {
    pub fn new(rt: Arc<Runtime>) -> Executor {
        Executor { rt }
    }

    /// Pick the artifact shape for an image: exact padded size.
    fn padded_shape(&self, img: &GrayImage) -> (usize, usize) {
        (align8(img.height), align8(img.width))
    }

    /// Can this backend run a grayscale compress at the image's padded
    /// shape?
    pub fn supports_gray(&self, img_w: usize, img_h: usize,
                         variant: &str) -> bool {
        self.rt.supports(
            "compress",
            Some(variant),
            align8(img_h),
            align8(img_w),
        )
    }

    /// Can this backend run a color compress for a `w x h` RGB image at
    /// the given subsampling (all three padded plane shapes covered)?
    pub fn supports_color(
        &self,
        img_w: usize,
        img_h: usize,
        variant: &str,
        subsampling: Subsampling,
    ) -> bool {
        let shapes =
            PlanarBatch::color_padded_shapes(img_w, img_h, subsampling);
        let roles =
            [PlaneRole::Luma, PlaneRole::Chroma, PlaneRole::Chroma];
        shapes.iter().zip(roles).all(|(&(h, w), role)| {
            self.rt.supports(kind_for(role), Some(variant), h, w)
        })
    }

    /// Compress one plane on the backend (blocking; used by the
    /// plane-parallel fan-out).
    fn compress_plane(&self, plane: &Plane, variant: Variant)
                      -> Result<PlaneOutcome> {
        if let Some(stub) = self.rt.stub_backend() {
            // host-side: the exact CPU-lane pipeline (pads internally)
            let t0 = std::time::Instant::now();
            let out = stub.compress_plane(
                &plane.image,
                variant,
                plane.role,
            );
            let execute_ms = t0.elapsed().as_secs_f64() * 1e3;
            return Ok(PlaneOutcome {
                recon: out.recon,
                qcoef: out.qcoef,
                scanned: out.scanned,
                padded_width: out.padded_width,
                padded_height: out.padded_height,
                execute_ms,
            });
        }
        let (pw, ph) = plane.padded_dims();
        let exe = self.rt.executable_for(
            kind_for(plane.role),
            Some(variant.as_str()),
            ph,
            pw,
        )?;
        let input = plane.padded().to_f32();
        let t0 = std::time::Instant::now();
        let mut outs = exe.run_f32(&[(&input, ph, pw)])?;
        let execute_ms = t0.elapsed().as_secs_f64() * 1e3;
        anyhow::ensure!(outs.len() == 2, "compress emits (recon, qcoef)");
        let qcoef = outs.pop().expect("qcoef output");
        let recon_padded = GrayImage::from_f32(pw, ph, &outs[0])?;
        let (w, h) = (plane.image.width, plane.image.height);
        let recon = if (pw, ph) != (w, h) {
            recon_padded.crop(w, h)?
        } else {
            recon_padded
        };
        let scanned = ScanCoefs::from_planar(&qcoef, pw, ph, w, h);
        Ok(PlaneOutcome {
            recon,
            qcoef,
            scanned,
            padded_width: pw,
            padded_height: ph,
            execute_ms,
        })
    }

    /// Compress a planar batch: every plane through the backend, planes
    /// in parallel when there are several (Y/Cb/Cr are independent until
    /// reassembly).
    pub fn compress_planar(
        &self,
        batch: &PlanarBatch,
        variant: Variant,
    ) -> Result<PlanarOutcome> {
        anyhow::ensure!(!batch.is_empty(), "empty planar batch");
        let t0 = std::time::Instant::now();
        let outcomes: Vec<Result<PlaneOutcome>> =
            if batch.len() == 1 {
                vec![self.compress_plane(&batch.planes()[0], variant)]
            } else {
                std::thread::scope(|scope| {
                    let handles: Vec<_> = batch
                        .planes()
                        .iter()
                        .map(|p| {
                            scope.spawn(move || {
                                self.compress_plane(p, variant)
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("plane worker panicked"))
                        .collect()
                })
            };
        let execute_ms = t0.elapsed().as_secs_f64() * 1e3;
        let planes: Vec<PlaneOutcome> =
            outcomes.into_iter().collect::<Result<_>>()?;
        Ok(PlanarOutcome { planes, execute_ms })
    }

    /// Full grayscale compression pipeline on the backend lane.
    pub fn compress(&self, img: &GrayImage, variant: &str)
                    -> Result<CompressOutcome> {
        let variant = Variant::parse(variant)
            .with_context(|| format!("unknown variant '{variant}'"))?;
        let batch = PlanarBatch::from_gray(img);
        let out = self.compress_planar(&batch, variant)?;
        let execute_ms = out.execute_ms;
        let p = out.planes.into_iter().next().expect("one plane");
        Ok(CompressOutcome {
            recon: p.recon,
            qcoef: p.qcoef,
            scanned: p.scanned,
            padded_width: p.padded_width,
            padded_height: p.padded_height,
            execute_ms,
        })
    }

    /// Full color (YCbCr) compression pipeline on the backend lane:
    /// split/subsample exactly as the CPU color pipeline does, compress
    /// the three planes in parallel, reassemble the RGB reconstruction.
    pub fn compress_color(
        &self,
        img: &ColorImage,
        variant: Variant,
        subsampling: Subsampling,
    ) -> Result<ColorCompressOutcome> {
        let batch = PlanarBatch::from_color(img, subsampling);
        let out = self.compress_planar(&batch, variant)?;
        let execute_ms = out.execute_ms;
        let mut planes = out.planes;
        anyhow::ensure!(planes.len() == 3, "color batch has 3 planes");
        let (recon_cr, coef_cr, scan_cr) =
            planes.pop().expect("cr").into_parts();
        let (recon_cb, coef_cb, scan_cb) =
            planes.pop().expect("cb").into_parts();
        let (recon_y, coef_y, scan_y) =
            planes.pop().expect("y").into_parts();
        let recon =
            batch.reassemble_color(&recon_y, &recon_cb, &recon_cr)?;
        Ok(ColorCompressOutcome {
            recon,
            recon_y,
            recon_cb,
            recon_cr,
            planes: [coef_y, coef_cb, coef_cr],
            scanned: [scan_y, scan_cb, scan_cr],
            execute_ms,
        })
    }

    /// PSNR between two same-sized images on the backend lane.
    pub fn psnr(&self, a: &GrayImage, b: &GrayImage) -> Result<f64> {
        anyhow::ensure!(
            (a.width, a.height) == (b.width, b.height),
            "psnr over mismatched sizes"
        );
        if self.rt.is_stub() {
            // the stub handles unaligned shapes: no pad distortion
            let fa = a.to_f32();
            let fb = b.to_f32();
            let outs = self.rt.run_f32(
                "psnr",
                None,
                &[(&fa, a.height, a.width), (&fb, b.height, b.width)],
            )?;
            return Ok((outs[0][0] as f64).min(PSNR_CAP_DB));
        }
        let (ph, pw) = self.padded_shape(a);
        let exe = self.rt.executable_for("psnr", None, ph, pw)?;
        let (pa, pb) = if (pw, ph) != (a.width, a.height) {
            (a.pad_edge(pw, ph)?, b.pad_edge(pw, ph)?)
        } else {
            (a.clone(), b.clone())
        };
        let fa = pa.to_f32();
        let fb = pb.to_f32();
        let outs = exe.run_f32(&[(&fa, ph, pw), (&fb, ph, pw)])?;
        let v = *outs
            .first()
            .and_then(|o| o.first())
            .context("psnr output missing")?;
        Ok((v as f64).min(PSNR_CAP_DB))
    }

    /// Per-channel + luma color PSNR on the backend lane: every plane
    /// figure (R/G/B channels and the BT.601 luma plane) runs through
    /// the backend's `psnr` kind; the 6:1:1 Y/Cb/Cr-weighted figure is
    /// combined host-side from plane MSEs, since the backend emits
    /// PSNRs, not MSEs. This is what `cordic-dct psnr --color --lane
    /// gpu` emits as its JSON artifact.
    pub fn psnr_color(
        &self,
        a: &ColorImage,
        b: &ColorImage,
    ) -> Result<crate::metrics::color::ColorPsnr> {
        use crate::image::ycbcr::rgb_to_ycbcr;
        use crate::metrics::color::weighted_ycbcr_mse;
        use crate::metrics::{mse, psnr_from_mse};
        anyhow::ensure!(
            (a.width, a.height) == (b.width, b.height),
            "color psnr over mismatched sizes"
        );
        let (ya, cba, cra) = rgb_to_ycbcr(a);
        let (yb, cbb, crb) = rgb_to_ycbcr(b);
        let weighted_mse = weighted_ycbcr_mse(
            mse(&ya, &yb),
            mse(&cba, &cbb),
            mse(&cra, &crb),
        );
        Ok(crate::metrics::color::ColorPsnr {
            r: self.psnr(&a.channel(0), &b.channel(0))?,
            g: self.psnr(&a.channel(1), &b.channel(1))?,
            b: self.psnr(&a.channel(2), &b.channel(2))?,
            y: self.psnr(&ya, &yb)?,
            weighted: psnr_from_mse(weighted_mse, 255.0),
        })
    }

    /// Histogram equalization on the backend lane.
    pub fn histeq(&self, img: &GrayImage) -> Result<(GrayImage, f64)> {
        let (ph, pw) = self.padded_shape(img);
        let padded = if (pw, ph) != (img.width, img.height) {
            img.pad_edge(pw, ph)?
        } else {
            img.clone()
        };
        let input = padded.to_f32();
        let t0 = std::time::Instant::now();
        let outs = self.rt.run_f32("histeq", None, &[(&input, ph, pw)])?;
        let execute_ms = t0.elapsed().as_secs_f64() * 1e3;
        let out_padded = GrayImage::from_f32(pw, ph, &outs[0])?;
        let out = if (pw, ph) != (img.width, img.height) {
            out_padded.crop(img.width, img.height)?
        } else {
            out_padded
        };
        Ok((out, execute_ms))
    }

    /// Bare forward DCT (microbench entry; 512x512 artifacts only on the
    /// PJRT backend — the stub covers any 8-aligned shape).
    pub fn dct_only(&self, img: &GrayImage, variant: &str)
                    -> Result<Vec<f32>> {
        let (ph, pw) = self.padded_shape(img);
        let input = img.to_f32();
        let outs = self.rt.run_f32(
            "dct",
            Some(variant),
            &[(&input, ph, pw)],
        )?;
        Ok(outs.into_iter().next().context("dct output")?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::{histeq as cpu_histeq, synthetic};
    use crate::metrics;

    fn executor() -> Option<Executor> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Executor::new(Arc::new(Runtime::new(dir).unwrap())))
    }

    fn stub_executor(quality: u8) -> Executor {
        Executor::new(Arc::new(Runtime::stub(quality)))
    }

    #[test]
    fn compress_matches_cpu_lane() {
        let Some(ex) = executor() else { return };
        let img = synthetic::lena_like(200, 200, 1);
        let gpu = ex.compress(&img, "dct").unwrap();
        let cpu = crate::dct::pipeline::CpuPipeline::new(
            crate::dct::Variant::Dct,
            50,
        )
        .compress(&img);
        // identical arithmetic up to XLA reduction-order ties
        let p_cross = metrics::psnr(&gpu.recon, &cpu.recon);
        assert!(p_cross > 50.0, "lanes disagree: {p_cross} dB");
        let p_gpu = metrics::psnr(&img, &gpu.recon);
        let p_cpu = metrics::psnr(&img, &cpu.recon);
        assert!((p_gpu - p_cpu).abs() < 0.2, "{p_gpu} vs {p_cpu}");
    }

    #[test]
    fn cordic_lane_matches_cpu_cordic() {
        let Some(ex) = executor() else { return };
        let img = synthetic::lena_like(200, 200, 2);
        let gpu = ex.compress(&img, "cordic").unwrap();
        let cpu = crate::dct::pipeline::CpuPipeline::new(
            crate::dct::Variant::Cordic,
            50,
        )
        .compress(&img);
        let p_cross = metrics::psnr(&gpu.recon, &cpu.recon);
        assert!(p_cross > 45.0, "cordic lanes disagree: {p_cross} dB");
    }

    #[test]
    fn psnr_lane_matches_cpu_metric() {
        let Some(ex) = executor() else { return };
        let a = synthetic::lena_like(200, 200, 3);
        let b = synthetic::cablecar_like(200, 200, 3);
        let gpu = ex.psnr(&a, &b).unwrap();
        let cpu = metrics::psnr(&a, &b);
        assert!((gpu - cpu).abs() < 0.01, "{gpu} vs {cpu}");
        let same = ex.psnr(&a, &a).unwrap();
        assert_eq!(same, crate::metrics::PSNR_CAP_DB);
    }

    #[test]
    fn histeq_lane_matches_cpu() {
        let Some(ex) = executor() else { return };
        let img = synthetic::cablecar_like(200, 200, 4);
        let (gpu, _ms) = ex.histeq(&img).unwrap();
        let cpu = cpu_histeq::histeq(&img);
        let diff = gpu
            .data
            .iter()
            .zip(&cpu.data)
            .filter(|(a, b)| a != b)
            .count();
        assert!(
            diff * 1000 < img.pixels(),
            "{diff} of {} pixels differ",
            img.pixels()
        );
    }

    #[test]
    fn unpadded_shape_uses_pad_crop() {
        let Some(ex) = executor() else { return };
        // 1024x814 -> padded artifact 1024x816
        let img = synthetic::lena_like(814, 1024, 5);
        let out = ex.compress(&img, "dct").unwrap();
        assert_eq!((out.recon.width, out.recon.height), (814, 1024));
        assert_eq!((out.padded_width, out.padded_height), (816, 1024));
        assert!(metrics::psnr(&img, &out.recon) > 28.0);
    }

    #[test]
    fn stub_gray_compress_bit_identical_to_cpu_lane() {
        let ex = stub_executor(50);
        let img = synthetic::lena_like(30, 21, 6);
        let gpu = ex.compress(&img, "cordic").unwrap();
        let cpu = crate::dct::pipeline::CpuPipeline::new(
            crate::dct::Variant::Cordic,
            50,
        )
        .compress(&img);
        assert_eq!(gpu.recon, cpu.recon);
        assert_eq!(gpu.qcoef, cpu.qcoef);
        assert_eq!(gpu.scanned, cpu.scanned);
        assert_eq!(
            (gpu.padded_width, gpu.padded_height),
            (cpu.padded_width, cpu.padded_height)
        );
    }

    #[test]
    fn stub_color_compress_bit_identical_to_color_pipeline() {
        use crate::dct::color::ColorPipeline;
        use crate::dct::Variant;
        let ex = stub_executor(50);
        let img = synthetic::lena_like_rgb(30, 21, 7);
        let gpu = ex
            .compress_color(&img, Variant::Cordic, Subsampling::S420)
            .unwrap();
        let cpu =
            ColorPipeline::new(Variant::Cordic, 50, Subsampling::S420)
                .compress(&img);
        assert_eq!(gpu.recon, cpu.recon);
        assert_eq!(gpu.recon_y, cpu.recon_y);
        assert_eq!(gpu.recon_cb, cpu.recon_cb);
        assert_eq!(gpu.recon_cr, cpu.recon_cr);
        assert_eq!(gpu.planes, cpu.planes);
        assert_eq!(gpu.scanned, cpu.scanned);
    }

    #[test]
    fn stub_psnr_color_matches_cpu_metric() {
        use crate::metrics::color::psnr_color as cpu_psnr_color;
        let ex = stub_executor(50);
        let a = synthetic::lena_like_rgb(30, 21, 5);
        let b = synthetic::cablecar_like_rgb(30, 21, 5);
        let gpu = ex.psnr_color(&a, &b).unwrap();
        let cpu = cpu_psnr_color(&a, &b);
        // plane figures round-trip through the backend's f32 output
        assert!((gpu.r - cpu.r).abs() < 1e-4);
        assert!((gpu.g - cpu.g).abs() < 1e-4);
        assert!((gpu.b - cpu.b).abs() < 1e-4);
        assert!((gpu.y - cpu.y).abs() < 1e-4);
        // the weighted figure is combined host-side: exact
        assert_eq!(gpu.weighted, cpu.weighted);
        let capped = ex.psnr_color(&a, &a).unwrap();
        assert_eq!(capped.weighted, metrics::PSNR_CAP_DB);
    }

    #[test]
    fn stub_supports_gray_and_color() {
        let ex = stub_executor(50);
        assert!(ex.supports_gray(30, 21, "cordic"));
        assert!(ex.supports_color(30, 21, "dct", Subsampling::S420));
        let (out, _ms) = ex
            .histeq(&synthetic::cablecar_like(24, 24, 1))
            .unwrap();
        assert_eq!(
            out,
            cpu_histeq::histeq(&synthetic::cablecar_like(24, 24, 1))
        );
        // unaligned psnr runs without pad distortion on the stub
        let a = synthetic::lena_like(30, 21, 2);
        let b = synthetic::cablecar_like(30, 21, 2);
        let p = ex.psnr(&a, &b).unwrap();
        assert!((p - metrics::psnr(&a, &b)).abs() < 1e-4);
        assert_eq!(ex.psnr(&a, &a).unwrap(), metrics::PSNR_CAP_DB);
    }
}
