//! Typed executor: the GrayImage-level API over the PJRT runtime.
//! Owns pad-to-artifact-shape / crop-back and literal marshaling; this is
//! the boundary the coordinator's GPU lane talks to.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::dct::blocks::align8;
use crate::image::GrayImage;
use crate::metrics::PSNR_CAP_DB;

use super::client::Runtime;

/// Result of a GPU-lane compression.
pub struct CompressOutcome {
    /// Reconstruction cropped to the input size.
    pub recon: GrayImage,
    /// Planar quantized coefficients at the padded artifact shape.
    pub qcoef: Vec<f32>,
    pub padded_width: usize,
    pub padded_height: usize,
    /// Pure execute wall time (excludes padding/marshaling), ms.
    pub execute_ms: f64,
}

/// GrayImage-level operations over the runtime.
pub struct Executor {
    pub rt: Arc<Runtime>,
}

impl Executor {
    pub fn new(rt: Arc<Runtime>) -> Executor {
        Executor { rt }
    }

    /// Pick the artifact shape for an image: exact padded size.
    fn padded_shape(&self, img: &GrayImage) -> (usize, usize) {
        (align8(img.height), align8(img.width))
    }

    /// Full compression pipeline on the PJRT lane.
    pub fn compress(&self, img: &GrayImage, variant: &str)
                    -> Result<CompressOutcome> {
        let (ph, pw) = self.padded_shape(img);
        let exe = self
            .rt
            .executable_for("compress", Some(variant), ph, pw)?;
        let padded = if (pw, ph) != (img.width, img.height) {
            img.pad_edge(pw, ph)?
        } else {
            img.clone()
        };
        let input = padded.to_f32();
        let t0 = std::time::Instant::now();
        let mut outs = exe.run_f32(&[(&input, ph, pw)])?;
        let execute_ms = t0.elapsed().as_secs_f64() * 1e3;
        anyhow::ensure!(outs.len() == 2, "compress emits (recon, qcoef)");
        let qcoef = outs.pop().expect("qcoef output");
        let recon_padded = GrayImage::from_f32(pw, ph, &outs[0])?;
        let recon = if (pw, ph) != (img.width, img.height) {
            recon_padded.crop(img.width, img.height)?
        } else {
            recon_padded
        };
        Ok(CompressOutcome {
            recon,
            qcoef,
            padded_width: pw,
            padded_height: ph,
            execute_ms,
        })
    }

    /// PSNR between two same-sized images on the PJRT lane.
    pub fn psnr(&self, a: &GrayImage, b: &GrayImage) -> Result<f64> {
        anyhow::ensure!(
            (a.width, a.height) == (b.width, b.height),
            "psnr over mismatched sizes"
        );
        let (ph, pw) = self.padded_shape(a);
        let exe = self.rt.executable_for("psnr", None, ph, pw)?;
        let (pa, pb) = if (pw, ph) != (a.width, a.height) {
            (a.pad_edge(pw, ph)?, b.pad_edge(pw, ph)?)
        } else {
            (a.clone(), b.clone())
        };
        let fa = pa.to_f32();
        let fb = pb.to_f32();
        let outs = exe.run_f32(&[(&fa, ph, pw), (&fb, ph, pw)])?;
        let v = *outs
            .first()
            .and_then(|o| o.first())
            .context("psnr output missing")?;
        Ok((v as f64).min(PSNR_CAP_DB))
    }

    /// Histogram equalization on the PJRT lane.
    pub fn histeq(&self, img: &GrayImage) -> Result<(GrayImage, f64)> {
        let (ph, pw) = self.padded_shape(img);
        let exe = self.rt.executable_for("histeq", None, ph, pw)?;
        let padded = if (pw, ph) != (img.width, img.height) {
            img.pad_edge(pw, ph)?
        } else {
            img.clone()
        };
        let input = padded.to_f32();
        let t0 = std::time::Instant::now();
        let outs = exe.run_f32(&[(&input, ph, pw)])?;
        let execute_ms = t0.elapsed().as_secs_f64() * 1e3;
        let out_padded = GrayImage::from_f32(pw, ph, &outs[0])?;
        let out = if (pw, ph) != (img.width, img.height) {
            out_padded.crop(img.width, img.height)?
        } else {
            out_padded
        };
        Ok((out, execute_ms))
    }

    /// Bare forward DCT (microbench entry; 512x512 artifacts only).
    pub fn dct_only(&self, img: &GrayImage, variant: &str)
                    -> Result<Vec<f32>> {
        let (ph, pw) = self.padded_shape(img);
        let exe = self.rt.executable_for("dct", Some(variant), ph, pw)?;
        let input = img.to_f32();
        let outs = exe.run_f32(&[(&input, ph, pw)])?;
        Ok(outs.into_iter().next().context("dct output")?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::{histeq as cpu_histeq, synthetic};
    use crate::metrics;

    fn executor() -> Option<Executor> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Executor::new(Arc::new(Runtime::new(dir).unwrap())))
    }

    #[test]
    fn compress_matches_cpu_lane() {
        let Some(ex) = executor() else { return };
        let img = synthetic::lena_like(200, 200, 1);
        let gpu = ex.compress(&img, "dct").unwrap();
        let cpu = crate::dct::pipeline::CpuPipeline::new(
            crate::dct::Variant::Dct,
            50,
        )
        .compress(&img);
        // identical arithmetic up to XLA reduction-order ties
        let p_cross = metrics::psnr(&gpu.recon, &cpu.recon);
        assert!(p_cross > 50.0, "lanes disagree: {p_cross} dB");
        let p_gpu = metrics::psnr(&img, &gpu.recon);
        let p_cpu = metrics::psnr(&img, &cpu.recon);
        assert!((p_gpu - p_cpu).abs() < 0.2, "{p_gpu} vs {p_cpu}");
    }

    #[test]
    fn cordic_lane_matches_cpu_cordic() {
        let Some(ex) = executor() else { return };
        let img = synthetic::lena_like(200, 200, 2);
        let gpu = ex.compress(&img, "cordic").unwrap();
        let cpu = crate::dct::pipeline::CpuPipeline::new(
            crate::dct::Variant::Cordic,
            50,
        )
        .compress(&img);
        let p_cross = metrics::psnr(&gpu.recon, &cpu.recon);
        assert!(p_cross > 45.0, "cordic lanes disagree: {p_cross} dB");
    }

    #[test]
    fn psnr_lane_matches_cpu_metric() {
        let Some(ex) = executor() else { return };
        let a = synthetic::lena_like(200, 200, 3);
        let b = synthetic::cablecar_like(200, 200, 3);
        let gpu = ex.psnr(&a, &b).unwrap();
        let cpu = metrics::psnr(&a, &b);
        assert!((gpu - cpu).abs() < 0.01, "{gpu} vs {cpu}");
        let same = ex.psnr(&a, &a).unwrap();
        assert_eq!(same, crate::metrics::PSNR_CAP_DB);
    }

    #[test]
    fn histeq_lane_matches_cpu() {
        let Some(ex) = executor() else { return };
        let img = synthetic::cablecar_like(200, 200, 4);
        let (gpu, _ms) = ex.histeq(&img).unwrap();
        let cpu = cpu_histeq::histeq(&img);
        let diff = gpu
            .data
            .iter()
            .zip(&cpu.data)
            .filter(|(a, b)| a != b)
            .count();
        assert!(
            diff * 1000 < img.pixels(),
            "{diff} of {} pixels differ",
            img.pixels()
        );
    }

    #[test]
    fn unpadded_shape_uses_pad_crop() {
        let Some(ex) = executor() else { return };
        // 1024x814 -> padded artifact 1024x816
        let img = synthetic::lena_like(814, 1024, 5);
        let out = ex.compress(&img, "dct").unwrap();
        assert_eq!((out.recon.width, out.recon.height), (814, 1024));
        assert_eq!((out.padded_width, out.padded_height), (816, 1024));
        assert!(metrics::psnr(&img, &out.recon) > 28.0);
    }
}
