//! The runtime: the paper's "GPU lane" — the massively-parallel kernel
//! path — adapted per DESIGN.md §Hardware-Adaptation, behind a backend
//! switch.
//!
//! * [`manifest`] — parses `artifacts/manifest.json`, resolves artifacts
//!   by kind/variant/shape (PJRT backend).
//! * [`client`] — the [`Runtime`]: either the PJRT client wrapper with a
//!   compiled-executable cache (compilation is milliseconds-to-seconds;
//!   serving amortizes it), or the host-side stub backend.
//! * [`stub`] — the stub backend: every artifact kind computed with the
//!   CPU lanes' batched engine, bit-identical to the CPU pipelines, so
//!   the GPU lane serves (and is tested) without artifacts.
//! * [`executor`] — typed entry points over
//!   [`PlanarBatch`](crate::dct::planar::PlanarBatch) jobs: gray and
//!   color compress (plane-parallel), psnr, histeq — including
//!   pad/crop and literal marshaling.

pub mod client;
pub mod executor;
pub mod manifest;
pub mod stub;

pub use client::Runtime;
pub use executor::{
    ColorCompressOutcome, CompressOutcome, Executor, PlanarOutcome,
    PlaneOutcome,
};
pub use manifest::{Artifact, Manifest};
pub use stub::StubBackend;
