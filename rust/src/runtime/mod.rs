//! The PJRT runtime: loads AOT-compiled HLO-text artifacts (produced by
//! `make artifacts` from the JAX/Pallas layers) and executes them on the
//! XLA CPU client. This is the paper's "GPU lane" — the massively-parallel
//! kernel path — adapted per DESIGN.md §Hardware-Adaptation.
//!
//! * [`manifest`] — parses `artifacts/manifest.json`, resolves artifacts
//!   by kind/variant/shape.
//! * [`client`] — PJRT client wrapper with a compiled-executable cache
//!   (compilation is milliseconds-to-seconds; serving amortizes it).
//! * [`executor`] — typed entry points: compress / psnr / histeq over
//!   `GrayImage`s, including pad/crop and literal marshaling.

pub mod client;
pub mod executor;
pub mod manifest;

pub use client::Runtime;
pub use executor::{CompressOutcome, Executor};
pub use manifest::{Artifact, Manifest};
