//! The stub backend: a host-side, bit-exact stand-in for the PJRT
//! executables, built directly on the CPU lanes' batched block engine.
//!
//! Two jobs:
//!
//! 1. **Offline serving/testing.** This build environment has no PJRT
//!    runtime (the vendored `xla` crate is a compile-time stub), so the
//!    GPU lane would otherwise be dead code. `Runtime::stub` swaps in
//!    this backend: every artifact "kind" the manifest would offer
//!    (`compress`, `psnr`, `histeq`, `dct`) is computed host-side with
//!    the exact arithmetic of the CPU lanes, so the whole coordinator /
//!    planar-batch / entropy path exercises end-to-end — and parity
//!    against the CPU lanes is *bit-identical*, which the real PJRT
//!    artifacts (XLA reduction-order ties) cannot promise.
//! 2. **Uniform planar consumption.** The stub consumes the same
//!    [`PlanarBatch`](crate::dct::planar::PlanarBatch) plane shape the
//!    PJRT path marshals, walking every plane's block grid through
//!    [`BlockBatch8`](crate::dct::batch::BlockBatch8) gathers via the
//!    [`BatchEngine`](crate::dct::batch::BatchEngine)-backed
//!    [`CpuPipeline`] — the CPU mirror of the GPU's thread-per-block
//!    mapping.
//!
//! Pipelines are cached per `(variant, role)` the way the PJRT client
//! caches compiled executables, and shared across worker threads.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::dct::pipeline::{CpuCompressOutput, CpuPipeline};
use crate::dct::planar::PlaneRole;
use crate::dct::quant::{effective_qtable, effective_qtable_chroma};
use crate::dct::Variant;
use crate::image::GrayImage;

/// Host-side executable cache: the stub's analogue of the PJRT
/// compiled-executable cache.
pub struct StubBackend {
    /// IJG quality every "artifact" of this backend quantizes at (the
    /// manifest-level quality of the PJRT path).
    pub quality: u8,
    pipelines: Mutex<HashMap<(Variant, PlaneRole), Arc<CpuPipeline>>>,
}

impl StubBackend {
    pub fn new(quality: u8) -> StubBackend {
        StubBackend {
            quality,
            pipelines: Mutex::new(HashMap::new()),
        }
    }

    /// Number of cached host-side pipelines (mirrors
    /// `Runtime::cached_count` for the PJRT backend).
    pub fn cached_count(&self) -> usize {
        self.pipelines.lock().unwrap().len()
    }

    /// Get (building if needed) the pipeline for a variant and plane
    /// role. Luma planes quantize with the Annex K luma table, chroma
    /// planes with the chroma table — exactly as
    /// [`ColorPipeline`](crate::dct::color::ColorPipeline) wires its
    /// per-plane pipelines, which is what makes stub GPU output
    /// bit-identical to the CPU lanes.
    pub fn pipeline(
        &self,
        variant: Variant,
        role: PlaneRole,
    ) -> Arc<CpuPipeline> {
        Arc::clone(
            self.pipelines
                .lock()
                .unwrap()
                .entry((variant, role))
                .or_insert_with(|| {
                    let qtable = match role {
                        PlaneRole::Luma => effective_qtable(self.quality),
                        PlaneRole::Chroma => {
                            effective_qtable_chroma(self.quality)
                        }
                    };
                    Arc::new(CpuPipeline::with_qtable(
                        variant,
                        self.quality,
                        qtable,
                    ))
                }),
        )
    }

    /// Compress one plane (bit-identical to the serial CPU lane).
    pub fn compress_plane(
        &self,
        img: &GrayImage,
        variant: Variant,
        role: PlaneRole,
    ) -> CpuCompressOutput {
        self.pipeline(variant, role).compress(img)
    }

    /// The raw `run_f32` artifact surface, host-side: dispatches on the
    /// artifact kind the PJRT manifest would resolve. Inputs are rank-2
    /// f32 planes `(buf, h, w)` with 8-aligned dims for block kinds.
    pub fn run_f32(
        &self,
        kind: &str,
        variant: Option<&str>,
        inputs: &[(&[f32], usize, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        let parse_variant = || -> Result<Variant> {
            let v = variant.unwrap_or("dct");
            Variant::parse(v)
                .with_context(|| format!("unknown variant '{v}'"))
        };
        match kind {
            "compress" | "compress_chroma" => {
                let role = if kind == "compress" {
                    PlaneRole::Luma
                } else {
                    PlaneRole::Chroma
                };
                let (buf, h, w) = single_input(kind, inputs)?;
                let img = GrayImage::from_f32(w, h, buf)?;
                let out =
                    self.compress_plane(&img, parse_variant()?, role);
                Ok(vec![out.recon.to_f32(), out.qcoef])
            }
            "psnr" => {
                anyhow::ensure!(
                    inputs.len() == 2,
                    "psnr takes two inputs"
                );
                let (ba, ha, wa) = inputs[0];
                let (bb, hb, wb) = inputs[1];
                let a = GrayImage::from_f32(wa, ha, ba)?;
                let b = GrayImage::from_f32(wb, hb, bb)?;
                anyhow::ensure!(
                    (wa, ha) == (wb, hb),
                    "psnr over mismatched sizes"
                );
                Ok(vec![vec![crate::metrics::psnr(&a, &b) as f32]])
            }
            "histeq" => {
                let (buf, h, w) = single_input(kind, inputs)?;
                let img = GrayImage::from_f32(w, h, buf)?;
                Ok(vec![crate::image::histeq::histeq(&img).to_f32()])
            }
            "dct" => {
                let (buf, h, w) = single_input(kind, inputs)?;
                let img = GrayImage::from_f32(w, h, buf)?;
                let t = parse_variant()?.transform();
                let mut out = vec![0.0f32; w * h];
                let (gw, gh) = crate::dct::blocks::grid_dims(w, h);
                let mut blk = [0.0f32; 64];
                for by in 0..gh {
                    for bx in 0..gw {
                        crate::dct::blocks::extract_block(
                            &img, bx, by, &mut blk,
                        );
                        t.forward(&mut blk);
                        for r in 0..8 {
                            let dst = (by * 8 + r) * w + bx * 8;
                            out[dst..dst + 8].copy_from_slice(
                                &blk[r * 8..r * 8 + 8],
                            );
                        }
                    }
                }
                Ok(vec![out])
            }
            other => bail!("stub backend has no kind '{other}'"),
        }
    }
}

fn single_input<'a>(
    kind: &str,
    inputs: &[(&'a [f32], usize, usize)],
) -> Result<(&'a [f32], usize, usize)> {
    anyhow::ensure!(inputs.len() == 1, "{kind} takes one input");
    let (buf, h, w) = inputs[0];
    anyhow::ensure!(buf.len() == h * w, "input buffer {} != {h}x{w}",
                    buf.len());
    Ok((buf, h, w))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synthetic;

    #[test]
    fn pipeline_cache_by_variant_and_role() {
        let s = StubBackend::new(50);
        assert_eq!(s.cached_count(), 0);
        let a = s.pipeline(Variant::Dct, PlaneRole::Luma);
        let b = s.pipeline(Variant::Dct, PlaneRole::Luma);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit cache");
        s.pipeline(Variant::Dct, PlaneRole::Chroma);
        s.pipeline(Variant::Cordic, PlaneRole::Luma);
        assert_eq!(s.cached_count(), 3);
    }

    #[test]
    fn compress_kind_matches_cpu_lane_bitwise() {
        let s = StubBackend::new(50);
        let img = synthetic::lena_like(32, 24, 1);
        let outs = s
            .run_f32("compress", Some("cordic"), &[(&img.to_f32(), 24, 32)])
            .unwrap();
        let cpu = CpuPipeline::new(Variant::Cordic, 50).compress(&img);
        assert_eq!(outs[0], cpu.recon.to_f32());
        assert_eq!(outs[1], cpu.qcoef);
    }

    #[test]
    fn psnr_and_histeq_kinds() {
        let s = StubBackend::new(50);
        let a = synthetic::lena_like(16, 16, 2);
        let b = synthetic::cablecar_like(16, 16, 2);
        let (fa, fb) = (a.to_f32(), b.to_f32());
        let p = s
            .run_f32("psnr", None, &[(&fa, 16, 16), (&fb, 16, 16)])
            .unwrap();
        assert!((p[0][0] as f64 - crate::metrics::psnr(&a, &b)).abs()
                < 1e-4);
        let eq = s.run_f32("histeq", None, &[(&fa, 16, 16)]).unwrap();
        assert_eq!(
            eq[0],
            crate::image::histeq::histeq(&a).to_f32()
        );
        assert!(s.run_f32("nope", None, &[(&fa, 16, 16)]).is_err());
    }
}
