//! # cordic-dct
//!
//! A Rust + JAX/Pallas reproduction of *"CUDA Based Performance Evaluation
//! of the Computational Efficiency of the DCT Image Compression Technique
//! on Both the CPU and GPU"* (Modieginyane, Ncube, Gasela, 2013).
//!
//! The paper's CUDA/GPU lane is rebuilt as AOT-compiled XLA executables
//! (JAX + Pallas kernels lowered to HLO text at build time, loaded and run
//! by the PJRT CPU client at serve time); the paper's serial-CPU lane is
//! rebuilt as scalar Rust in [`dct`]. The [`coordinator`] is the serving
//! layer: a request router + dynamic batcher + worker pool dispatching
//! images to either lane. See DESIGN.md for the full system inventory and
//! the hardware-adaptation argument.
//!
//! ## Layers
//!
//! * [`util`] — substrates the offline environment forces us to own: JSON,
//!   CLI parsing, PRNG, thread pool, bit I/O, timers, a property-test
//!   harness.
//! * [`image`] — grayscale image type, PGM/PPM/BMP/PNG codecs, synthetic
//!   test-image generators (the Lena / Cable-car stand-ins), resize,
//!   histogram equalization.
//! * [`dct`] — the transform substrate: naive / matrix / Loeffler /
//!   Cordic-based-Loeffler 8x8 DCTs, JPEG quantization, block management.
//! * [`codec`] — a complete entropy codec (zigzag, DC-DPCM + AC-RLE,
//!   canonical Huffman, bitstream container) turning quantized
//!   coefficients into a real compressed file format.
//! * [`metrics`] — MSE / PSNR / SSIM and latency statistics.
//! * [`runtime`] — the PJRT side: artifact manifest, executable cache,
//!   literal marshaling.
//! * [`coordinator`] — router, batcher, worker pool, service facade.
//! * [`bench`] — the measurement harness and the paper-table formatters
//!   used by `cargo bench` targets.

pub mod bench;
pub mod codec;
pub mod coordinator;
pub mod dct;
pub mod image;
pub mod metrics;
pub mod runtime;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
