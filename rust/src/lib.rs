//! # cordic-dct
//!
//! A Rust + JAX/Pallas reproduction of *"CUDA Based Performance Evaluation
//! of the Computational Efficiency of the DCT Image Compression Technique
//! on Both the CPU and GPU"* (Modieginyane, Ncube, Gasela, 2013).
//!
//! The paper's CUDA/GPU lane is rebuilt as AOT-compiled XLA executables
//! (JAX + Pallas kernels lowered to HLO text at build time, loaded and run
//! by the PJRT CPU client at serve time); the paper's serial-CPU lane is
//! rebuilt as scalar Rust in [`dct`]. The [`coordinator`] is the serving
//! layer: a request router + dynamic batcher + worker pool dispatching
//! images across three lanes. See DESIGN.md for the full system inventory
//! and the hardware-adaptation argument.
//!
//! ## The three lanes
//!
//! | lane          | gray                                    | color |
//! |---------------|-----------------------------------------|-------|
//! | `Cpu`         | [`dct::pipeline::CpuPipeline`], one thread — the paper's "CPU serial code" baseline | [`dct::color::ColorPipeline`] over serial plane pipelines |
//! | `CpuParallel` | [`dct::parallel::ParallelCpuPipeline`], row-band tiles over scoped threads; bit-identical to `Cpu` | `ColorPipeline` over parallel plane pipelines |
//! | `Gpu`         | [`runtime::Executor`] over the backend's artifact surface (planar batch of 1) | `Executor::compress_color` (planar batch of 3, planes in parallel) |
//!
//! The parallel lane exists because comparing CUDA against one core
//! flatters the GPU; it runs the *same arithmetic* as the serial lane
//! (asserted bit-exact by `tests/parallel_parity.rs`) so the three-way
//! comparison isolates scheduling from numerics. `Lane::Auto` routes to
//! `Gpu` when the backend covers the job — for gray, the artifact (or
//! stub kind) at the padded shape; for color, all three padded plane
//! shapes — else `Cpu`. See `ARCHITECTURE.md` for the full data-flow
//! and batch-layout diagrams, and `docs/api/` for generated per-module
//! API references (`cargo xtask doc-md`).
//!
//! ## The color workload
//!
//! The paper evaluates grayscale only; the color path extends the same
//! Cordic-Loeffler pipeline to RGB by splitting into BT.601 YCbCr planes
//! (luma + optionally subsampled chroma) — the shared
//! [`dct::planar::split_ycbcr`] decomposition every lane starts from —
//! running the *unchanged* grayscale pipeline per plane with the Annex K
//! luma/chroma quantization tables, and entropy-coding the three planes
//! into one `CDC3` container ([`codec::color`]), fed from the fused
//! zigzag output ([`codec::encoder::ScanCoefs`]). On an `R = G = B`
//! input at 4:4:4 the luma path is bit-identical to the grayscale
//! pipeline (`tests/color_parity.rs`); [`dct::planar::PlanarBatch`] (1
//! or 3 planes) is the uniform job shape the GPU lane consumes, with
//! stub-backend output bit-identical to the CPU lanes
//! (`tests/gpu_color_parity.rs`).
//!
//! ## Layers
//!
//! * [`util`] — substrates the offline environment forces us to own: JSON,
//!   CLI parsing, PRNG, thread pool, bit I/O, timers, a property-test
//!   harness.
//! * [`image`] — grayscale + interleaved-RGB image types, PGM/PPM/BMP/PNG
//!   codecs (gray and color), BT.601 YCbCr conversion with chroma
//!   subsampling, synthetic test-image generators (the Lena / Cable-car
//!   stand-ins, gray and colorized), resize, histogram equalization.
//! * [`dct`] — the transform substrate: naive / matrix / Loeffler /
//!   Cordic-based-Loeffler / fixed-point `cordic-fxp` 8x8 DCTs, JPEG
//!   quantization (luma + chroma tables), block management, the serial +
//!   block-parallel CPU pipelines and the per-plane color pipeline. Both
//!   CPU lanes run their block loops on [`dct::batch`], the
//!   width-generic lane-major SoA engine (8- or 16-wide, dispatched per
//!   engine via [`dct::batch::BatchWidth`]; bit-identical to the scalar
//!   sequence at either width, one block per SIMD lane). The
//!   [`dct::cordic_fxp`] variant is the one approximate lane: an i32
//!   shift-add CORDIC datapath with configurable precision, PSNR-bound
//!   rather than bit-parity-bound.
//! * [`codec`] — a complete entropy codec (zigzag, DC-DPCM + AC-RLE,
//!   canonical Huffman, bitstream container) turning quantized
//!   coefficients into a real compressed file format; `CDC1` grayscale
//!   and `CDC3` color containers.
//! * [`metrics`] — MSE / PSNR / SSIM, per-channel + luma-weighted color
//!   metrics, and latency statistics.
//! * [`faults`] — deterministic, seeded fault injection for chaos
//!   testing: socket-level slow/short reads and writes, mid-frame
//!   disconnects, outbound bit-flips, worker panics and artificial job
//!   latency — all behind an `Option` so production paths pay nothing
//!   when no plan is configured.
//! * [`runtime`] — the GPU lane: artifact manifest, PJRT executable
//!   cache, the bit-exact stub backend, and the planar-batch executor
//!   (gray + color, plane-parallel).
//! * [`coordinator`] — router, per-lane batcher, worker pool, service
//!   facade over all three lanes (gray and color compress, decode,
//!   histeq requests).
//! * [`serve`] — the TCP front-end over the coordinator: length-prefixed
//!   binary framing, admission control + structured overload replies,
//!   per-connection timeouts, a blocking client plus a retrying,
//!   circuit-breaking variant, load-shedding `Degraded` replies, and
//!   the load generator behind `ablation_serve_load` and
//!   `ablation_chaos`.
//! * [`bench`] — the measurement harness and the paper-table formatters
//!   used by `cargo bench` targets (now with serial/parallel/GPU columns).

pub mod bench;
pub mod codec;
pub mod coordinator;
pub mod dct;
pub mod faults;
pub mod image;
pub mod metrics;
pub mod runtime;
pub mod serve;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
