//! # cordic-dct
//!
//! A Rust + JAX/Pallas reproduction of *"CUDA Based Performance Evaluation
//! of the Computational Efficiency of the DCT Image Compression Technique
//! on Both the CPU and GPU"* (Modieginyane, Ncube, Gasela, 2013).
//!
//! The paper's CUDA/GPU lane is rebuilt as AOT-compiled XLA executables
//! (JAX + Pallas kernels lowered to HLO text at build time, loaded and run
//! by the PJRT CPU client at serve time); the paper's serial-CPU lane is
//! rebuilt as scalar Rust in [`dct`]. The [`coordinator`] is the serving
//! layer: a request router + dynamic batcher + worker pool dispatching
//! images across three lanes. See DESIGN.md for the full system inventory
//! and the hardware-adaptation argument.
//!
//! ## The three lanes
//!
//! | lane          | implementation                          | role |
//! |---------------|-----------------------------------------|------|
//! | `Cpu`         | [`dct::pipeline::CpuPipeline`], one thread | the paper's "CPU serial code" baseline |
//! | `CpuParallel` | [`dct::parallel::ParallelCpuPipeline`], row-band tiles over scoped threads | the fair multi-core CPU number; bit-identical to `Cpu` |
//! | `Gpu`         | [`runtime::Executor`] over cached PJRT executables | the paper's CUDA lane |
//!
//! The parallel lane exists because comparing CUDA against one core
//! flatters the GPU; it runs the *same arithmetic* as the serial lane
//! (asserted bit-exact by `tests/parallel_parity.rs`) so the three-way
//! comparison isolates scheduling from numerics. `Lane::Auto` routes to
//! `Gpu` when an artifact covers the padded shape, else `Cpu`.
//!
//! ## Layers
//!
//! * [`util`] — substrates the offline environment forces us to own: JSON,
//!   CLI parsing, PRNG, thread pool, bit I/O, timers, a property-test
//!   harness.
//! * [`image`] — grayscale image type, PGM/PPM/BMP/PNG codecs, synthetic
//!   test-image generators (the Lena / Cable-car stand-ins), resize,
//!   histogram equalization.
//! * [`dct`] — the transform substrate: naive / matrix / Loeffler /
//!   Cordic-based-Loeffler 8x8 DCTs, JPEG quantization, block management,
//!   and the serial + block-parallel CPU pipelines.
//! * [`codec`] — a complete entropy codec (zigzag, DC-DPCM + AC-RLE,
//!   canonical Huffman, bitstream container) turning quantized
//!   coefficients into a real compressed file format.
//! * [`metrics`] — MSE / PSNR / SSIM and latency statistics.
//! * [`runtime`] — the PJRT side: artifact manifest, executable cache,
//!   literal marshaling.
//! * [`coordinator`] — router, per-lane batcher, worker pool, service
//!   facade over all three lanes.
//! * [`bench`] — the measurement harness and the paper-table formatters
//!   used by `cargo bench` targets (now with serial/parallel/GPU columns).

pub mod bench;
pub mod codec;
pub mod coordinator;
pub mod dct;
pub mod image;
pub mod metrics;
pub mod runtime;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
