//! Fixed-size worker pool over std threads — the crate's tokio stand-in
//! for CPU-bound fan-out (the coordinator's "GPU lane" dispatch and the
//! parallel parts of the benchmark harness).
//!
//! Design: one injector MPSC channel guarded by a mutex on the receiver
//! (simple work-stealing is unnecessary at our job granularity — each job
//! is an image tile or a whole image, >100us), plus a `scope`d variant for
//! borrowed data. Panics in jobs are caught and re-thrown on `join`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads consuming jobs from a shared queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    panics: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "thread pool needs at least one worker");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let panics = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let panics = Arc::clone(&panics);
                thread::Builder::new()
                    .name(format!("cordic-dct-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                if catch_unwind(AssertUnwindSafe(job)).is_err()
                                {
                                    panics.fetch_add(1, Ordering::SeqCst);
                                }
                            }
                            Err(_) => break, // channel closed
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
            panics,
        }
    }

    /// Default pool sized to the machine (at least 2 workers).
    pub fn default_size() -> usize {
        thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .max(2)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool already joined")
            .send(Box::new(job))
            .expect("worker channel closed");
    }

    /// Number of jobs that panicked so far.
    pub fn panic_count(&self) -> usize {
        self.panics.load(Ordering::SeqCst)
    }

    /// Close the queue and wait for all workers to drain it.
    /// Panics if any job panicked.
    pub fn join(mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            w.join().expect("worker thread panicked");
        }
        let p = self.panics.load(Ordering::SeqCst);
        assert!(p == 0, "{p} pool job(s) panicked");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f(i)` for i in 0..n across `workers` scoped threads, collecting
/// results in order. Uses std scoped threads so `f` may borrow.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(workers >= 1);
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    thread::scope(|scope| {
        for _ in 0..workers.min(n.max(1)) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(v);
            });
        }
    });
    out.into_iter().map(|x| x.expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn jobs_run_concurrently() {
        use std::time::{Duration, Instant};
        let pool = ThreadPool::new(4);
        let t0 = Instant::now();
        let (tx, rx) = mpsc::channel();
        for _ in 0..4 {
            let tx = tx.clone();
            pool.execute(move || {
                thread::sleep(Duration::from_millis(50));
                tx.send(()).unwrap();
            });
        }
        for _ in 0..4 {
            rx.recv().unwrap();
        }
        // 4 x 50ms on 4 workers should take well under 200ms serial time.
        assert!(t0.elapsed() < Duration::from_millis(150));
        pool.join();
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn join_reports_job_panics() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        pool.join();
    }

    #[test]
    fn parallel_map_ordered() {
        let v = parallel_map(100, 8, |i| i * i);
        assert_eq!(v.len(), 100);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn parallel_map_borrows() {
        let data: Vec<u64> = (0..50).collect();
        let v = parallel_map(50, 4, |i| data[i] + 1);
        assert_eq!(v[49], 50);
    }

    #[test]
    fn parallel_map_zero_items() {
        let v: Vec<u32> = parallel_map(0, 4, |_| unreachable!());
        assert!(v.is_empty());
    }
}
