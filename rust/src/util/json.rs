//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Exists because serde is not in the vendored crate set. Supports the full
//! JSON grammar (objects, arrays, strings with escapes, numbers, booleans,
//! null); numbers are held as f64, which is sufficient for the artifact
//! manifest and benchmark result files this crate exchanges.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value. Objects use BTreeMap so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup: `j.get("artifacts")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Required-field lookup with a useful error.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow!("missing JSON field '{key}'"))
    }

    // -- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i..self.i + 4)
                                    .ok_or_else(|| anyhow!("bad \\u"))?,
                            )?;
                            self.i += 4;
                            let cp = u32::from_str_radix(hex, 16)?;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = std::str::from_utf8(
                                        &self.b[self.i + 2..self.i + 6],
                                    )?;
                                    self.i += 6;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // collect the full UTF-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(
                        self.b
                            .get(start..start + len)
                            .ok_or_else(|| anyhow!("bad utf8"))?,
                    )?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>().map_err(|e| {
            anyhow!("bad number '{txt}' at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x");
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn parse_unicode_escape() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let j = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"obj":{"k":-3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn escaped_output_reparses() {
        let j = Json::Str("quote\" slash\\ ctl\u{1}".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"artifacts":[{"name":"compress_dct_512x512",
            "file":"a.hlo.txt","inputs":[{"shape":[512,512],
            "dtype":"f32"}]}],"version":1}"#;
        let j = Json::parse(src).unwrap();
        let a = &j.get("artifacts").unwrap().as_arr().unwrap()[0];
        let shape = a.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 512);
    }
}
