//! Seeded generate-and-shrink property testing — the crate's proptest
//! stand-in.
//!
//! `check(cases, gen, prop)` draws `cases` inputs from `gen`, runs the
//! property, and on failure greedily shrinks the input via the
//! [`Shrink`] trait before panicking with the minimal counterexample.
//! The seed comes from `CORDIC_DCT_PROPTEST_SEED` if set (for replay),
//! otherwise a fixed default keeps CI deterministic.

use std::fmt::Debug;

use crate::util::prng::Rng;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone {
    /// Candidate shrinks, roughly largest-step first. Empty when minimal.
    fn shrinks(&self) -> Vec<Self>;
}

impl Shrink for i32 {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0 {
            out.push(0);
            out.push(self / 2);
            if *self < 0 {
                out.push(-self);
            }
            if self.abs() > 1 {
                out.push(self - self.signum());
            }
        }
        out.retain(|v| v != self);
        out.dedup();
        out
    }
}

impl Shrink for i64 {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0 {
            out.push(0);
            out.push(self / 2);
            if *self < 0 {
                out.push(-self);
            }
            if self.abs() > 1 {
                out.push(self - self.signum());
            }
        }
        out.retain(|v| v != self);
        out.dedup();
        out
    }
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0 {
            out.push(0);
            out.push(self / 2);
            if *self > 1 {
                out.push(self - 1);
            }
        }
        out.retain(|v| v != self);
        out.dedup();
        out
    }
}

impl Shrink for f32 {
    fn shrinks(&self) -> Vec<Self> {
        if *self == 0.0 {
            return vec![];
        }
        let mut out = vec![0.0, self / 2.0, self.trunc()];
        out.retain(|v| v != self && v.is_finite());
        out.dedup();
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let n = self.len();
        if n == 0 {
            return out;
        }
        // structural shrinks: drop halves, drop one element
        out.push(self[..n / 2].to_vec());
        out.push(self[n / 2..].to_vec());
        if n > 1 {
            let mut v = self.clone();
            v.pop();
            out.push(v);
            let mut v = self.clone();
            v.remove(0);
            out.push(v);
        }
        // elementwise shrinks on a few positions
        for i in [0, n / 2, n - 1] {
            for cand in self[i].shrinks().into_iter().take(2) {
                let mut v = self.clone();
                v[i] = cand;
                out.push(v);
            }
        }
        out.retain(|v| v.len() < n || v.iter().zip(self).any(|(a, b)| {
            // any difference counts; Vec<T: Shrink> lacks PartialEq bound,
            // so approximate via shrink-produced inequality (best effort)
            !std::ptr::eq(a as *const T, b as *const T)
        }));
        out
    }
}

/// Pair generator convenience.
impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrinks()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrinks().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

fn seed_from_env() -> u64 {
    std::env::var("CORDIC_DCT_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xDC7_2013)
}

/// Run a property over `cases` generated inputs; shrink on failure.
///
/// `prop` returns `Err(reason)` (or panics) to signal failure.
pub fn check<T, G, P>(cases: usize, mut gen: G, prop: P)
where
    T: Shrink + Debug,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed_from_env());
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(first_reason) = run_case(&prop, &input) {
            let (min, reason, steps) = shrink(&prop, input, first_reason);
            panic!(
                "property failed (case {case}, after {steps} shrink steps)\n\
                 minimal input: {min:?}\nreason: {reason}"
            );
        }
    }
}

fn run_case<T, P>(prop: &P, input: &T) -> Result<(), String>
where
    T: Debug,
    P: Fn(&T) -> Result<(), String>,
{
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        prop(input)
    })) {
        Ok(r) => r,
        Err(p) => {
            let msg = p
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "panic".into());
            Err(format!("panicked: {msg}"))
        }
    }
}

fn shrink<T, P>(prop: &P, mut cur: T, mut reason: String) -> (T, String, usize)
where
    T: Shrink + Debug,
    P: Fn(&T) -> Result<(), String>,
{
    let mut steps = 0;
    'outer: loop {
        if steps > 500 {
            break;
        }
        for cand in cur.shrinks() {
            if let Err(r) = run_case(prop, &cand) {
                cur = cand;
                reason = r;
                steps += 1;
                continue 'outer;
            }
        }
        break;
    }
    (cur, reason, steps)
}

/// Generator helpers.
pub mod gen {
    use crate::util::prng::Rng;

    pub fn vec_i32(rng: &mut Rng, max_len: usize, lo: i32, hi: i32)
                   -> Vec<i32> {
        let n = rng.below(max_len as u64 + 1) as usize;
        (0..n)
            .map(|_| rng.range_i64(lo as i64, hi as i64) as i32)
            .collect()
    }

    pub fn vec_f32(rng: &mut Rng, max_len: usize, lo: f32, hi: f32)
                   -> Vec<f32> {
        let n = rng.below(max_len as u64 + 1) as usize;
        (0..n)
            .map(|_| rng.range_f64(lo as f64, hi as f64) as f32)
            .collect()
    }

    /// Dims that are multiples of 8, up to `max_blocks` blocks.
    pub fn dim8(rng: &mut Rng, max_blocks: usize) -> usize {
        (rng.below(max_blocks as u64) as usize + 1) * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_quiet() {
        check(50, |r| gen::vec_i32(r, 20, -100, 100), |v| {
            if v.iter().map(|x| x.abs()).sum::<i32>() >= 0 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            check(100, |r| gen::vec_i32(r, 30, 0, 1000), |v| {
                // property: no vector sums above 900 (false)
                if v.iter().sum::<i32>() <= 900 {
                    Ok(())
                } else {
                    Err(format!("sum {} > 900", v.iter().sum::<i32>()))
                }
            });
        });
        let msg = match result {
            Err(p) => p
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("minimal input"), "{msg}");
    }

    #[test]
    fn i32_shrink_terminates() {
        let mut v = 1_000_000i32;
        let mut steps = 0;
        while let Some(next) = v.shrinks().first().copied() {
            v = next;
            steps += 1;
            if v == 0 {
                break;
            }
        }
        assert_eq!(v, 0);
        assert!(steps < 100);
    }

    #[test]
    fn dim8_multiple_of_8() {
        let mut r = crate::util::prng::Rng::new(1);
        for _ in 0..100 {
            assert_eq!(gen::dim8(&mut r, 6) % 8, 0);
        }
    }
}
