//! MSB-first bit-level I/O for the entropy codec.
//!
//! The writer packs bits big-endian within each byte (JPEG convention);
//! the reader mirrors it. Both track total bit counts so the codec can
//! report exact compressed sizes.

use anyhow::{bail, Result};

/// Accumulates bits MSB-first into a byte vector.
#[derive(Default, Debug)]
pub struct BitWriter {
    buf: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `value` (n <= 57).
    #[inline]
    pub fn put(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 57, "put() supports at most 57 bits");
        debug_assert!(n == 64 || value < (1u64 << n));
        self.acc = (self.acc << n) | value;
        self.nbits += n;
        while self.nbits >= 8 {
            self.nbits -= 8;
            self.buf.push((self.acc >> self.nbits) as u8);
        }
    }

    /// Write a single bit.
    #[inline]
    pub fn put_bit(&mut self, bit: bool) {
        self.put(bit as u64, 1);
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Pad with zero bits to a byte boundary and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            let pad = 8 - self.nbits;
            self.acc <<= pad;
            self.buf.push(self.acc as u8);
            self.nbits = 0;
        }
        self.buf
    }
}

/// Reads bits MSB-first from a byte slice.
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    byte: usize,
    bit: u32, // bits consumed of current byte (0..8)
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, byte: 0, bit: 0 }
    }

    /// Bits remaining.
    pub fn remaining(&self) -> usize {
        (self.buf.len() - self.byte) * 8 - self.bit as usize
    }

    /// Read `n` bits (n <= 57) as an unsigned value.
    #[inline]
    pub fn get(&mut self, n: u32) -> Result<u64> {
        if self.remaining() < n as usize {
            bail!(
                "bitstream exhausted: wanted {n} bits, {} left",
                self.remaining()
            );
        }
        let mut out: u64 = 0;
        let mut need = n;
        while need > 0 {
            let avail = 8 - self.bit;
            let take = need.min(avail);
            let cur = self.buf[self.byte];
            let shifted = (cur >> (avail - take)) & ((1u16 << take) - 1) as u8;
            out = (out << take) | shifted as u64;
            self.bit += take;
            if self.bit == 8 {
                self.bit = 0;
                self.byte += 1;
            }
            need -= take;
        }
        Ok(out)
    }

    /// Read one bit.
    #[inline]
    pub fn get_bit(&mut self) -> Result<bool> {
        Ok(self.get(1)? == 1)
    }

    /// Skip to the next byte boundary (used after entropy-coded segments).
    pub fn align(&mut self) {
        if self.bit != 0 {
            self.bit = 0;
            self.byte += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_simple() {
        let mut w = BitWriter::new();
        w.put(0b101, 3);
        w.put(0xFF, 8);
        w.put(0, 1);
        w.put(0b11_0011, 6);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(3).unwrap(), 0b101);
        assert_eq!(r.get(8).unwrap(), 0xFF);
        assert_eq!(r.get(1).unwrap(), 0);
        assert_eq!(r.get(6).unwrap(), 0b11_0011);
    }

    #[test]
    fn roundtrip_random_fields() {
        let mut rng = Rng::new(99);
        let fields: Vec<(u64, u32)> = (0..2_000)
            .map(|_| {
                let n = rng.range_i64(1, 57) as u32;
                let v = rng.next_u64() & ((1u64 << n) - 1);
                (v, n)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(v, n) in &fields {
            w.put(v, n);
        }
        let total = w.bit_len();
        let bytes = w.finish();
        assert_eq!(bytes.len(), total.div_ceil(8));
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &fields {
            assert_eq!(r.get(n).unwrap(), v, "field of {n} bits");
        }
    }

    #[test]
    fn bit_len_counts() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.put(1, 1);
        assert_eq!(w.bit_len(), 1);
        w.put(0x7F, 7);
        assert_eq!(w.bit_len(), 8);
        w.put(3, 2);
        assert_eq!(w.bit_len(), 10);
    }

    #[test]
    fn exhaustion_errors() {
        let bytes = [0xABu8];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(8).unwrap(), 0xAB);
        assert!(r.get(1).is_err());
    }

    #[test]
    fn align_skips_partial_byte() {
        let bytes = [0b1010_0000u8, 0xCD];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(3).unwrap(), 0b101);
        r.align();
        assert_eq!(r.get(8).unwrap(), 0xCD);
    }

    #[test]
    fn padding_is_zero() {
        let mut w = BitWriter::new();
        w.put(1, 1);
        let bytes = w.finish();
        assert_eq!(bytes, vec![0b1000_0000]);
    }
}
