//! MSB-first bit-level I/O for the entropy codec.
//!
//! The writer packs bits big-endian within each byte (JPEG convention);
//! the reader mirrors it. Both track total bit counts so the codec can
//! report exact compressed sizes.

use anyhow::{bail, Result};

/// Accumulates bits MSB-first into a byte vector.
///
/// Bits collect left-aligned in a 64-bit accumulator and flush as whole
/// 32-bit big-endian words, so the hot Huffman encode loop touches the
/// output vector once per ~4 symbols instead of once per byte. The
/// emitted byte stream is identical to the historical per-byte flush.
#[derive(Default, Debug)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Pending bits, left-aligned (bit 63 is the next bit to emit).
    acc: u64,
    /// Number of pending bits in `acc` (always < 32 between calls).
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the low `n` bits of `value` (n <= 57).
    #[inline]
    pub fn put(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 57, "put() supports at most 57 bits");
        debug_assert!(n == 64 || value < (1u64 << n));
        if n > 32 {
            self.put_word((value >> 32) as u32, n - 32);
            self.put_word(value as u32, 32);
        } else {
            self.put_word(value as u32, n);
        }
    }

    /// Append up to 32 bits to the accumulator, flushing one whole
    /// big-endian word when 32+ bits are pending.
    #[inline]
    fn put_word(&mut self, value: u32, n: u32) {
        if n == 0 {
            return;
        }
        self.acc |= (value as u64) << (64 - self.nbits - n);
        self.nbits += n;
        if self.nbits >= 32 {
            self.buf
                .extend_from_slice(&((self.acc >> 32) as u32).to_be_bytes());
            self.acc <<= 32;
            self.nbits -= 32;
        }
    }

    /// Write a single bit.
    #[inline]
    pub fn put_bit(&mut self, bit: bool) {
        self.put(bit as u64, 1);
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Pad with zero bits to a byte boundary and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        let mut acc = self.acc;
        let mut nbits = self.nbits;
        while nbits > 0 {
            self.buf.push((acc >> 56) as u8);
            acc <<= 8;
            nbits = nbits.saturating_sub(8);
        }
        self.buf
    }
}

/// Reads bits MSB-first from a byte slice.
#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    byte: usize,
    bit: u32, // bits consumed of current byte (0..8)
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, byte: 0, bit: 0 }
    }

    /// Bits remaining.
    pub fn remaining(&self) -> usize {
        (self.buf.len() - self.byte) * 8 - self.bit as usize
    }

    /// Read `n` bits (n <= 57) as an unsigned value.
    #[inline]
    pub fn get(&mut self, n: u32) -> Result<u64> {
        if self.remaining() < n as usize {
            bail!(
                "bitstream exhausted: wanted {n} bits, {} left",
                self.remaining()
            );
        }
        let mut out: u64 = 0;
        let mut need = n;
        while need > 0 {
            let avail = 8 - self.bit;
            let take = need.min(avail);
            let cur = self.buf[self.byte];
            let shifted = (cur >> (avail - take)) & ((1u16 << take) - 1) as u8;
            out = (out << take) | shifted as u64;
            self.bit += take;
            if self.bit == 8 {
                self.bit = 0;
                self.byte += 1;
            }
            need -= take;
        }
        Ok(out)
    }

    /// Read one bit.
    #[inline]
    pub fn get_bit(&mut self) -> Result<bool> {
        Ok(self.get(1)? == 1)
    }

    /// Peek the next `n` bits (n <= 32) without consuming them; bits past
    /// the end of the buffer read as zero. Used by the Huffman decoder's
    /// first-level lookup table, which must inspect a fixed-width prefix
    /// even when fewer bits remain (prefix-freeness makes the zero
    /// padding harmless: only genuinely present bits are ever consumed).
    #[inline]
    pub fn peek(&self, n: u32) -> u64 {
        debug_assert!(n <= 32);
        let mut out: u64 = 0;
        let mut need = n;
        let mut byte = self.byte;
        let mut bit = self.bit;
        while need > 0 {
            let cur = if byte < self.buf.len() {
                self.buf[byte]
            } else {
                0
            };
            let avail = 8 - bit;
            let take = need.min(avail);
            let shifted =
                (cur >> (avail - take)) & ((1u16 << take) - 1) as u8;
            out = (out << take) | shifted as u64;
            bit += take;
            if bit == 8 {
                bit = 0;
                byte += 1;
            }
            need -= take;
        }
        out
    }

    /// Advance past `n` bits that were already inspected via [`peek`]
    /// (bounds-checked, no re-extraction of the bit values).
    ///
    /// [`peek`]: BitReader::peek
    #[inline]
    pub fn consume(&mut self, n: u32) -> Result<()> {
        if self.remaining() < n as usize {
            bail!(
                "bitstream exhausted: wanted {n} bits, {} left",
                self.remaining()
            );
        }
        let total = self.bit + n;
        self.byte += (total / 8) as usize;
        self.bit = total % 8;
        Ok(())
    }

    /// Skip to the next byte boundary (used after entropy-coded segments).
    pub fn align(&mut self) {
        if self.bit != 0 {
            self.bit = 0;
            self.byte += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn roundtrip_simple() {
        let mut w = BitWriter::new();
        w.put(0b101, 3);
        w.put(0xFF, 8);
        w.put(0, 1);
        w.put(0b11_0011, 6);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(3).unwrap(), 0b101);
        assert_eq!(r.get(8).unwrap(), 0xFF);
        assert_eq!(r.get(1).unwrap(), 0);
        assert_eq!(r.get(6).unwrap(), 0b11_0011);
    }

    #[test]
    fn roundtrip_random_fields() {
        let mut rng = Rng::new(99);
        let fields: Vec<(u64, u32)> = (0..2_000)
            .map(|_| {
                let n = rng.range_i64(1, 57) as u32;
                let v = rng.next_u64() & ((1u64 << n) - 1);
                (v, n)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(v, n) in &fields {
            w.put(v, n);
        }
        let total = w.bit_len();
        let bytes = w.finish();
        assert_eq!(bytes.len(), total.div_ceil(8));
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &fields {
            assert_eq!(r.get(n).unwrap(), v, "field of {n} bits");
        }
    }

    #[test]
    fn bit_len_counts() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.put(1, 1);
        assert_eq!(w.bit_len(), 1);
        w.put(0x7F, 7);
        assert_eq!(w.bit_len(), 8);
        w.put(3, 2);
        assert_eq!(w.bit_len(), 10);
    }

    #[test]
    fn exhaustion_errors() {
        let bytes = [0xABu8];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(8).unwrap(), 0xAB);
        assert!(r.get(1).is_err());
    }

    #[test]
    fn align_skips_partial_byte() {
        let bytes = [0b1010_0000u8, 0xCD];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.get(3).unwrap(), 0b101);
        r.align();
        assert_eq!(r.get(8).unwrap(), 0xCD);
    }

    #[test]
    fn peek_does_not_consume_and_zero_pads() {
        let bytes = [0b1011_0110u8, 0b1100_0001];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.peek(8), 0b1011_0110);
        assert_eq!(r.peek(8), 0b1011_0110); // still not consumed
        assert_eq!(r.get(3).unwrap(), 0b101);
        assert_eq!(r.peek(8), 0b1011_0110);
        assert_eq!(r.get(13).unwrap(), 0b1_0110_1100_0001);
        // exhausted: peeks read as zero, get errors
        assert_eq!(r.peek(8), 0);
        assert!(r.get(1).is_err());
    }

    #[test]
    fn consume_advances_like_get() {
        let bytes = [0xA5u8, 0x3C, 0x7E];
        let mut a = BitReader::new(&bytes);
        let mut b = BitReader::new(&bytes);
        for n in [3u32, 5, 7, 9] {
            a.get(n).unwrap();
            b.consume(n).unwrap();
            assert_eq!(a.remaining(), b.remaining());
            assert_eq!(a.peek(8), b.peek(8));
        }
        // exhaustion errors exactly like get
        assert!(b.consume(1).is_err());
        assert_eq!(b.remaining(), 0);
    }

    #[test]
    fn word_flush_matches_per_byte_reference() {
        // cross-check the word-flushing writer against a simple per-bit
        // reference over an irregular field mix
        let mut rng = Rng::new(7);
        let fields: Vec<(u64, u32)> = (0..500)
            .map(|_| {
                let n = rng.range_i64(0, 57) as u32;
                let v = if n == 0 {
                    0
                } else {
                    rng.next_u64() & ((1u64 << n) - 1)
                };
                (v, n)
            })
            .collect();
        let mut w = BitWriter::new();
        let mut bits: Vec<bool> = Vec::new();
        for &(v, n) in &fields {
            w.put(v, n);
            for i in (0..n).rev() {
                bits.push((v >> i) & 1 == 1);
            }
        }
        let got = w.finish();
        let mut want = vec![0u8; bits.len().div_ceil(8)];
        for (i, &b) in bits.iter().enumerate() {
            if b {
                want[i / 8] |= 1 << (7 - i % 8);
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn padding_is_zero() {
        let mut w = BitWriter::new();
        w.put(1, 1);
        let bytes = w.finish();
        assert_eq!(bytes, vec![0b1000_0000]);
    }
}
