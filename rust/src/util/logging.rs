//! Leveled stderr logger for the coordinator and launcher.
//!
//! Level is set once at startup (from `--log-level` or `CORDIC_DCT_LOG`);
//! messages carry a monotonic timestamp relative to process start so serve
//! logs read like a trace.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

use once_cell::sync::Lazy;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: Lazy<Instant> = Lazy::new(Instant::now);

/// Set the global level (e.g. at CLI startup).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize from the CORDIC_DCT_LOG env var if present.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("CORDIC_DCT_LOG") {
        if let Some(l) = Level::parse(&v) {
            set_level(l);
        }
    }
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let t = START.elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {} {target}] {msg}", level.tag());
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info, $target,
            format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn, $target,
            format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error, $target,
            format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug, $target,
            format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
