//! Infrastructure substrates.
//!
//! The build environment is fully offline with a fixed vendored crate set
//! (no serde, clap, criterion, proptest, rayon, tokio), so this module owns
//! the pieces a framework normally pulls from crates.io:
//!
//! * [`json`] — minimal JSON parser/serializer (manifest + results files)
//! * [`cli`] — declarative argument parser for the launcher binaries
//! * [`prng`] — splitmix64/xoshiro256** deterministic PRNG
//! * [`bitio`] — MSB-first bit reader/writer for the entropy codec
//! * [`timer`] — wall-clock measurement with warmup + robust statistics
//! * [`threadpool`] — fixed worker pool with panic propagation
//! * [`proptest`] — seeded generate-and-shrink property-test harness
//! * [`logging`] — leveled stderr logger for the coordinator

pub mod bitio;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prng;
pub mod proptest;
pub mod threadpool;
pub mod timer;
