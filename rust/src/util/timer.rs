//! Wall-clock measurement with warmup and robust statistics — the crate's
//! criterion stand-in, and the measurement protocol behind every paper
//! table (§4: "processing speed measured in milliseconds").

use std::time::Instant;

/// Summary statistics over a set of timing samples, in milliseconds.
#[derive(Clone, Debug, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub mean_ms: f64,
    pub median_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
    pub p95_ms: f64,
    pub std_ms: f64,
}

impl Stats {
    pub fn from_samples_ms(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty());
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                / (n - 1) as f64
        } else {
            0.0
        };
        Stats {
            n,
            mean_ms: mean,
            median_ms: percentile(&s, 50.0),
            min_ms: s[0],
            max_ms: s[n - 1],
            p95_ms: percentile(&s, 95.0),
            std_ms: var.sqrt(),
        }
    }
}

/// Nearest-rank percentile on an already-sorted slice.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0 * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Measurement configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
    /// Stop early once this much wall time (ms) has been spent measuring;
    /// at least 3 iterations always run.
    pub budget_ms: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: 2,
            iters: 10,
            budget_ms: 10_000.0,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup: 1,
            iters: 5,
            budget_ms: 3_000.0,
        }
    }

    /// Time `f`, returning stats over the measured iterations. The closure
    /// result is passed to `std::hint::black_box` to keep the optimizer
    /// honest.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        let started = Instant::now();
        for i in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64() * 1e3);
            if i >= 2 && started.elapsed().as_secs_f64() * 1e3 > self.budget_ms
            {
                break;
            }
        }
        Stats::from_samples_ms(&samples)
    }
}

/// One-shot timing helper: `(result, elapsed_ms)`.
pub fn time_ms<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64() * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_constant() {
        let s = Stats::from_samples_ms(&[5.0, 5.0, 5.0]);
        assert_eq!(s.mean_ms, 5.0);
        assert_eq!(s.median_ms, 5.0);
        assert_eq!(s.std_ms, 0.0);
    }

    #[test]
    fn stats_ordering() {
        let s = Stats::from_samples_ms(&[9.0, 1.0, 5.0, 3.0, 7.0]);
        assert_eq!(s.min_ms, 1.0);
        assert_eq!(s.max_ms, 9.0);
        assert_eq!(s.median_ms, 5.0);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn percentile_bounds() {
        let s = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&s, 100.0), 4.0);
    }

    #[test]
    fn bench_runs_and_counts() {
        let b = Bench {
            warmup: 1,
            iters: 4,
            budget_ms: 60_000.0,
        };
        let mut count = 0usize;
        let s = b.run(|| {
            count += 1;
            count
        });
        assert_eq!(count, 5); // 1 warmup + 4 measured
        assert_eq!(s.n, 4);
    }

    #[test]
    fn budget_stops_early() {
        let b = Bench {
            warmup: 0,
            iters: 1_000_000,
            budget_ms: 20.0,
        };
        let s = b.run(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(s.n >= 3 && s.n < 100, "n = {}", s.n);
    }

    #[test]
    fn time_ms_measures() {
        let (_out, ms) =
            time_ms(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        assert!(ms >= 9.0, "{ms}");
    }
}
