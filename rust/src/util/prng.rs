//! Deterministic PRNG: splitmix64 seeding + xoshiro256** generation.
//!
//! Every stochastic component in the crate (synthetic images, workload
//! generators, property tests) derives from this generator so runs are
//! reproducible from a single `u64` seed.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-thread / per-image use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo) as u64 + 1) as i64
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(9);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            saw_lo |= v == -3;
            saw_hi |= v == 3;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1234);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
