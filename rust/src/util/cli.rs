//! Declarative command-line parsing — the crate's clap stand-in.
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value` options
//! with defaults, positional arguments, and auto-generated `--help` text.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Specification for one option/flag.
#[derive(Clone, Debug)]
struct OptSpec {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_flag: bool,
}

/// A declarative command spec: options, flags and positionals.
#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    opts: Vec<OptSpec>,
    positionals: Vec<(&'static str, &'static str, bool)>, // name, help, required
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command {
            name,
            about,
            ..Default::default()
        }
    }

    /// `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str,
               help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// `--name <value>` required (no default).
    pub fn opt_req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: false,
        });
        self
    }

    /// Boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    /// Positional argument.
    pub fn pos(mut self, name: &'static str, help: &'static str,
               required: bool) -> Self {
        self.positionals.push((name, help, required));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about,
                            self.name);
        for (p, _, req) in &self.positionals {
            if *req {
                s += &format!(" <{p}>");
            } else {
                s += &format!(" [{p}]");
            }
        }
        if !self.opts.is_empty() {
            s += " [OPTIONS]\n\nOPTIONS:\n";
            for o in &self.opts {
                let head = if o.is_flag {
                    format!("  --{}", o.name)
                } else if let Some(d) = &o.default {
                    format!("  --{} <v> (default {})", o.name, d)
                } else {
                    format!("  --{} <v> (required)", o.name)
                };
                s += &format!("{head:<42} {}\n", o.help);
            }
        }
        for (p, h, _) in &self.positionals {
            s += &format!("  <{p:<38}> {h}\n");
        }
        s
    }

    /// Parse an argument list (not including argv[0]/subcommand name).
    pub fn parse(&self, args: &[String]) -> Result<Matches> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut pos: Vec<String> = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| {
                        anyhow!("unknown option --{key}\n\n{}", self.usage())
                    })?;
                if spec.is_flag {
                    if inline.is_some() {
                        bail!("flag --{key} takes no value");
                    }
                    flags.push(key);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .ok_or_else(|| {
                                    anyhow!("option --{key} needs a value")
                                })?
                                .clone()
                        }
                    };
                    values.insert(key, v);
                }
            } else {
                pos.push(a.clone());
            }
            i += 1;
        }
        // defaults + required checks
        for o in &self.opts {
            if o.is_flag {
                continue;
            }
            if !values.contains_key(o.name) {
                match &o.default {
                    Some(d) => {
                        values.insert(o.name.to_string(), d.clone());
                    }
                    None => bail!("missing required option --{}\n\n{}",
                                  o.name, self.usage()),
                }
            }
        }
        let required = self.positionals.iter().filter(|p| p.2).count();
        if pos.len() < required {
            bail!("missing positional argument(s)\n\n{}", self.usage());
        }
        if pos.len() > self.positionals.len() {
            bail!("too many positional arguments\n\n{}", self.usage());
        }
        Ok(Matches { values, flags, pos })
    }
}

/// Parsed arguments.
#[derive(Debug)]
pub struct Matches {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pos: Vec<String>,
}

impl Matches {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name)
            .parse()
            .map_err(|e| anyhow!("--{name}: {e}"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn pos(&self, i: usize) -> Option<&str> {
        self.pos.get(i).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strs(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("compress", "compress an image")
            .opt("quality", "50", "JPEG quality")
            .opt("variant", "dct", "transform variant")
            .opt_req("input", "input file")
            .flag("verbose", "chatty output")
            .pos("output", "output path", false)
    }

    #[test]
    fn parses_defaults_and_required() {
        let m = cmd().parse(&strs(&["--input", "a.png"])).unwrap();
        assert_eq!(m.get("quality"), "50");
        assert_eq!(m.get("input"), "a.png");
        assert!(!m.flag("verbose"));
    }

    #[test]
    fn parses_eq_syntax_and_flags() {
        let m = cmd()
            .parse(&strs(&["--input=x.pgm", "--quality=90", "--verbose",
                           "out.bin"]))
            .unwrap();
        assert_eq!(m.get_usize("quality").unwrap(), 90);
        assert!(m.flag("verbose"));
        assert_eq!(m.pos(0), Some("out.bin"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(cmd().parse(&strs(&[])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cmd()
            .parse(&strs(&["--input", "a", "--bogus", "1"]))
            .is_err());
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(cmd().parse(&strs(&["--input=a", "--verbose=1"])).is_err());
    }

    #[test]
    fn too_many_positionals_errors() {
        assert!(cmd()
            .parse(&strs(&["--input=a", "one", "two"]))
            .is_err());
    }

    #[test]
    fn help_bails_with_usage() {
        let err = cmd().parse(&strs(&["--help"])).unwrap_err();
        assert!(err.to_string().contains("USAGE"));
        assert!(err.to_string().contains("--quality"));
    }
}
