//! Deterministic fault injection for chaos-testing the serving stack.
//!
//! The ROADMAP's north star is a service that survives "heavy traffic
//! from millions of users"; nothing earns that claim until failure is
//! *injectable, survivable, and measurable*. This module is the
//! injectable third: a seeded [`FaultPlan`] (parsed from
//! `serve --faults <spec>` or the [`FAULTS_ENV`] environment variable)
//! drives a [`FaultInjector`] whose decision points are threaded
//! through the serve and coordinator layers:
//!
//! ```text
//!              client ──frame──▶ serve::conn ──job──▶ coordinator worker
//! socket:  slow-read  short-read │                │  panic      (caught,
//!          slow-write short-write│                │  latency     answered
//!          disconnect (mid-frame)│                │              + respawn)
//! payload: bitflip (outbound) ───┘                └─▶ structured reply
//! ```
//!
//! Everything is deterministic: one root injector per server, one
//! [`FaultInjector::fork`] per connection and per worker, so the fault
//! sequence each actor sees depends only on the plan's seed and the
//! actor's index — never on thread interleaving. A run is reproducible
//! from its spec string.
//!
//! When no plan is configured the serving stack holds `None` instead
//! of an injector and every site reduces to one `Option` check; the
//! `microbench_hotpath` perf gates run with faults off and hold the
//! layer to "free when disabled".

pub mod injector;
pub mod spec;

pub use injector::{FaultCounts, FaultInjector, FaultStream};
pub use spec::{FaultPlan, FAULTS_ENV};
