//! The fault *injector*: the runtime half of the chaos layer. One root
//! [`FaultInjector`] is built per server (or service) from a
//! [`FaultPlan`]; each connection and each worker then [`fork`]s its
//! own child so every injection site draws from an independent,
//! deterministic random stream — the fault sequence seen by connection
//! N does not depend on how the scheduler interleaves connection M.
//!
//! [`fork`]: FaultInjector::fork

use std::io::{self, Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::spec::FaultPlan;
use crate::util::prng::Rng;

/// Snapshot of how many faults an injector has actually fired, by
/// class. Forked children keep their own counts.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounts {
    /// Reads delayed by `slow_ms`.
    pub slow_reads: u64,
    /// Reads truncated to a single byte.
    pub short_reads: u64,
    /// Writes delayed by `slow_ms`.
    pub slow_writes: u64,
    /// Writes that accepted only a prefix of the buffer.
    pub short_writes: u64,
    /// Writes aborted mid-frame.
    pub disconnects: u64,
    /// Outbound payloads with one bit flipped.
    pub bit_flips: u64,
    /// Jobs that were made to panic.
    pub panics: u64,
    /// Jobs delayed by `latency_ms`.
    pub latencies: u64,
}

/// A seeded fault source. Decision helpers are plain function calls
/// that first test the configured probability against zero, so an
/// injector built from a no-op plan (and, one level up, a `None`
/// injector) adds nothing to the hot path: no lock, no RNG draw.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    rng: Mutex<Rng>,
    slow_reads: AtomicU64,
    short_reads: AtomicU64,
    slow_writes: AtomicU64,
    short_writes: AtomicU64,
    disconnects: AtomicU64,
    bit_flips: AtomicU64,
    panics: AtomicU64,
    latencies: AtomicU64,
}

impl FaultInjector {
    /// Build a root injector seeded from the plan.
    pub fn new(plan: FaultPlan) -> FaultInjector {
        let rng = Rng::new(plan.seed);
        Self::with_rng(plan, rng)
    }

    fn with_rng(plan: FaultPlan, rng: Rng) -> FaultInjector {
        FaultInjector {
            plan,
            rng: Mutex::new(rng),
            slow_reads: AtomicU64::new(0),
            short_reads: AtomicU64::new(0),
            slow_writes: AtomicU64::new(0),
            short_writes: AtomicU64::new(0),
            disconnects: AtomicU64::new(0),
            bit_flips: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            latencies: AtomicU64::new(0),
        }
    }

    /// Derive a child injector with an independent random stream (same
    /// plan, fresh counts). `tag` should be unique per child — the
    /// connection or worker index — so runs are reproducible no matter
    /// how threads interleave.
    pub fn fork(&self, tag: u64) -> FaultInjector {
        let rng = self.rng.lock().unwrap().fork(tag);
        Self::with_rng(self.plan.clone(), rng)
    }

    /// The plan this injector was built from.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Snapshot the per-class fired-fault counters.
    pub fn counts(&self) -> FaultCounts {
        FaultCounts {
            slow_reads: self.slow_reads.load(Ordering::Relaxed),
            short_reads: self.short_reads.load(Ordering::Relaxed),
            slow_writes: self.slow_writes.load(Ordering::Relaxed),
            short_writes: self.short_writes.load(Ordering::Relaxed),
            disconnects: self.disconnects.load(Ordering::Relaxed),
            bit_flips: self.bit_flips.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            latencies: self.latencies.load(Ordering::Relaxed),
        }
    }

    fn roll(&self, p: f64, counter: &AtomicU64) -> bool {
        if p <= 0.0 {
            return false;
        }
        let hit = self.rng.lock().unwrap().chance(p);
        if hit {
            counter.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Should the current job panic? (Worker-side injection point.)
    pub fn worker_panic(&self) -> bool {
        self.roll(self.plan.panic, &self.panics)
    }

    /// Artificial latency to apply before running the current job.
    pub fn job_latency(&self) -> Option<Duration> {
        if self.roll(self.plan.latency, &self.latencies) {
            Some(Duration::from_millis(self.plan.latency_ms))
        } else {
            None
        }
    }

    /// Maybe flip one random bit of an outbound payload in place;
    /// returns whether a bit was flipped. Empty payloads are left
    /// alone.
    pub fn flip_bit(&self, bytes: &mut [u8]) -> bool {
        if bytes.is_empty() || self.plan.bitflip <= 0.0 {
            return false;
        }
        let mut rng = self.rng.lock().unwrap();
        if !rng.chance(self.plan.bitflip) {
            return false;
        }
        let bit = rng.below(bytes.len() as u64 * 8);
        drop(rng);
        bytes[(bit / 8) as usize] ^= 1 << (bit % 8);
        self.bit_flips.fetch_add(1, Ordering::Relaxed);
        true
    }

    fn slow_duration(&self) -> Duration {
        Duration::from_millis(self.plan.slow_ms)
    }
}

/// A `Read`/`Write` adapter that injects socket-level faults around an
/// inner stream. Short reads and writes always make progress (at least
/// one byte), so correct callers that loop — like
/// [`crate::serve::framing::read_frame`] — survive them; an injected
/// disconnect surfaces as `ConnectionAborted` after transferring half
/// the buffer, modelling a peer dying mid-frame.
#[derive(Debug)]
pub struct FaultStream<S> {
    inner: S,
    injector: Arc<FaultInjector>,
}

impl<S> FaultStream<S> {
    /// Wrap `inner`, drawing fault decisions from `injector`.
    pub fn new(inner: S, injector: Arc<FaultInjector>) -> FaultStream<S> {
        FaultStream { inner, injector }
    }

    /// Unwrap back to the inner stream.
    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<S: Read> Read for FaultStream<S> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let inj = &self.injector;
        if inj.roll(inj.plan.slow_read, &inj.slow_reads) {
            std::thread::sleep(inj.slow_duration());
        }
        if buf.len() > 1 && inj.roll(inj.plan.short_read, &inj.short_reads) {
            return self.inner.read(&mut buf[..1]);
        }
        self.inner.read(buf)
    }
}

impl<S: Write> Write for FaultStream<S> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let inj = &self.injector;
        if inj.roll(inj.plan.slow_write, &inj.slow_writes) {
            std::thread::sleep(inj.slow_duration());
        }
        if !buf.is_empty() && inj.roll(inj.plan.disconnect, &inj.disconnects) {
            // model a peer dying mid-frame: half the bytes land, then
            // the connection is gone
            let _ = self.inner.write(&buf[..buf.len() / 2]);
            return Err(io::Error::new(
                io::ErrorKind::ConnectionAborted,
                "injected disconnect",
            ));
        }
        if buf.len() > 1 && inj.roll(inj.plan.short_write, &inj.short_writes) {
            return self.inner.write(&buf[..buf.len().div_ceil(2)]);
        }
        self.inner.write(buf)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn plan(spec: &str) -> FaultPlan {
        FaultPlan::parse(spec).unwrap()
    }

    #[test]
    fn noop_plan_never_fires() {
        let inj = FaultInjector::new(FaultPlan::default());
        for _ in 0..256 {
            assert!(!inj.worker_panic());
            assert!(inj.job_latency().is_none());
        }
        let mut bytes = vec![0xAAu8; 32];
        assert!(!inj.flip_bit(&mut bytes));
        assert_eq!(bytes, vec![0xAAu8; 32]);
        assert_eq!(inj.counts(), FaultCounts::default());
    }

    #[test]
    fn decisions_are_deterministic_from_seed() {
        let a = FaultInjector::new(plan("seed=42,panic=0.3,latency=0.3"));
        let b = FaultInjector::new(plan("seed=42,panic=0.3,latency=0.3"));
        for _ in 0..128 {
            assert_eq!(a.worker_panic(), b.worker_panic());
            assert_eq!(a.job_latency(), b.job_latency());
        }
        assert_eq!(a.counts(), b.counts());
        assert!(a.counts().panics > 0, "p=0.3 over 128 draws must fire");
    }

    #[test]
    fn forks_are_deterministic_and_independent() {
        let root_a = FaultInjector::new(plan("seed=9,panic=0.5"));
        let root_b = FaultInjector::new(plan("seed=9,panic=0.5"));
        // same tag -> same stream, even when the other root burned
        // draws in between
        for _ in 0..7 {
            root_b.worker_panic();
        }
        let fa = root_a.fork(3);
        let fb = root_b.fork(3);
        let seq_a: Vec<bool> = (0..64).map(|_| fa.worker_panic()).collect();
        let seq_b: Vec<bool> = (0..64).map(|_| fb.worker_panic()).collect();
        assert_eq!(seq_a, seq_b);
        // fork counts are the child's own, not the root's
        assert_eq!(root_a.counts().panics, 0);
    }

    #[test]
    fn flip_bit_changes_exactly_one_bit() {
        let inj = FaultInjector::new(plan("seed=5,bitflip=1.0"));
        let original = vec![0x00u8, 0xFF, 0x5A, 0xA5];
        let mut bytes = original.clone();
        assert!(inj.flip_bit(&mut bytes));
        let diff: u32 = original
            .iter()
            .zip(&bytes)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum();
        assert_eq!(diff, 1, "exactly one bit must differ");
        assert_eq!(inj.counts().bit_flips, 1);
    }

    #[test]
    fn short_read_still_makes_progress() {
        let inj = Arc::new(FaultInjector::new(plan("seed=2,short-read=1.0")));
        let data: Vec<u8> = (0u8..64).collect();
        let mut fs = FaultStream::new(Cursor::new(data.clone()), inj);
        let mut out = Vec::new();
        let mut buf = [0u8; 16];
        loop {
            let n = fs.read(&mut buf).unwrap();
            if n == 0 {
                break;
            }
            assert!(n >= 1);
            out.extend_from_slice(&buf[..n]);
        }
        assert_eq!(out, data, "looping reader must still see every byte");
    }

    #[test]
    fn short_write_still_makes_progress() {
        let inj =
            Arc::new(FaultInjector::new(plan("seed=2,short-write=1.0")));
        let data: Vec<u8> = (0u8..64).collect();
        let mut fs = FaultStream::new(Vec::new(), inj.clone());
        let mut rest: &[u8] = &data;
        while !rest.is_empty() {
            let n = fs.write(rest).unwrap();
            assert!(n >= 1);
            rest = &rest[n..];
        }
        fs.flush().unwrap();
        assert_eq!(fs.into_inner(), data);
        assert!(inj.counts().short_writes > 0);
    }

    #[test]
    fn disconnect_surfaces_as_connection_aborted() {
        let inj =
            Arc::new(FaultInjector::new(plan("seed=3,disconnect=1.0")));
        let mut fs = FaultStream::new(Vec::new(), inj.clone());
        let err = fs.write(&[1, 2, 3, 4]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::ConnectionAborted);
        assert_eq!(inj.counts().disconnects, 1);
        // half the bytes landed before the abort
        assert_eq!(fs.into_inner(), vec![1, 2]);
    }
}
