//! The fault *plan*: a seeded, declarative description of which faults
//! to inject and how often, parsed from `serve --faults <spec>` or the
//! `CORDIC_DCT_FAULTS` environment variable.
//!
//! A spec is a comma-separated `key=value` list, e.g.
//!
//! ```text
//! seed=7,slow-read=0.05,short-write=0.1,disconnect=0.02,panic=0.03
//! ```
//!
//! Probabilities are per *injection site visit* (per socket read, per
//! write, per job), not per request, so a single request crossing many
//! sites sees a correspondingly higher compound fault rate. All
//! randomness derives from `seed` through [`crate::util::prng::Rng`]
//! forks, so a run is reproducible from its spec string alone.

use anyhow::{bail, ensure, Context, Result};

/// Environment variable consulted by [`FaultPlan::from_env`]. The CLI
/// flag `serve --faults <spec>` takes precedence when both are set.
pub const FAULTS_ENV: &str = "CORDIC_DCT_FAULTS";

/// A parsed, validated fault-injection plan.
///
/// The default plan injects nothing (all probabilities zero); a
/// [`crate::faults::FaultInjector`] built from it draws no randomness
/// on the hot path because every decision helper first checks the
/// probability against zero.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Root seed for all injection randomness (`seed=`; default 1).
    pub seed: u64,
    /// Probability a socket read is delayed by `slow_ms` (`slow-read=`).
    pub slow_read: f64,
    /// Probability a socket write is delayed by `slow_ms`
    /// (`slow-write=`).
    pub slow_write: f64,
    /// Probability a socket read returns fewer bytes than asked for
    /// (`short-read=`). Progress is still guaranteed: at least one
    /// byte is transferred, so correct callers that loop survive.
    pub short_read: f64,
    /// Probability a socket write accepts only a prefix of the buffer
    /// (`short-write=`).
    pub short_write: f64,
    /// Probability a socket write aborts mid-frame after transferring
    /// half the buffer (`disconnect=`).
    pub disconnect: f64,
    /// Probability one bit of an outbound response payload is flipped
    /// before framing (`bitflip=`).
    pub bitflip: f64,
    /// Probability a worker panics while running a job (`panic=`).
    pub panic: f64,
    /// Probability a job is delayed by `latency_ms` before running
    /// (`latency=`).
    pub latency: f64,
    /// Delay applied by slow reads/writes, in milliseconds
    /// (`slow-ms=`; default 5).
    pub slow_ms: u64,
    /// Delay applied by the job-latency fault, in milliseconds
    /// (`latency-ms=`; default 20).
    pub latency_ms: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 1,
            slow_read: 0.0,
            slow_write: 0.0,
            short_read: 0.0,
            short_write: 0.0,
            disconnect: 0.0,
            bitflip: 0.0,
            panic: 0.0,
            latency: 0.0,
            slow_ms: 5,
            latency_ms: 20,
        }
    }
}

impl FaultPlan {
    /// Parse a comma-separated `key=value` spec string. Unknown keys
    /// and out-of-range probabilities are hard errors — a chaos run
    /// with a silently dropped fault key would report false health.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((key, value)) = part.split_once('=') else {
                bail!("fault spec entry {part:?} is not key=value");
            };
            let (key, value) = (key.trim(), value.trim());
            match key {
                "seed" => plan.seed = parse_u64(key, value)?,
                "slow-read" => plan.slow_read = parse_prob(key, value)?,
                "slow-write" => plan.slow_write = parse_prob(key, value)?,
                "short-read" => plan.short_read = parse_prob(key, value)?,
                "short-write" => {
                    plan.short_write = parse_prob(key, value)?;
                }
                "disconnect" => plan.disconnect = parse_prob(key, value)?,
                "bitflip" => plan.bitflip = parse_prob(key, value)?,
                "panic" => plan.panic = parse_prob(key, value)?,
                "latency" => plan.latency = parse_prob(key, value)?,
                "slow-ms" => plan.slow_ms = parse_u64(key, value)?,
                "latency-ms" => plan.latency_ms = parse_u64(key, value)?,
                other => bail!(
                    "unknown fault key {other:?} (valid: seed, slow-read, \
                     slow-write, short-read, short-write, disconnect, \
                     bitflip, panic, latency, slow-ms, latency-ms)"
                ),
            }
        }
        Ok(plan)
    }

    /// Read a plan from [`FAULTS_ENV`]. Returns `Ok(None)` when the
    /// variable is unset or empty; a set-but-invalid spec is an error
    /// (never silently ignored).
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var(FAULTS_ENV) {
            Ok(spec) if !spec.trim().is_empty() => {
                let plan = Self::parse(&spec).with_context(|| {
                    format!("parsing {FAULTS_ENV}={spec:?}")
                })?;
                Ok(Some(plan))
            }
            _ => Ok(None),
        }
    }

    /// True when the plan can never fire (all probabilities zero).
    pub fn is_noop(&self) -> bool {
        self.slow_read == 0.0
            && self.slow_write == 0.0
            && self.short_read == 0.0
            && self.short_write == 0.0
            && self.disconnect == 0.0
            && self.bitflip == 0.0
            && self.panic == 0.0
            && self.latency == 0.0
    }
}

fn parse_u64(key: &str, value: &str) -> Result<u64> {
    value
        .parse::<u64>()
        .map_err(|e| anyhow::anyhow!("fault key {key}={value:?}: {e}"))
}

fn parse_prob(key: &str, value: &str) -> Result<f64> {
    let p: f64 = value
        .parse()
        .map_err(|e| anyhow::anyhow!("fault key {key}={value:?}: {e}"))?;
    ensure!(
        (0.0..=1.0).contains(&p),
        "fault key {key}={value}: probability must be in [0, 1]"
    );
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_noop() {
        let plan = FaultPlan::default();
        assert!(plan.is_noop());
        assert_eq!(plan.seed, 1);
        assert_eq!(plan.slow_ms, 5);
        assert_eq!(plan.latency_ms, 20);
    }

    #[test]
    fn parses_full_spec() {
        let plan = FaultPlan::parse(
            "seed=7, slow-read=0.05, slow-write=0.1, short-read=0.2, \
             short-write=0.3, disconnect=0.02, bitflip=0.01, panic=0.03, \
             latency=0.5, slow-ms=9, latency-ms=33",
        )
        .unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.slow_read, 0.05);
        assert_eq!(plan.slow_write, 0.1);
        assert_eq!(plan.short_read, 0.2);
        assert_eq!(plan.short_write, 0.3);
        assert_eq!(plan.disconnect, 0.02);
        assert_eq!(plan.bitflip, 0.01);
        assert_eq!(plan.panic, 0.03);
        assert_eq!(plan.latency, 0.5);
        assert_eq!(plan.slow_ms, 9);
        assert_eq!(plan.latency_ms, 33);
        assert!(!plan.is_noop());
    }

    #[test]
    fn empty_spec_is_default() {
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
        assert_eq!(FaultPlan::parse(" , ").unwrap(), FaultPlan::default());
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(FaultPlan::parse("warp=0.5").is_err());
        assert!(FaultPlan::parse("panic").is_err());
        assert!(FaultPlan::parse("panic=1.5").is_err());
        assert!(FaultPlan::parse("panic=-0.1").is_err());
        assert!(FaultPlan::parse("seed=abc").is_err());
        assert!(FaultPlan::parse("slow-ms=-3").is_err());
    }
}
