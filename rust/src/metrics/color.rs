//! Color quality metrics: per-channel RGB PSNR/SSIM plus the
//! luma-weighted color PSNR the paper-style color tables report.
//!
//! The weighted figure uses the conventional 6:1:1 Y/Cb/Cr MSE weighting
//! (luma dominates perceived quality, which is also why 4:2:0 works), so
//! chroma subsampling shows up honestly but does not swamp the score.

use crate::image::color::ColorImage;
use crate::image::ycbcr::rgb_to_ycbcr;

use super::{mse, psnr_from_mse, ssim};

/// PSNR breakdown of a color image pair (all dB).
#[derive(Clone, Copy, Debug)]
pub struct ColorPsnr {
    pub r: f64,
    pub g: f64,
    pub b: f64,
    /// Full-resolution luma-plane PSNR.
    pub y: f64,
    /// PSNR of the 6:1:1-weighted Y/Cb/Cr MSE.
    pub weighted: f64,
}

/// SSIM breakdown of a color image pair.
#[derive(Clone, Copy, Debug)]
pub struct ColorSsim {
    pub r: f64,
    pub g: f64,
    pub b: f64,
    /// Full-resolution luma-plane SSIM.
    pub y: f64,
}

/// Combine per-plane MSEs with the 6:1:1 Y/Cb/Cr weighting — the one
/// place the weighting constants live, shared by [`psnr_color`] and the
/// GPU lane's `Executor::psnr_color` (whose per-plane figures run on
/// the backend but whose weighted figure must use the exact same
/// weighting as the CPU metric).
pub fn weighted_ycbcr_mse(y_mse: f64, cb_mse: f64, cr_mse: f64) -> f64 {
    (6.0 * y_mse + cb_mse + cr_mse) / 8.0
}

/// Per-channel and luma-weighted PSNR between two same-sized RGB images.
pub fn psnr_color(a: &ColorImage, b: &ColorImage) -> ColorPsnr {
    assert_eq!(
        (a.width, a.height),
        (b.width, b.height),
        "color PSNR over mismatched sizes"
    );
    let channel_mse = |c: usize| mse(&a.channel(c), &b.channel(c));
    let (ya, cba, cra) = rgb_to_ycbcr(a);
    let (yb, cbb, crb) = rgb_to_ycbcr(b);
    let my = mse(&ya, &yb);
    let weighted =
        weighted_ycbcr_mse(my, mse(&cba, &cbb), mse(&cra, &crb));
    ColorPsnr {
        r: psnr_from_mse(channel_mse(0), 255.0),
        g: psnr_from_mse(channel_mse(1), 255.0),
        b: psnr_from_mse(channel_mse(2), 255.0),
        y: psnr_from_mse(my, 255.0),
        weighted: psnr_from_mse(weighted, 255.0),
    }
}

/// Per-channel and luma SSIM between two same-sized RGB images.
pub fn ssim_color(a: &ColorImage, b: &ColorImage) -> ColorSsim {
    assert_eq!((a.width, a.height), (b.width, b.height));
    let (ya, _, _) = rgb_to_ycbcr(a);
    let (yb, _, _) = rgb_to_ycbcr(b);
    ColorSsim {
        r: ssim(&a.channel(0), &b.channel(0)),
        g: ssim(&a.channel(1), &b.channel(1)),
        b: ssim(&a.channel(2), &b.channel(2)),
        y: ssim(&ya, &yb),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synthetic;
    use crate::metrics::PSNR_CAP_DB;

    #[test]
    fn identical_images_cap() {
        let img = synthetic::lena_like_rgb(32, 32, 1);
        let p = psnr_color(&img, &img);
        assert_eq!(p.r, PSNR_CAP_DB);
        assert_eq!(p.g, PSNR_CAP_DB);
        assert_eq!(p.b, PSNR_CAP_DB);
        assert_eq!(p.y, PSNR_CAP_DB);
        assert_eq!(p.weighted, PSNR_CAP_DB);
        let s = ssim_color(&img, &img);
        assert!((s.y - 1.0).abs() < 1e-9);
        assert!((s.r - 1.0).abs() < 1e-9);
    }

    #[test]
    fn single_channel_error_isolates() {
        let a = synthetic::lena_like_rgb(32, 32, 2);
        let mut b = a.clone();
        // perturb only the red channel
        for p in b.data.chunks_exact_mut(3) {
            p[0] = p[0].wrapping_add(16);
        }
        let p = psnr_color(&a, &b);
        assert!(p.r < 30.0, "r {:.1}", p.r);
        assert_eq!(p.g, PSNR_CAP_DB);
        assert_eq!(p.b, PSNR_CAP_DB);
        // luma picks up 0.299 of the red error
        assert!(p.y < PSNR_CAP_DB);
        assert!(p.y > p.r);
    }

    #[test]
    fn chroma_error_discounted_by_weighting() {
        let a = synthetic::lena_like_rgb(48, 48, 3);
        // equal-magnitude perturbations: one luma-directed, one
        // chroma-directed (blue-yellow) — weighting must punish the luma
        // one harder
        let mut luma_err = a.clone();
        for p in luma_err.data.chunks_exact_mut(3) {
            for c in p.iter_mut() {
                *c = c.saturating_add(10);
            }
        }
        let mut chroma_err = a.clone();
        for p in chroma_err.data.chunks_exact_mut(3) {
            p[2] = p[2].saturating_add(30);
        }
        let pl = psnr_color(&a, &luma_err);
        let pc = psnr_color(&a, &chroma_err);
        assert!(pc.y > pl.y, "{} vs {}", pc.y, pl.y);
    }

    #[test]
    #[should_panic(expected = "mismatched")]
    fn size_mismatch_panics() {
        let a = ColorImage::new(8, 8);
        let b = ColorImage::new(8, 9);
        psnr_color(&a, &b);
    }
}
