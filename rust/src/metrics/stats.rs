//! Latency/throughput accumulators for the coordinator's service metrics.

use std::sync::Mutex;
use std::time::Instant;

/// Reservoir-free latency histogram with fixed log-spaced buckets
/// (microseconds to ~100s), plus exact count/sum/min/max.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    bounds_us: Vec<f64>,
    count: u64,
    sum_us: f64,
    min_us: f64,
    max_us: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        // 1us .. ~100s, 5 buckets per decade
        let mut bounds = Vec::new();
        let mut b = 1.0f64;
        while b < 1e8 {
            for m in [1.0, 1.6, 2.5, 4.0, 6.3] {
                bounds.push(b * m);
            }
            b *= 10.0;
        }
        LatencyHistogram {
            buckets: vec![0; bounds.len() + 1],
            bounds_us: bounds,
            count: 0,
            sum_us: 0.0,
            min_us: f64::INFINITY,
            max_us: 0.0,
        }
    }

    pub fn record_us(&mut self, us: f64) {
        let idx = self
            .bounds_us
            .partition_point(|&b| b < us);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
    }

    pub fn record_since(&mut self, start: Instant) {
        self.record_us(start.elapsed().as_secs_f64() * 1e6);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us / self.count as f64 / 1e3
        }
    }

    pub fn max_ms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max_us / 1e3
        }
    }

    /// Approximate percentile from the histogram (upper bucket bound).
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                let bound = if i < self.bounds_us.len() {
                    self.bounds_us[i]
                } else {
                    self.max_us
                };
                return bound.min(self.max_us) / 1e3;
            }
        }
        self.max_us / 1e3
    }
}

/// Thread-safe wrapper used by the coordinator.
#[derive(Debug, Default)]
pub struct SharedHistogram(Mutex<LatencyHistogram>);

impl SharedHistogram {
    pub fn record_us(&self, us: f64) {
        self.0.lock().unwrap().record_us(us);
    }

    pub fn record_since(&self, start: Instant) {
        self.0.lock().unwrap().record_since(start);
    }

    pub fn snapshot(&self) -> (u64, f64, f64, f64) {
        let h = self.0.lock().unwrap();
        (h.count(), h.mean_ms(), h.percentile_ms(95.0), h.max_ms())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_counts() {
        let mut h = LatencyHistogram::new();
        for us in [10.0, 100.0, 1000.0, 10_000.0] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean_ms() - 2.7775).abs() < 1e-6);
        assert_eq!(h.max_ms(), 10.0);
    }

    #[test]
    fn percentile_monotone() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record_us(i as f64 * 10.0);
        }
        let p50 = h.percentile_ms(50.0);
        let p95 = h.percentile_ms(95.0);
        let p99 = h.percentile_ms(99.0);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // p50 of uniform 0.01..10ms is ~5ms; log buckets are coarse
        assert!((2.0..8.0).contains(&p50), "p50 {p50}");
    }

    #[test]
    fn empty_histogram_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean_ms(), 0.0);
        assert_eq!(h.percentile_ms(99.0), 0.0);
    }

    #[test]
    fn shared_wrapper() {
        let h = SharedHistogram::default();
        h.record_us(500.0);
        let (n, mean, _p95, max) = h.snapshot();
        assert_eq!(n, 1);
        assert!((mean - 0.5).abs() < 1e-9);
        assert!((max - 0.5).abs() < 1e-9);
    }
}
