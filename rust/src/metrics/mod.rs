//! Quality and performance metrics: MSE / PSNR (paper §4.1 eq. 23-24),
//! SSIM, compression ratio, per-channel color metrics ([`color`]), and
//! latency accumulators for the coordinator.

pub mod color;
pub mod stats;

use crate::image::GrayImage;

/// PSNR cap for identical images (MSE = 0), matching the python oracle.
pub const PSNR_CAP_DB: f64 = 99.0;

/// Mean squared error between two same-sized images (paper eq. 24).
pub fn mse(a: &GrayImage, b: &GrayImage) -> f64 {
    assert_eq!(
        (a.width, a.height),
        (b.width, b.height),
        "MSE over mismatched sizes"
    );
    let sum: f64 = a
        .data
        .iter()
        .zip(&b.data)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum();
    sum / a.pixels() as f64
}

/// PSNR in dB with MAX = 255 (paper eq. 23). Identical images cap at
/// [`PSNR_CAP_DB`].
pub fn psnr(a: &GrayImage, b: &GrayImage) -> f64 {
    psnr_with_max(a, b, 255.0)
}

pub fn psnr_with_max(a: &GrayImage, b: &GrayImage, max_value: f64) -> f64 {
    psnr_from_mse(mse(a, b), max_value)
}

/// PSNR in dB from a precomputed MSE (capped like [`psnr`]).
pub fn psnr_from_mse(mse: f64, max_value: f64) -> f64 {
    if mse <= 0.0 {
        return PSNR_CAP_DB;
    }
    (20.0 * max_value.log10() - 10.0 * mse.log10()).min(PSNR_CAP_DB)
}

/// Mean SSIM over 8x8 windows (stride 4), standard constants.
pub fn ssim(a: &GrayImage, b: &GrayImage) -> f64 {
    assert_eq!((a.width, a.height), (b.width, b.height));
    const C1: f64 = 6.5025; // (0.01 * 255)^2
    const C2: f64 = 58.5225; // (0.03 * 255)^2
    const WIN: usize = 8;
    const STRIDE: usize = 4;
    if a.width < WIN || a.height < WIN {
        // degenerate: global statistics
        return ssim_window(a, b, 0, 0, a.width.min(a.height));
    }
    let mut total = 0.0;
    let mut count = 0usize;
    let mut y = 0;
    while y + WIN <= a.height {
        let mut x = 0;
        while x + WIN <= a.width {
            total += ssim_window_at(a, b, x, y, WIN, C1, C2);
            count += 1;
            x += STRIDE;
        }
        y += STRIDE;
    }
    total / count.max(1) as f64
}

fn ssim_window(a: &GrayImage, b: &GrayImage, x: usize, y: usize,
               win: usize) -> f64 {
    ssim_window_at(a, b, x, y, win, 6.5025, 58.5225)
}

fn ssim_window_at(
    a: &GrayImage,
    b: &GrayImage,
    x0: usize,
    y0: usize,
    win: usize,
    c1: f64,
    c2: f64,
) -> f64 {
    let n = (win * win) as f64;
    let (mut sa, mut sb) = (0.0, 0.0);
    for y in y0..y0 + win {
        for x in x0..x0 + win {
            sa += a.get(x, y) as f64;
            sb += b.get(x, y) as f64;
        }
    }
    let (ma, mb) = (sa / n, sb / n);
    let (mut va, mut vb, mut cov) = (0.0, 0.0, 0.0);
    for y in y0..y0 + win {
        for x in x0..x0 + win {
            let da = a.get(x, y) as f64 - ma;
            let db = b.get(x, y) as f64 - mb;
            va += da * da;
            vb += db * db;
            cov += da * db;
        }
    }
    va /= n - 1.0;
    vb /= n - 1.0;
    cov /= n - 1.0;
    ((2.0 * ma * mb + c1) * (2.0 * cov + c2))
        / ((ma * ma + mb * mb + c1) * (va + vb + c2))
}

/// Compression ratio: raw bytes / compressed bytes.
pub fn compression_ratio(raw_bytes: usize, compressed_bytes: usize) -> f64 {
    raw_bytes as f64 / compressed_bytes.max(1) as f64
}

/// Bits per pixel of a compressed representation.
pub fn bits_per_pixel(compressed_bytes: usize, pixels: usize) -> f64 {
    compressed_bytes as f64 * 8.0 / pixels.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synthetic;

    #[test]
    fn identical_images_cap() {
        let img = synthetic::lena_like(32, 32, 1);
        assert_eq!(psnr(&img, &img), PSNR_CAP_DB);
        assert!((ssim(&img, &img) - 1.0).abs() < 1e-9);
        assert_eq!(mse(&img, &img), 0.0);
    }

    #[test]
    fn known_psnr_value() {
        // uniform difference of 16 -> MSE 256 -> PSNR = 20log10(255/16)
        let a = GrayImage::from_vec(8, 8, vec![100; 64]).unwrap();
        let b = GrayImage::from_vec(8, 8, vec![116; 64]).unwrap();
        let want = 20.0 * (255.0f64 / 16.0).log10();
        assert!((psnr(&a, &b) - want).abs() < 1e-9);
    }

    #[test]
    fn psnr_symmetric() {
        let a = synthetic::lena_like(40, 40, 2);
        let b = synthetic::cablecar_like(40, 40, 2);
        assert!((psnr(&a, &b) - psnr(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn more_noise_lower_psnr_and_ssim() {
        let a = synthetic::lena_like(64, 64, 3);
        let mut rng = crate::util::prng::Rng::new(5);
        let mut noisy = |amp: i64| {
            let mut img = a.clone();
            let mut r = rng.fork(amp as u64);
            for v in &mut img.data {
                let n = r.range_i64(-amp, amp);
                *v = (*v as i64 + n).clamp(0, 255) as u8;
            }
            img
        };
        let small = noisy(5);
        let big = noisy(40);
        assert!(psnr(&a, &small) > psnr(&a, &big));
        assert!(ssim(&a, &small) > ssim(&a, &big));
    }

    #[test]
    fn ssim_in_unit_range() {
        let a = synthetic::lena_like(48, 48, 7);
        let b = synthetic::cablecar_like(48, 48, 7);
        let s = ssim(&a, &b);
        assert!((-1.0..=1.0).contains(&s), "ssim {s}");
    }

    #[test]
    #[should_panic(expected = "mismatched")]
    fn mse_size_mismatch_panics() {
        let a = GrayImage::new(8, 8);
        let b = GrayImage::new(8, 9);
        mse(&a, &b);
    }

    #[test]
    fn ratio_helpers() {
        assert_eq!(compression_ratio(1000, 100), 10.0);
        assert_eq!(bits_per_pixel(100, 800), 1.0);
    }
}
