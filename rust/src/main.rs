//! cordic-dct launcher: the framework CLI.
//!
//! ```text
//! cordic-dct compress   --input img.png --output out.cdc [--variant cordic]
//!                       [--color --chroma 420] [--lane gpu]
//!                       [--batch-width auto|8|16] [--precision N]
//!                       [--restart-interval 4]
//! cordic-dct decompress --input out.cdc --output back.png [--salvage]
//! cordic-dct serve      --requests 64 --scene lena --lane auto [--color]
//!                       [--stub-gpu]
//! cordic-dct serve      --listen 127.0.0.1:7070 [--max-conns 32]
//!                       [--shards 1] [--max-inflight 32] [--cache-mb 64]
//!                       [--duration-s 0] [--stub-gpu]
//!                       [--faults seed=1,panic=0.01,...] [--degrade]
//! cordic-dct loadgen    --addr 127.0.0.1:7070[,127.0.0.1:7071,...]
//!                       --clients 4 --requests 16
//!                       [--pipeline 8] [--mix per-client|unique|shared:K]
//!                       [--size 128] [--color] [--json load.json]
//!                       [--faults] [--seed 1]
//! cordic-dct psnr       --a ref.png --b test.png [--color] [--lane gpu]
//!                       [--json psnr.json]
//! cordic-dct histeq     --input img.pgm --output eq.pgm [--lane gpu]
//! cordic-dct synth      --scene cablecar --width 512 --height 512 --output x.png
//! cordic-dct paper-tables [--quick]
//! cordic-dct info
//! ```
//!
//! `--lane gpu` on `compress`/`psnr`/`histeq` uses the PJRT artifacts
//! when `artifacts/manifest.json` exists and otherwise falls back to the
//! stub backend (host-side, bit-identical to the CPU lanes), so the
//! GPU-lane paths — including `--lane gpu --color` — run in offline
//! builds and CI.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use cordic_dct::codec::{self, color as color_codec, decoder, encoder};
use cordic_dct::coordinator::{Backpressure, Lane, Service, ServiceConfig};
use cordic_dct::dct::batch::{BatchWidth, EngineConfig};
use cordic_dct::dct::color::ColorPipeline;
use cordic_dct::dct::cordic_fxp::FxpPrecision;
use cordic_dct::dct::pipeline::CpuPipeline;
use cordic_dct::dct::Variant;
use cordic_dct::image::ycbcr::Subsampling;
use cordic_dct::image::{synthetic, ColorImage, GrayImage};
use cordic_dct::metrics::color::psnr_color;
use cordic_dct::runtime::Runtime;
use cordic_dct::util::cli::Command;
use cordic_dct::util::logging;
use cordic_dct::{bench, metrics};

fn main() {
    logging::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(sub) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match sub.as_str() {
        "compress" => cmd_compress(rest),
        "decompress" => cmd_decompress(rest),
        "serve" => cmd_serve(rest),
        "loadgen" => cmd_loadgen(rest),
        "psnr" => cmd_psnr(rest),
        "histeq" => cmd_histeq(rest),
        "synth" => cmd_synth(rest),
        "paper-tables" => cmd_paper_tables(rest),
        "info" => cmd_info(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'; try `cordic-dct help`"),
    }
}

fn print_usage() {
    println!(
        "cordic-dct — DCT image compression on CPU and (PJRT) GPU lanes\n\
         \n\
         SUBCOMMANDS:\n\
         \x20 compress     compress an image to .cdc (--color for RGB/YCbCr)\n\
         \x20 decompress   decode a .cdc (gray or color) back to an image\n\
         \x20 serve        run the coordinator on a synthetic workload, or\n\
         \x20              with --listen ADDR as a TCP server\n\
         \x20 loadgen      drive a running TCP server and report latency\n\
         \x20 psnr         PSNR between two images\n\
         \x20 histeq       histogram equalization\n\
         \x20 synth        generate a synthetic test image\n\
         \x20 paper-tables regenerate the paper's tables/figures\n\
         \x20 info         runtime + artifact inventory\n\
         \n\
         Run any subcommand with --help for options."
    );
}

fn parse_variant(s: &str) -> Result<Variant> {
    Variant::parse(s).with_context(|| {
        format!(
            "unknown variant '{s}' \
             (dct | loeffler | cordic | cordic-fxp | naive)"
        )
    })
}

fn parse_mix(s: &str) -> Result<cordic_dct::serve::ImageMix> {
    use cordic_dct::serve::ImageMix;
    if s == "per-client" {
        return Ok(ImageMix::PerClient);
    }
    if s == "unique" {
        return Ok(ImageMix::Unique);
    }
    if let Some(k) = s.strip_prefix("shared:") {
        let k: usize = k
            .parse()
            .with_context(|| format!("bad shared pool size in mix '{s}'"))?;
        return Ok(ImageMix::Shared(k.max(1)));
    }
    bail!("unknown mix '{s}' (per-client | unique | shared:K)")
}

fn parse_batch_width(s: &str) -> Result<BatchWidth> {
    BatchWidth::parse(s).with_context(|| {
        format!("unknown batch width '{s}' (auto | 8 | 16)")
    })
}

/// Build the batch-engine configuration from the shared
/// `--batch-width` / `--precision` options. `--precision 0` keeps the
/// fixed-point default; levels 1..=8 map through
/// [`FxpPrecision::from_level`].
fn engine_config(m: &cordic_dct::util::cli::Matches) -> Result<EngineConfig> {
    let width = parse_batch_width(m.get("batch-width"))?;
    let level = m.get_usize("precision")?;
    anyhow::ensure!(level <= 8, "--precision takes a level 0..=8");
    let precision = if level == 0 {
        FxpPrecision::default()
    } else {
        FxpPrecision::from_level(level as u32)
    };
    Ok(EngineConfig { width, precision })
}

fn parse_lane(s: &str) -> Result<Lane> {
    Lane::parse(s).with_context(|| {
        format!("unknown lane '{s}' (cpu | cpu-parallel | gpu | auto)")
    })
}

fn parse_chroma(s: &str) -> Result<Subsampling> {
    Subsampling::parse(s).with_context(|| {
        format!("unknown chroma mode '{s}' (444 | 422 | 420)")
    })
}

/// Executor for the CLI's `--lane gpu` paths: the PJRT runtime when the
/// artifact manifest loads, else the host-side stub backend (which
/// computes every kind bit-identically to the CPU lanes, so offline
/// builds and CI can drive the GPU-lane code end-to-end).
fn gpu_executor(quality: u8) -> Result<cordic_dct::runtime::Executor> {
    let rt = Runtime::new_or_stub("artifacts", quality);
    if rt.is_stub() {
        eprintln!(
            "note: PJRT artifacts unavailable — GPU lane served by the \
             stub backend"
        );
    }
    Ok(cordic_dct::runtime::Executor::new(std::sync::Arc::new(rt)))
}

/// Build the `--lane gpu` executor and resolve the quality the backend
/// actually quantizes at (the PJRT manifest's may override `--quality`;
/// the container header must record the effective one).
fn gpu_lane(quality: u8)
            -> Result<(cordic_dct::runtime::Executor, u8)> {
    let ex = gpu_executor(quality)?;
    let backend_quality = ex.rt.quality();
    if backend_quality != quality {
        eprintln!(
            "note: GPU backend quantizes at quality {backend_quality}; \
             ignoring --quality {quality}"
        );
    }
    Ok((ex, backend_quality))
}

fn cmd_compress(args: &[String]) -> Result<()> {
    let m = Command::new("compress", "compress an image to .cdc")
        .opt_req("input", "input image (.pgm/.ppm/.bmp/.png)")
        .opt_req("output", "output .cdc path")
        .opt("variant", "cordic",
             "transform: dct|loeffler|cordic|cordic-fxp|naive")
        .opt("quality", "50", "IJG quality 1..100")
        .opt("lane", "cpu", "cpu|gpu (gpu falls back to the stub backend \
                             without artifacts)")
        .opt("batch-width", "auto",
             "CPU batch lane width: auto|8|16 (auto honours \
              CORDIC_DCT_BATCH_WIDTH, else detects)")
        .opt("precision", "0",
             "cordic-fxp precision level 1..8 (0 = library default)")
        .opt("recon", "", "also write the reconstruction here")
        .flag("color", "keep RGB and write a CDC3 color container")
        .opt("chroma", "420", "chroma subsampling for --color: 444|422|420")
        .opt("restart-interval", "4",
             "block rows per CDC2 restart segment (0 = one segment per \
              plane, minimal overhead, no partial recovery)")
        .flag("verbose", "print timings")
        .parse(args)?;
    let variant = parse_variant(m.get("variant"))?;
    let quality = m.get_usize("quality")? as u8;
    let lane = parse_lane(m.get("lane"))?;
    let engine = engine_config(&m)?;
    let restart_interval = parse_restart_interval(&m)?;
    anyhow::ensure!(
        matches!(lane, Lane::Cpu | Lane::Gpu),
        "compress supports --lane cpu|gpu; use `serve` for the \
         cpu-parallel and auto lanes"
    );
    if m.flag("color") {
        return compress_color_file(
            &m,
            variant,
            quality,
            lane,
            engine,
            restart_interval,
        );
    }
    let img = GrayImage::load(m.get("input"))?;
    let t0 = Instant::now();
    // both lanes hand the encoder the fused zigzag output directly; the
    // header records the quality the lane actually quantized at
    let (recon, scanned, quality) = match lane {
        Lane::Gpu => {
            let (ex, quality) = gpu_lane(quality)?;
            let out = ex.compress(&img, variant.as_str())?;
            (out.recon, out.scanned, quality)
        }
        _ => {
            let out = CpuPipeline::with_config(variant, quality, engine)
                .compress(&img);
            (out.recon, out.scanned, quality)
        }
    };
    let header = codec::Header {
        width: img.width as u32,
        height: img.height as u32,
        padded_width: scanned.padded_width as u32,
        padded_height: scanned.padded_height as u32,
        quality,
        variant: codec::variant_tag(variant),
    };
    let bytes =
        encoder::encode_scanned_v2(&header, &scanned, restart_interval)?;
    let elapsed = t0.elapsed().as_secs_f64() * 1e3;
    std::fs::write(m.get("output"), &bytes)
        .with_context(|| format!("writing {}", m.get("output")))?;
    let p = metrics::psnr(&img, &recon);
    println!(
        "{} -> {} ({} -> {} bytes, ratio {:.1}x, PSNR {:.2} dB{})",
        m.get("input"),
        m.get("output"),
        img.pixels(),
        bytes.len(),
        metrics::compression_ratio(img.pixels(), bytes.len()),
        p,
        if m.flag("verbose") {
            format!(", {elapsed:.1} ms")
        } else {
            String::new()
        }
    );
    let recon_path = m.get("recon");
    if !recon_path.is_empty() {
        recon.save(recon_path)?;
    }
    Ok(())
}

fn parse_restart_interval(
    m: &cordic_dct::util::cli::Matches,
) -> Result<u16> {
    let v = m.get_usize("restart-interval")?;
    anyhow::ensure!(
        v <= u16::MAX as usize,
        "--restart-interval must fit in 16 bits"
    );
    Ok(v as u16)
}

fn compress_color_file(
    m: &cordic_dct::util::cli::Matches,
    variant: Variant,
    quality: u8,
    lane: Lane,
    engine: EngineConfig,
    restart_interval: u16,
) -> Result<()> {
    let img = ColorImage::load(m.get("input"))?;
    let chroma = parse_chroma(m.get("chroma"))?;
    let t0 = Instant::now();
    // every lane feeds the color container from the fused zigzag
    // planes; the header records the quality the lane quantized at
    let (recon, scanned, quality) = match lane {
        Lane::Gpu => {
            let (ex, quality) = gpu_lane(quality)?;
            let out = ex.compress_color(&img, variant, chroma)?;
            (out.recon, out.scanned, quality)
        }
        _ => {
            let out = ColorPipeline::new_with(variant, quality, chroma, engine)
                .compress(&img);
            (out.recon, out.scanned, quality)
        }
    };
    let header = color_codec::ColorHeader {
        width: img.width as u32,
        height: img.height as u32,
        quality,
        variant: codec::variant_tag(variant),
        subsampling: color_codec::subsampling_tag(chroma),
    };
    let bytes = color_codec::encode_scanned_v2(
        &header,
        &scanned,
        restart_interval,
    )?;
    let elapsed = t0.elapsed().as_secs_f64() * 1e3;
    std::fs::write(m.get("output"), &bytes)
        .with_context(|| format!("writing {}", m.get("output")))?;
    let p = psnr_color(&img, &recon);
    println!(
        "{} -> {} ({} {} -> {} bytes, ratio {:.1}x, PSNR R {:.2} \
         G {:.2} B {:.2} Y {:.2} weighted {:.2} dB{})",
        m.get("input"),
        m.get("output"),
        chroma.as_str(),
        img.bytes(),
        bytes.len(),
        metrics::compression_ratio(img.bytes(), bytes.len()),
        p.r,
        p.g,
        p.b,
        p.y,
        p.weighted,
        if m.flag("verbose") {
            format!(", {elapsed:.1} ms")
        } else {
            String::new()
        }
    );
    let recon_path = m.get("recon");
    if !recon_path.is_empty() {
        recon.save(recon_path)?;
    }
    Ok(())
}

fn cmd_decompress(args: &[String]) -> Result<()> {
    let m = Command::new("decompress", "decode a .cdc to an image")
        .opt_req("input", "input .cdc (gray CDC1/CDC2 or color CDC3)")
        .opt_req("output", "output image (.pgm/.ppm/.bmp/.png)")
        .flag("salvage",
              "tolerate damage: conceal broken CDC2 segments and print \
               the damage report instead of failing")
        .parse(args)?;
    let bytes = std::fs::read(m.get("input"))?;
    let salvage = m.flag("salvage");
    if color_codec::is_color_container(&bytes) {
        let (dec, report) = if salvage {
            let (dec, report) = color_codec::decode_salvage(&bytes)?;
            (dec, Some(report))
        } else {
            (color_codec::decode(&bytes)?, None)
        };
        let variant = codec::tag_variant(dec.header.variant)?;
        let chroma =
            color_codec::tag_subsampling(dec.header.subsampling)?;
        let pipe =
            ColorPipeline::new(variant, dec.header.quality, chroma);
        let img = pipe.decode_coefficients(&dec.planes);
        img.save(m.get("output"))?;
        println!(
            "{} -> {} ({}x{} RGB {}, q{}, {})",
            m.get("input"),
            m.get("output"),
            img.width,
            img.height,
            chroma.as_str(),
            dec.header.quality,
            variant.as_str()
        );
        print_salvage_report(report.as_ref());
        return Ok(());
    }
    let (dec, report) = if salvage {
        let (dec, report) = decoder::decode_salvage(&bytes)?;
        (dec, Some(report))
    } else {
        (decoder::decode(&bytes)?, None)
    };
    let variant = codec::tag_variant(dec.header.variant)?;
    let pipe = CpuPipeline::new(variant, dec.header.quality);
    let img = pipe.decode_coefficients(
        &dec.qcoef_planar,
        dec.header.padded_width as usize,
        dec.header.padded_height as usize,
        dec.header.width as usize,
        dec.header.height as usize,
    );
    img.save(m.get("output"))?;
    println!(
        "{} -> {} ({}x{}, q{}, {})",
        m.get("input"),
        m.get("output"),
        img.width,
        img.height,
        dec.header.quality,
        variant.as_str()
    );
    print_salvage_report(report.as_ref());
    Ok(())
}

/// Print the `--salvage` damage report (clean decodes say so).
fn print_salvage_report(report: Option<&codec::SalvageReport>) {
    let Some(r) = report else { return };
    if r.is_clean() {
        println!(
            "salvage: container intact ({} segment(s), no damage)",
            r.segments_total
        );
    } else {
        println!(
            "salvage: {} of {} segment(s) damaged, {} concealed, \
             {} byte(s) skipped",
            r.segments_damaged,
            r.segments_total,
            r.segments_concealed,
            r.bytes_skipped
        );
    }
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let m = Command::new("serve", "run the coordinator on a synthetic load")
        .opt("requests", "32", "number of requests")
        .opt("scene", "lena", "scene generator: lena|cablecar")
        .opt("size", "512", "square image size")
        .opt("variant", "cordic", "transform variant")
        .opt("lane", "auto", "cpu|cpu-parallel|gpu|auto")
        .flag("color", "submit color (YCbCr) jobs instead of grayscale")
        .opt("chroma", "420", "chroma subsampling for --color: 444|422|420")
        .opt("workers", "0", "worker threads (0 = machine default)")
        .opt("par-workers", "0",
             "threads per cpu-parallel job (0 = machine default)")
        .opt("batch-width", "auto",
             "CPU batch lane width: auto|8|16 (auto honours \
              CORDIC_DCT_BATCH_WIDTH, else detects)")
        .opt("precision", "0",
             "cordic-fxp precision level 1..8 (0 = library default)")
        .opt("queue", "256", "queue capacity")
        .opt("restart-interval", "4",
             "block rows per CDC2 restart segment in compressed replies \
              (0 = one segment per plane)")
        .opt("batch", "8", "gpu max batch")
        .opt("artifacts", "artifacts", "artifact dir ('' disables GPU lane)")
        .flag("stub-gpu",
              "serve the GPU lane with the host-side stub backend when \
               no artifact manifest exists")
        .opt("listen", "",
             "bind a TCP front-end here (e.g. 127.0.0.1:7070) instead of \
              running the in-process synthetic load")
        .opt("max-conns", "32", "TCP mode: admission-control cap")
        .opt("shards", "1",
             "TCP mode: shared-nothing listeners on consecutive ports \
              starting at --listen (each with its own workers and cache)")
        .opt("max-inflight", "32",
             "TCP mode: per-connection pipelined (v2) request cap; \
              excess answers a structured Busy frame")
        .opt("cache-mb", "64",
             "TCP mode: content-addressed response cache budget per \
              shard, in MiB (0 disables caching)")
        .opt("duration-s", "0",
             "TCP mode: serve this long then shut down gracefully \
              (0 = until killed)")
        .opt("faults", "",
             "TCP mode: seeded fault-injection spec, e.g. \
              seed=7,slow-read=0.05,panic=0.01 (default: the \
              CORDIC_DCT_FAULTS env var)")
        .flag("degrade",
              "TCP mode: answer queue-rejected compress requests with a \
               reduced-quality Degraded result instead of Overloaded")
        .parse(args)?;
    let n = m.get_usize("requests")?;
    let size = m.get_usize("size")?;
    let lane = parse_lane(m.get("lane"))?;
    let variant = parse_variant(m.get("variant"))?;
    let color = m.flag("color");
    let chroma = parse_chroma(m.get("chroma"))?;
    let mut cfg = ServiceConfig {
        queue_capacity: m.get_usize("queue")?,
        backpressure: Backpressure::Block,
        ..Default::default()
    };
    let workers = m.get_usize("workers")?;
    if workers > 0 {
        cfg.workers = workers;
    }
    cfg.cpu_parallel_workers = m.get_usize("par-workers")?;
    cfg.restart_interval = parse_restart_interval(&m)?;
    let engine = engine_config(&m)?;
    cfg.batch_width = engine.width;
    cfg.precision = engine.precision;
    cfg.batch.gpu_max_batch = m.get_usize("batch")?;
    let adir = m.get("artifacts");
    cfg.artifact_dir =
        (!adir.is_empty()).then(|| PathBuf::from(adir));
    cfg.stub_gpu = m.flag("stub-gpu");
    if !m.get("listen").is_empty() {
        return serve_tcp(&m, cfg);
    }
    let svc = Service::start(cfg)?;
    println!(
        "serving {n} x {size}x{size} '{}' {} requests on lane {:?} \
         (gpu lane: {})",
        m.get("scene"),
        if color {
            format!("color/{}", chroma.as_str())
        } else {
            "gray".to_string()
        },
        lane,
        svc.has_gpu_lane()
    );
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n)
        .map(|i| {
            if color {
                let img = synthetic::color_by_name(
                    m.get("scene"),
                    size,
                    size,
                    i as u64,
                )
                .context("unknown scene")?;
                svc.compress_color(img, variant, lane, chroma)
            } else {
                let img =
                    synthetic::by_name(m.get("scene"), size, size, i as u64)
                        .context("unknown scene")?;
                svc.compress(img, variant, lane)
            }
        })
        .collect::<Result<_>>()?;
    let mut lanes = std::collections::BTreeMap::new();
    let mut worst_psnr = f64::INFINITY;
    let mut bytes_total = 0usize;
    for h in handles {
        let resp = h.wait();
        let out = resp.result?;
        *lanes.entry(format!("{:?}", resp.lane)).or_insert(0u32) += 1;
        worst_psnr = worst_psnr.min(out.psnr_db.unwrap_or(f64::NAN));
        bytes_total += out.compressed_bytes.unwrap_or(0);
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = svc.stats();
    println!(
        "done: {n} requests in {wall:.2}s = {:.1} req/s; lanes {lanes:?}",
        n as f64 / wall
    );
    println!(
        "queue wait mean {:.2} ms p95 {:.2} ms; process mean {:.2} ms \
         p95 {:.2} ms",
        stats.queue_wait.1, stats.queue_wait.2, stats.process.1,
        stats.process.2
    );
    println!(
        "worst PSNR {worst_psnr:.2} dB; {:.1} KiB compressed total; \
         {} executables compiled",
        bytes_total as f64 / 1024.0,
        stats.compiled_executables
    );
    svc.shutdown();
    Ok(())
}

/// `serve --listen ADDR`: the real TCP front-end over the coordinator.
fn serve_tcp(
    m: &cordic_dct::util::cli::Matches,
    service: ServiceConfig,
) -> Result<()> {
    use cordic_dct::faults::FaultPlan;
    use cordic_dct::serve::{ServeConfig, ShardGroup, TcpServer};
    let spec = m.get("faults");
    let faults = if spec.is_empty() {
        FaultPlan::from_env()?
    } else {
        Some(FaultPlan::parse(spec)?)
    };
    if let Some(plan) = &faults {
        println!("fault injection armed: {plan:?}");
    }
    let cfg = ServeConfig {
        service,
        max_connections: m.get_usize("max-conns")?.max(1),
        faults,
        degrade: m.flag("degrade"),
        max_inflight: m.get_usize("max-inflight")?.max(1),
        cache_bytes: m.get_usize("cache-mb")? * 1024 * 1024,
        ..Default::default()
    };
    let shards = m.get_usize("shards")?.max(1);
    let duration_s = m.get_usize("duration-s")?;
    let lifetime = if duration_s == 0 {
        "until killed".to_string()
    } else {
        format!("for {duration_s}s")
    };
    if shards > 1 {
        let group = ShardGroup::bind(m.get("listen"), shards, cfg)?;
        for (i, addr) in group.addrs().iter().enumerate() {
            println!("shard {i} listening on {addr} ({lifetime})");
        }
        if duration_s == 0 {
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        std::thread::sleep(std::time::Duration::from_secs(duration_s as u64));
        println!("shutting down {} shard(s)", group.len());
        group.shutdown();
        return Ok(());
    }
    let server = TcpServer::bind(m.get("listen"), cfg)?;
    println!("listening on {} ({lifetime})", server.local_addr());
    if duration_s == 0 {
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    std::thread::sleep(std::time::Duration::from_secs(duration_s as u64));
    println!(
        "shutting down: {} active connection(s), {} overload reject(s)",
        server.active_connections(),
        server.overload_rejects()
    );
    server.shutdown();
    Ok(())
}

fn cmd_loadgen(args: &[String]) -> Result<()> {
    use cordic_dct::serve::{run_load, Client, ImageMix, LoadSpec};
    let m = Command::new("loadgen", "drive a running TCP serve front-end")
        .opt_req("addr",
                 "server address(es), comma-separated for shard mode, \
                  e.g. 127.0.0.1:7070,127.0.0.1:7071")
        .opt("clients", "4", "concurrent connections")
        .opt("requests", "16", "requests per client")
        .opt("pipeline", "0",
             "in-flight window per connection over the v2 protocol \
              (0 or 1 = closed-loop v1)")
        .opt("mix", "per-client",
             "request image mix: per-client | unique | shared:K \
              (shared:1 makes every request cache-identical)")
        .opt("size", "128", "square synthetic image size")
        .opt("variant", "cordic", "transform variant")
        .opt("lane", "cpu", "cpu|cpu-parallel|gpu|auto")
        .flag("color", "send color jobs")
        .flag("psnr", "ask the server for PSNR (disables the fast path)")
        .flag("faults",
              "chaos mode: retrying clients, per-cause error counts, and \
               resilience invariant checks (non-zero exit on violation)")
        .opt("seed", "1", "chaos mode: retry-jitter seed")
        .opt("json", "", "write the report as JSON here")
        .parse(args)?;
    let addrs: Vec<std::net::SocketAddr> = m
        .get("addr")
        .split(',')
        .map(|s| {
            s.trim()
                .parse()
                .with_context(|| format!("bad address '{}'", s.trim()))
        })
        .collect::<Result<_>>()?;
    // fail fast with a clear message when any target isn't listening
    for addr in &addrs {
        Client::connect(*addr)
            .and_then(|mut c| c.ping())
            .with_context(|| format!("no serve front-end at {addr}"))?;
    }
    let mix = parse_mix(m.get("mix"))?;
    let spec = LoadSpec {
        clients: m.get_usize("clients")?.max(1),
        requests_per_client: m.get_usize("requests")?.max(1),
        pipeline: m.get_usize("pipeline")?,
        mix,
        addrs: if addrs.len() > 1 { addrs.clone() } else { Vec::new() },
        size: m.get_usize("size")?.max(8),
        color: m.flag("color"),
        variant: parse_variant(m.get("variant"))?,
        lane: parse_lane(m.get("lane"))?,
        want_psnr: m.flag("psnr"),
        faults: m.flag("faults"),
        seed: m.get_u64("seed")?,
        ..LoadSpec::new(addrs[0])
    };
    let report = run_load(&spec)?;
    println!("{report}");
    let path = m.get("json");
    if !path.is_empty() {
        std::fs::write(path, report.to_json().to_string())
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    // a chaos soak fails loudly: any invariant violation is a bug in
    // the resilience layer, not load noise
    anyhow::ensure!(
        report.invariant_violations == 0,
        "{} resilience invariant violation(s)",
        report.invariant_violations
    );
    Ok(())
}

fn cmd_psnr(args: &[String]) -> Result<()> {
    let m = Command::new("psnr", "PSNR between two images")
        .opt_req("a", "reference image")
        .opt_req("b", "test image")
        .opt("lane", "cpu",
             "cpu|gpu (gpu uses the PSNR artifact, or the stub backend \
              without artifacts)")
        .flag("color", "compare as RGB: per-channel + luma-weighted PSNR")
        .opt("json", "", "also write the figures as a JSON artifact here")
        .parse(args)?;
    let lane = parse_lane(m.get("lane"))?;
    let lane_str = if lane == Lane::Gpu { "gpu" } else { "cpu" };
    if m.flag("color") {
        let a = ColorImage::load(m.get("a"))?;
        let b = ColorImage::load(m.get("b"))?;
        let p = match lane {
            Lane::Gpu => gpu_executor(50)?.psnr_color(&a, &b)?,
            _ => psnr_color(&a, &b),
        };
        println!(
            "PSNR({}, {}) = R {:.2} G {:.2} B {:.2} Y {:.2} \
             weighted {:.2} dB [{lane_str}]",
            m.get("a"),
            m.get("b"),
            p.r,
            p.g,
            p.b,
            p.y,
            p.weighted
        );
        write_psnr_json(&m, lane_str, true, &[
            ("psnr_r", p.r),
            ("psnr_g", p.g),
            ("psnr_b", p.b),
            ("psnr_y", p.y),
            ("psnr_weighted", p.weighted),
        ])?;
        return Ok(());
    }
    let a = GrayImage::load(m.get("a"))?;
    let b = GrayImage::load(m.get("b"))?;
    let p = match lane {
        Lane::Gpu => gpu_executor(50)?.psnr(&a, &b)?,
        _ => metrics::psnr(&a, &b),
    };
    let s = metrics::ssim(&a, &b);
    println!("PSNR({}, {}) = {p:.6} dB", m.get("a"), m.get("b"));
    println!("SSIM = {s:.4}");
    write_psnr_json(&m, lane_str, false, &[("psnr", p), ("ssim", s)])?;
    Ok(())
}

/// Emit the `psnr` subcommand's figures as a JSON artifact (the CI
/// bench-smoke job uploads the GPU-lane color one next to the bench
/// JSON) when `--json <path>` was given.
fn write_psnr_json(
    m: &cordic_dct::util::cli::Matches,
    lane: &str,
    color: bool,
    figures: &[(&str, f64)],
) -> Result<()> {
    use cordic_dct::util::json::Json;
    let path = m.get("json");
    if path.is_empty() {
        return Ok(());
    }
    let mut pairs = vec![
        ("a", Json::str(m.get("a"))),
        ("b", Json::str(m.get("b"))),
        ("lane", Json::str(lane)),
        ("color", Json::Bool(color)),
    ];
    for &(k, v) in figures {
        pairs.push((k, Json::num(v)));
    }
    std::fs::write(path, Json::obj(pairs).to_string())
        .with_context(|| format!("writing {path}"))?;
    println!("wrote {path}");
    Ok(())
}

fn cmd_histeq(args: &[String]) -> Result<()> {
    let m = Command::new("histeq", "grayscale histogram equalization")
        .opt_req("input", "input image")
        .opt_req("output", "output image")
        .opt("lane", "cpu", "cpu|gpu")
        .parse(args)?;
    let img = GrayImage::load(m.get("input"))?;
    let t0 = Instant::now();
    let out = match parse_lane(m.get("lane"))? {
        Lane::Gpu => gpu_executor(50)?.histeq(&img)?.0,
        _ => cordic_dct::image::histeq::histeq(&img),
    };
    println!(
        "equalized {}x{} in {:.2} ms",
        img.width,
        img.height,
        t0.elapsed().as_secs_f64() * 1e3
    );
    out.save(m.get("output"))
}

fn cmd_synth(args: &[String]) -> Result<()> {
    let m = Command::new("synth", "generate a synthetic test image")
        .opt("scene", "lena", "lena|cablecar")
        .opt("width", "512", "width")
        .opt("height", "512", "height")
        .opt("seed", "3287", "random seed")
        .flag("color", "generate an RGB image (.ppm/.bmp/.png output)")
        .opt_req("output", "output image path")
        .parse(args)?;
    let (w, h) = (m.get_usize("width")?, m.get_usize("height")?);
    let seed = m.get_u64("seed")?;
    if m.flag("color") {
        let img = synthetic::color_by_name(m.get("scene"), w, h, seed)
            .context("unknown scene (lena|cablecar)")?;
        img.save(m.get("output"))?;
        println!(
            "wrote {} ({}x{} RGB)",
            m.get("output"),
            img.width,
            img.height
        );
        return Ok(());
    }
    let img = synthetic::by_name(m.get("scene"), w, h, seed)
        .context("unknown scene (lena|cablecar)")?;
    img.save(m.get("output"))?;
    println!(
        "wrote {} ({}x{}, mean {:.1}, sd {:.1})",
        m.get("output"),
        img.width,
        img.height,
        img.mean(),
        img.stddev()
    );
    Ok(())
}

fn cmd_paper_tables(args: &[String]) -> Result<()> {
    let m = Command::new("paper-tables", "regenerate all paper tables")
        .flag("quick", "trim sizes + iterations (CI)")
        .parse(args)?;
    if m.flag("quick") {
        std::env::set_var("CORDIC_DCT_BENCH_QUICK", "1");
    }
    bench::tables::run_timing_experiment(
        "table1_lena",
        "Table 1 (Lena timing)",
        "lena",
        bench::tables::LENA_SIZES,
        bench::tables::PAPER_TABLE1,
    )?;
    bench::tables::run_timing_experiment(
        "table2_cablecar",
        "Table 2 (Cable-car timing)",
        "cablecar",
        bench::tables::CABLECAR_SIZES,
        bench::tables::PAPER_TABLE2,
    )?;
    bench::tables::run_psnr_experiment(
        "table3_psnr_lena",
        "Table 3 (Lena PSNR)",
        "lena",
        bench::tables::LENA_PSNR_SIZES,
    )?;
    bench::tables::run_psnr_experiment(
        "table4_psnr_cablecar",
        "Table 4 (Cable-car PSNR)",
        "cablecar",
        bench::tables::CABLECAR_PSNR_SIZES,
    )?;
    Ok(())
}

fn cmd_info(args: &[String]) -> Result<()> {
    let m = Command::new("info", "runtime + artifact inventory")
        .opt("artifacts", "artifacts", "artifact dir")
        .parse(args)?;
    println!("cordic-dct {}", env!("CARGO_PKG_VERSION"));
    let dir = PathBuf::from(m.get("artifacts"));
    if !dir.join("manifest.json").exists() {
        println!(
            "artifacts: none at {} (run `make artifacts`); GPU-lane CLI \
             paths fall back to the stub backend",
            dir.display()
        );
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    println!(
        "PJRT platform: {} ({} device(s))",
        rt.platform(),
        rt.device_count()
    );
    let manifest = rt.manifest().expect("PJRT runtime has a manifest");
    println!(
        "artifacts: {} entries at {} (quality {})",
        manifest.len(),
        dir.display(),
        manifest.quality
    );
    for kind in ["compress", "psnr", "histeq", "dct", "compress_unfused"] {
        let shapes = manifest.shapes(kind);
        if !shapes.is_empty() {
            println!("  {kind:<18} {} shapes", shapes.len());
        }
    }
    Ok(())
}
