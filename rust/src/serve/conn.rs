//! Per-connection frame loop: read a request frame, run it through the
//! coordinator, answer exactly one response frame.
//!
//! Error containment is the whole design: malformed frames, hostile
//! containers, queue overload, and job failures all come back as
//! structured frames ([`ResponseMsg::Error`] / `Overloaded`) on a still-
//! healthy connection, never as a panic or a silent drop. Only a
//! desynchronized byte stream (bad length prefix, mid-frame stall or
//! disconnect) closes the connection — after a best-effort error frame —
//! because framing cannot resynchronize.
//!
//! Two optional layers sit on top:
//!
//! - **Fault injection** (chaos testing): when the server carries a
//!   [`FaultInjector`], each connection forks its own deterministic
//!   stream and wraps both socket halves in a [`FaultStream`] (slow and
//!   short reads/writes, mid-frame disconnects), plus outbound payload
//!   bit-flips applied after encoding. Disabled, the whole layer is one
//!   `Option` check per connection.
//! - **Graceful degradation** (`--degrade`): a compress request the
//!   queue rejected is answered with a reduced-quality
//!   [`ResponseMsg::Degraded`] result computed inline on the serial
//!   lane, instead of a bare Overloaded refusal.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use anyhow::Result;

use crate::codec::{
    classify_decode_error, color as color_codec, encoder, variant_tag,
    Header,
};
use crate::coordinator::{
    JobHandle, JobOutput, Lane, Service, JOB_PANIC_TAG,
};
use crate::dct::batch::EngineConfig;
use crate::dct::color::ColorPipeline;
use crate::dct::pipeline::CpuPipeline;
use crate::faults::{FaultInjector, FaultStream};
use crate::log_debug;
use crate::metrics::{color::psnr_color, psnr};
use crate::util::json::Json;

use super::framing::{self, FrameEvent};
use super::protocol::{
    decode_error_code, ImagePayload, RequestMsg, ResponseMsg,
    ERR_BAD_FRAME, ERR_JOB_FAILED, ERR_JOB_TIMEOUT, ERR_WORKER_PANIC,
};
use super::server::Shared;

/// Entry point for the connection pool; errors end the connection and
/// are logged, not propagated.
pub(crate) fn handle(stream: TcpStream, sh: &Shared) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "<unknown>".into());
    if let Err(e) = serve_conn(stream, sh) {
        log_debug!("serve", "connection {peer} closed: {e:#}");
    }
}

fn serve_conn(stream: TcpStream, sh: &Shared) -> Result<()> {
    stream.set_read_timeout(Some(sh.read_timeout))?;
    stream.set_write_timeout(Some(sh.write_timeout))?;
    let _ = stream.set_nodelay(true);
    let read_half = stream.try_clone()?;
    match &sh.faults {
        Some(root) => {
            // each connection gets its own fork so decisions stay
            // deterministic per stream regardless of accept order
            let seq = sh.fault_seq.fetch_add(1, Ordering::SeqCst);
            let inj = Arc::new(root.fork(seq));
            let reader = BufReader::new(FaultStream::new(
                read_half,
                Arc::clone(&inj),
            ));
            let writer =
                BufWriter::new(FaultStream::new(stream, Arc::clone(&inj)));
            frame_loop(reader, writer, sh, Some(&inj))
        }
        None => {
            let reader = BufReader::new(read_half);
            let writer = BufWriter::new(stream);
            frame_loop(reader, writer, sh, None)
        }
    }
}

fn frame_loop(
    mut reader: impl Read,
    mut writer: impl Write,
    sh: &Shared,
    inj: Option<&FaultInjector>,
) -> Result<()> {
    loop {
        match framing::read_frame(&mut reader, sh.max_frame_len) {
            Ok(FrameEvent::Eof) => return Ok(()),
            Ok(FrameEvent::Idle) => {
                if sh.shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
            }
            Ok(FrameEvent::Frame { kind, payload }) => {
                let resp = process(sh, kind, &payload);
                let ctr = match resp {
                    ResponseMsg::Error { .. }
                    | ResponseMsg::Overloaded => &sh.counters.frames_error,
                    ResponseMsg::Degraded { .. } => {
                        sh.counters.degraded.fetch_add(1, Ordering::SeqCst);
                        &sh.counters.frames_ok
                    }
                    _ => &sh.counters.frames_ok,
                };
                ctr.fetch_add(1, Ordering::SeqCst);
                let (k, mut body) = resp.encode();
                if let Some(f) = inj {
                    // corrupt the encoded payload, not the framing, so
                    // the client sees a well-formed frame carrying a
                    // damaged container — the hardest case to detect
                    f.flip_bit(&mut body);
                }
                framing::write_frame(&mut writer, k, &body)?;
            }
            Err(e) => {
                // the stream is desynchronized; tell the client why if
                // the socket still accepts a write, then drop it
                sh.counters.frames_error.fetch_add(1, Ordering::SeqCst);
                let (k, body) = ResponseMsg::Error {
                    code: ERR_BAD_FRAME,
                    message: format!("{e:#}"),
                }
                .encode();
                let _ = framing::write_frame(&mut writer, k, &body);
                return Err(e);
            }
        }
    }
}

/// Turn one request frame into one response frame. Never panics: every
/// failure path is a structured frame.
fn process(sh: &Shared, kind: u8, payload: &[u8]) -> ResponseMsg {
    let msg = match RequestMsg::decode(kind, payload) {
        Ok(m) => m,
        Err(e) => {
            return ResponseMsg::Error {
                code: ERR_BAD_FRAME,
                message: format!("{e:#}"),
            }
        }
    };
    match msg {
        RequestMsg::Ping => ResponseMsg::Pong,
        RequestMsg::Stats => ResponseMsg::StatsJson(stats_json(sh)),
        RequestMsg::CompressGray {
            image,
            variant,
            lane,
            want_psnr,
        } => {
            let resp = submit_and_wait(sh, |svc| {
                svc.compress_opts(image, variant, lane, want_psnr)
            });
            degrade_if_overloaded(sh, kind, payload, resp)
        }
        RequestMsg::CompressColor {
            image,
            variant,
            lane,
            subsampling,
            want_psnr,
        } => {
            let resp = submit_and_wait(sh, |svc| {
                svc.compress_color_opts(
                    image,
                    variant,
                    lane,
                    subsampling,
                    want_psnr,
                )
            });
            degrade_if_overloaded(sh, kind, payload, resp)
        }
        RequestMsg::Decode { container, lane } => {
            submit_and_wait(sh, |svc| svc.decode(container, lane))
        }
        RequestMsg::DecodeSalvage { container, lane } => {
            submit_and_wait(sh, |svc| svc.decode_salvage(container, lane))
        }
        RequestMsg::Histeq { image, lane } => {
            submit_and_wait(sh, |svc| svc.histeq(image, lane))
        }
    }
}

/// Load shedding: an Overloaded answer to a compress request becomes a
/// reduced-quality [`ResponseMsg::Degraded`] reply when the server was
/// started with `--degrade`. Non-compress requests (and every other
/// response) pass through untouched.
fn degrade_if_overloaded(
    sh: &Shared,
    kind: u8,
    payload: &[u8],
    resp: ResponseMsg,
) -> ResponseMsg {
    if !sh.degrade || !matches!(resp, ResponseMsg::Overloaded) {
        return resp;
    }
    // re-decode the request: the frame already parsed once, so the only
    // way this fails is a logic bug — fall back to the plain refusal
    // rather than risking a panic on the degrade path
    match RequestMsg::decode(kind, payload) {
        Ok(msg) => {
            degraded_reply(sh, msg).unwrap_or(ResponseMsg::Overloaded)
        }
        Err(_) => ResponseMsg::Overloaded,
    }
}

/// Compute the reduced-quality result inline on the connection thread:
/// serial CPU lane at half the service quality (floor 10). The work
/// deliberately bypasses the saturated queue — shedding trades fidelity
/// and this one thread's latency for availability, which beats making
/// the client retry against a queue that is already full.
fn degraded_reply(sh: &Shared, msg: RequestMsg) -> Option<ResponseMsg> {
    let dq = (sh.service.quality() / 2).max(10);
    match msg {
        RequestMsg::CompressGray {
            image,
            variant,
            want_psnr,
            ..
        } => {
            let pipe = CpuPipeline::new(variant, dq);
            let (psnr_db, scanned) = if want_psnr {
                let out = pipe.compress_fused(&image);
                (Some(psnr(&image, &out.recon)), out.scanned)
            } else {
                (None, pipe.analyze_scanned(&image))
            };
            let header = Header {
                width: image.width as u32,
                height: image.height as u32,
                padded_width: scanned.padded_width as u32,
                padded_height: scanned.padded_height as u32,
                quality: dq,
                variant: variant_tag(variant),
            };
            let container =
                encoder::encode_scanned(&header, &scanned).ok()?;
            Some(ResponseMsg::Degraded {
                lane: Lane::Cpu,
                psnr_db,
                container,
            })
        }
        RequestMsg::CompressColor {
            image,
            variant,
            subsampling,
            want_psnr,
            ..
        } => {
            let pipe = ColorPipeline::new_with(
                variant,
                dq,
                subsampling,
                EngineConfig::default(),
            );
            let (psnr_db, planes) = if want_psnr {
                let out = pipe.compress_fused(&image);
                (
                    Some(psnr_color(&image, &out.recon).weighted),
                    out.scanned,
                )
            } else {
                (None, pipe.analyze_scanned(&image))
            };
            let header = color_codec::ColorHeader {
                width: image.width as u32,
                height: image.height as u32,
                quality: dq,
                variant: variant_tag(variant),
                subsampling: color_codec::subsampling_tag(subsampling),
            };
            let container =
                color_codec::encode_scanned(&header, &planes).ok()?;
            Some(ResponseMsg::Degraded {
                lane: Lane::Cpu,
                psnr_db,
                container,
            })
        }
        _ => None,
    }
}

fn submit_and_wait(
    sh: &Shared,
    submit: impl FnOnce(&Service) -> Result<JobHandle>,
) -> ResponseMsg {
    let handle = match submit(&sh.service) {
        Ok(h) => h,
        Err(e) => {
            let message = format!("{e:#}");
            // the queue's Reject policy phrases exactly one error this
            // way; it is backpressure, not failure
            if message.contains("queue full") {
                return ResponseMsg::Overloaded;
            }
            return ResponseMsg::Error {
                code: ERR_JOB_FAILED,
                message,
            };
        }
    };
    let Some(resp) = handle.wait_timeout(sh.job_timeout) else {
        return ResponseMsg::Error {
            code: ERR_JOB_TIMEOUT,
            message: format!(
                "job exceeded the {} ms serve timeout",
                sh.job_timeout.as_millis()
            ),
        };
    };
    match resp.result {
        Ok(out) => output_msg(resp.lane, out),
        Err(e) => {
            let message = format!("{e:#}");
            // a panicked job already cost a worker respawn; answer the
            // dedicated code so clients can distinguish it from a
            // deterministic job failure (and avoid retrying it blindly)
            if message.contains(JOB_PANIC_TAG) {
                return ResponseMsg::Error {
                    code: ERR_WORKER_PANIC,
                    message,
                };
            }
            let code = classify_decode_error(&e)
                .map(decode_error_code)
                .unwrap_or(ERR_JOB_FAILED);
            ResponseMsg::Error { code, message }
        }
    }
}

fn output_msg(lane: Lane, out: JobOutput) -> ResponseMsg {
    // a salvage decode always answers a Salvaged frame, damaged or not,
    // so the client can tell an honest clean report from a strict decode
    if let Some(report) = out.salvage {
        let image = if let Some(c) = out.color_image {
            ImagePayload::Color(c)
        } else if let Some(g) = out.image {
            ImagePayload::Gray(g)
        } else {
            return ResponseMsg::Error {
                code: ERR_JOB_FAILED,
                message: "salvage decode produced no pixels".into(),
            };
        };
        return ResponseMsg::Salvaged {
            lane,
            segments_total: report.segments_total,
            segments_damaged: report.segments_damaged,
            segments_concealed: report.segments_concealed,
            bytes_skipped: report.bytes_skipped,
            image,
        };
    }
    if let Some(container) = out.container {
        ResponseMsg::Compressed {
            lane,
            psnr_db: out.psnr_db,
            container,
        }
    } else if let Some(c) = out.color_image {
        ResponseMsg::Image {
            lane,
            image: ImagePayload::Color(c),
        }
    } else if let Some(g) = out.image {
        ResponseMsg::Image {
            lane,
            image: ImagePayload::Gray(g),
        }
    } else {
        ResponseMsg::Error {
            code: ERR_JOB_FAILED,
            message: "job produced no output".into(),
        }
    }
}

fn stats_json(sh: &Shared) -> String {
    let s = sh.service.stats();
    let c = &sh.counters;
    Json::obj(vec![
        ("submitted", Json::num(s.submitted as f64)),
        ("queue_depth", s.queue_depth.into()),
        ("queue_wait_ms_mean", Json::num(s.queue_wait.1)),
        ("queue_wait_ms_p95", Json::num(s.queue_wait.2)),
        ("process_ms_mean", Json::num(s.process.1)),
        ("process_ms_p95", Json::num(s.process.2)),
        ("compiled_executables", s.compiled_executables.into()),
        (
            "worker_restarts",
            Json::num(s.worker_restarts as f64),
        ),
        (
            "active_connections",
            sh.active.load(Ordering::SeqCst).into(),
        ),
        (
            "accepted",
            Json::num(c.accepted.load(Ordering::SeqCst) as f64),
        ),
        (
            "frames_ok",
            Json::num(c.frames_ok.load(Ordering::SeqCst) as f64),
        ),
        (
            "frames_error",
            Json::num(c.frames_error.load(Ordering::SeqCst) as f64),
        ),
        (
            "overload_rejects",
            Json::num(c.overload_rejects.load(Ordering::SeqCst) as f64),
        ),
        (
            "degraded_replies",
            Json::num(c.degraded.load(Ordering::SeqCst) as f64),
        ),
        (
            "decode_strict_failures",
            Json::num(s.decode_strict_failures as f64),
        ),
        (
            "decode_salvaged",
            Json::num(s.decode_salvaged as f64),
        ),
        (
            "segments_concealed_total",
            Json::num(s.segments_concealed_total as f64),
        ),
    ])
    .to_string()
}
