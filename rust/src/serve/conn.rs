//! Per-connection frame loop: v1 requests answer one response frame
//! each, in order; v2 frames multiplex many in-flight requests over the
//! same socket.
//!
//! Error containment is the whole design: malformed frames, hostile
//! containers, queue overload, and job failures all come back as
//! structured frames ([`ResponseMsg::Error`] / `Overloaded` / Busy) on
//! a still-healthy connection, never as a panic or a silent drop. Only
//! a desynchronized byte stream (bad length prefix, mid-frame stall or
//! disconnect) closes the connection — after a best-effort error frame —
//! because framing cannot resynchronize.
//!
//! ## Pipelining (v2)
//!
//! ```text
//!  socket ──► reader thread ──► coordinator queue (submit_with_reply)
//!     ▲            │ v1 frames answered inline, in order
//!     │            │ v2 dup-id / Busy / Ping / Stats / cache hits
//!     │            ▼            answered inline too
//!  Mutex<writer> ◄── drainer thread ◄── shared mpsc: completions
//!                    (completion order)   arrive as workers finish
//! ```
//!
//! A v2 frame wraps a v1 request with a client-assigned `request_id`;
//! the reader submits the job with a reply sender shared by the whole
//! connection and moves on, so up to [`Shared::max_inflight`] jobs run
//! concurrently. The drainer receives completions in completion order
//! and writes each response wrapped with its request id — the id, not
//! arrival order, is the correlation. v1 frames on the same connection
//! still run closed-loop on the reader thread (bit-compatible with v1
//! servers by construction); both threads share the writer through a
//! mutex, and `write_frame` emits one whole frame per call, so frames
//! never interleave.
//!
//! ## Response cache
//!
//! With a [`ResponseCache`] configured, compress requests are looked up
//! by content-addressed [`CacheKey`] before touching the queue; a hit
//! answers the exact container bytes a cold compress would have
//! produced. Fresh full-quality compress results are inserted at
//! response-build time — *before* the chaos layer's outbound bit-flips,
//! so a corrupted wire frame can never poison the cache.
//!
//! Two more optional layers:
//!
//! - **Fault injection** (chaos testing): when the server carries a
//!   [`FaultInjector`], each connection forks its own deterministic
//!   stream and wraps both socket halves in a [`FaultStream`] (slow and
//!   short reads/writes, mid-frame disconnects), plus outbound payload
//!   bit-flips applied after encoding. Disabled, the whole layer is one
//!   `Option` check per connection.
//! - **Graceful degradation** (`--degrade`): a compress request the
//!   queue rejected is answered with a reduced-quality
//!   [`ResponseMsg::Degraded`] result computed inline on the serial
//!   lane, instead of a bare Overloaded refusal (v1 and v2 alike).

use std::collections::{HashMap, HashSet};
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::codec::{
    classify_decode_error, color as color_codec, encoder, variant_tag,
    Header,
};
use crate::coordinator::{
    JobHandle, JobOutput, Lane, Request, Response, Service, JOB_PANIC_TAG,
};
use crate::dct::batch::EngineConfig;
use crate::dct::color::ColorPipeline;
use crate::dct::pipeline::CpuPipeline;
use crate::faults::{FaultInjector, FaultStream};
use crate::log_debug;
use crate::metrics::{color::psnr_color, psnr};
use crate::util::json::Json;

use super::cache::{CacheKey, CachedReply};
use super::framing::{self, FrameEvent};
use super::protocol::{
    self, decode_error_code, ImagePayload, RequestMsg, ResponseMsg,
    ERR_BAD_FRAME, ERR_DUPLICATE_ID, ERR_JOB_FAILED, ERR_JOB_TIMEOUT,
    ERR_WORKER_PANIC, REQ_V2,
};
use super::server::Shared;

/// Entry point for the connection pool; errors end the connection and
/// are logged, not propagated.
pub(crate) fn handle(stream: TcpStream, sh: &Shared) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "<unknown>".into());
    if let Err(e) = serve_conn(stream, sh) {
        log_debug!("serve", "connection {peer} closed: {e:#}");
    }
}

fn serve_conn(stream: TcpStream, sh: &Shared) -> Result<()> {
    stream.set_read_timeout(Some(sh.read_timeout))?;
    stream.set_write_timeout(Some(sh.write_timeout))?;
    let _ = stream.set_nodelay(true);
    let read_half = stream.try_clone()?;
    match &sh.faults {
        Some(root) => {
            // each connection gets its own fork so decisions stay
            // deterministic per stream regardless of accept order
            let seq = sh.fault_seq.fetch_add(1, Ordering::SeqCst);
            let inj = Arc::new(root.fork(seq));
            let reader = BufReader::new(FaultStream::new(
                read_half,
                Arc::clone(&inj),
            ));
            let writer =
                BufWriter::new(FaultStream::new(stream, Arc::clone(&inj)));
            frame_loop(reader, writer, sh, Some(&inj))
        }
        None => {
            let reader = BufReader::new(read_half);
            let writer = BufWriter::new(stream);
            frame_loop(reader, writer, sh, None)
        }
    }
}

/// One v2 request in flight: everything the drainer needs to write (and
/// cache) the response when the coordinator completes the job.
struct Pending {
    request_id: u64,
    cache_key: Option<CacheKey>,
    deadline: Instant,
}

/// In-flight v2 requests, shared between the reader (inserts) and the
/// drainer (removes on completion or deadline).
#[derive(Default)]
struct PendingState {
    /// Keyed by coordinator job id — what a completion carries.
    by_job: HashMap<u64, Pending>,
    /// Client-assigned ids currently in flight (duplicate detection).
    ids: HashSet<u64>,
}

impl PendingState {
    fn take_job(&mut self, job_id: u64) -> Option<Pending> {
        let p = self.by_job.remove(&job_id)?;
        self.ids.remove(&p.request_id);
        Some(p)
    }

    fn take_expired(&mut self, now: Instant) -> Vec<Pending> {
        let expired: Vec<u64> = self
            .by_job
            .iter()
            .filter(|(_, p)| p.deadline <= now)
            .map(|(job, _)| *job)
            .collect();
        expired.into_iter().filter_map(|j| self.take_job(j)).collect()
    }
}

fn frame_loop<W: Write + Send>(
    mut reader: impl Read,
    writer: W,
    sh: &Shared,
    inj: Option<&FaultInjector>,
) -> Result<()> {
    let writer = Mutex::new(writer);
    let pending = Mutex::new(PendingState::default());
    // completions from every in-flight job on this connection funnel
    // into one channel, so the drainer sees them in completion order
    let (tx, rx) = mpsc::channel::<Response>();
    std::thread::scope(|s| {
        let drainer =
            s.spawn(|| drain_loop(&writer, &pending, rx, sh, inj));
        let out = read_loop(&mut reader, &writer, &pending, &tx, sh, inj);
        // reader is done: dropping its sender lets the drainer exit once
        // the last in-flight job has replied (workers hold the only
        // remaining clones), draining outstanding responses gracefully
        drop(tx);
        let _ = drainer.join();
        out
    })
}

fn read_loop(
    reader: &mut impl Read,
    writer: &Mutex<impl Write>,
    pending: &Mutex<PendingState>,
    tx: &mpsc::Sender<Response>,
    sh: &Shared,
    inj: Option<&FaultInjector>,
) -> Result<()> {
    loop {
        match framing::read_frame(reader, sh.max_frame_len) {
            Ok(FrameEvent::Eof) => return Ok(()),
            Ok(FrameEvent::Idle) => {
                if sh.shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
            }
            Ok(FrameEvent::Frame { kind, payload }) if kind == REQ_V2 => {
                handle_v2(writer, pending, tx, sh, inj, &payload)?;
            }
            Ok(FrameEvent::Frame { kind, payload }) => {
                let resp = process(sh, kind, &payload);
                count_response(sh, &resp);
                let (k, body) = resp.encode();
                send_frame(writer, inj, k, body)?;
            }
            Err(e) => {
                // the stream is desynchronized; tell the client why if
                // the socket still accepts a write, then drop it
                sh.counters.frames_error.fetch_add(1, Ordering::SeqCst);
                let (k, body) = ResponseMsg::Error {
                    code: ERR_BAD_FRAME,
                    message: format!("{e:#}"),
                }
                .encode();
                let _ = send_frame(writer, inj, k, body);
                return Err(e);
            }
        }
    }
}

/// Dispatch one v2 frame from the reader thread. Admission problems
/// (duplicate id, full window), inline requests (Ping/Stats), cache
/// hits, and submit failures answer immediately; everything else lands
/// in the coordinator queue with the response left to the drainer.
fn handle_v2(
    writer: &Mutex<impl Write>,
    pending: &Mutex<PendingState>,
    tx: &mpsc::Sender<Response>,
    sh: &Shared,
    inj: Option<&FaultInjector>,
    payload: &[u8],
) -> Result<()> {
    // an unparseable prefix has no id to echo — the one v2 error that
    // must answer unwrapped
    let Ok((request_id, inner_kind, inner)) = protocol::v2_prefix(payload)
    else {
        let resp = ResponseMsg::Error {
            code: ERR_BAD_FRAME,
            message: "v2 frame shorter than its 9-byte prefix".into(),
        };
        count_response(sh, &resp);
        let (k, body) = resp.encode();
        return send_frame(writer, inj, k, body);
    };
    {
        let st = pending.lock().unwrap();
        if st.ids.contains(&request_id) {
            drop(st);
            let resp = ResponseMsg::Error {
                code: ERR_DUPLICATE_ID,
                message: format!(
                    "request id {request_id} is already in flight"
                ),
            };
            return send_v2(writer, sh, inj, request_id, &resp);
        }
        if st.by_job.len() >= sh.max_inflight {
            drop(st);
            // structured backpressure: the window is full, nothing was
            // admitted, and every other in-flight request is unharmed
            sh.counters.frames_error.fetch_add(1, Ordering::SeqCst);
            let (k, body) = protocol::encode_v2_busy(
                request_id,
                sh.max_inflight as u32,
            );
            return send_frame(writer, inj, k, body);
        }
    }
    let msg = match RequestMsg::decode(inner_kind, inner) {
        Ok(m) => m,
        Err(e) => {
            let resp = ResponseMsg::Error {
                code: ERR_BAD_FRAME,
                message: format!("{e:#}"),
            };
            return send_v2(writer, sh, inj, request_id, &resp);
        }
    };
    // Ping/Stats never queue — answer on the reader thread, as v1 does
    match &msg {
        RequestMsg::Ping => {
            return send_v2(writer, sh, inj, request_id, &ResponseMsg::Pong)
        }
        RequestMsg::Stats => {
            let resp = ResponseMsg::StatsJson(stats_json(sh));
            return send_v2(writer, sh, inj, request_id, &resp);
        }
        _ => {}
    }
    let cache_key = sh.cache.as_ref().and_then(|_| {
        CacheKey::for_request(&msg, sh.quality, sh.restart_interval)
    });
    if let (Some(cache), Some(key)) = (&sh.cache, cache_key) {
        if let Some(hit) = cache.get(&key) {
            let resp = ResponseMsg::Compressed {
                lane: hit.lane,
                psnr_db: hit.psnr_db,
                container: (*hit.container).clone(),
            };
            return send_v2(writer, sh, inj, request_id, &resp);
        }
    }
    // reserve the pending slot inside the build closure — the job id
    // only exists there, and the entry must be visible before the queue
    // can hand the job to a worker (a fast completion would otherwise
    // race the insert and get dropped as a stale reply)
    let mut reserved = None;
    let submitted = sh.service.submit_with_reply(
        |id| {
            let mut st = pending.lock().unwrap();
            st.ids.insert(request_id);
            st.by_job.insert(
                id,
                Pending {
                    request_id,
                    cache_key,
                    deadline: Instant::now() + sh.job_timeout,
                },
            );
            reserved = Some(id);
            request_for(id, msg)
        },
        tx.clone(),
    );
    if let Err(e) = submitted {
        if let Some(id) = reserved {
            pending.lock().unwrap().take_job(id);
        }
        let message = format!("{e:#}");
        let resp = if message.contains("queue full") {
            // same shedding policy as v1: a rejected compress becomes a
            // reduced-quality inline result when --degrade is on
            degrade_if_overloaded(
                sh,
                inner_kind,
                inner,
                ResponseMsg::Overloaded,
            )
        } else {
            ResponseMsg::Error {
                code: ERR_JOB_FAILED,
                message,
            }
        };
        return send_v2(writer, sh, inj, request_id, &resp);
    }
    Ok(())
}

/// Build the coordinator request for an admitted (non-inline) v2
/// message.
fn request_for(id: u64, msg: RequestMsg) -> Request {
    match msg {
        RequestMsg::CompressGray {
            image,
            variant,
            lane,
            want_psnr,
        } => {
            let req = Request::compress(id, image, variant, lane);
            if want_psnr {
                req
            } else {
                req.no_psnr()
            }
        }
        RequestMsg::CompressColor {
            image,
            variant,
            lane,
            subsampling,
            want_psnr,
        } => {
            let req = Request::compress_color(
                id,
                image,
                variant,
                lane,
                subsampling,
            );
            if want_psnr {
                req
            } else {
                req.no_psnr()
            }
        }
        RequestMsg::Decode { container, lane } => {
            Request::decode(id, container, lane)
        }
        RequestMsg::DecodeSalvage { container, lane } => {
            Request::decode_salvage(id, container, lane)
        }
        RequestMsg::Histeq { image, lane } => {
            Request::histeq(id, image, lane)
        }
        RequestMsg::Ping | RequestMsg::Stats => {
            unreachable!("inline kinds are answered before submission")
        }
    }
}

/// Drain coordinator completions for one connection, in completion
/// order, until the reader has exited *and* the last in-flight job has
/// replied (channel disconnect). Also enforces per-job deadlines on the
/// recv tick: an expired entry answers a timeout error, and its late
/// reply — the worker finishes regardless — is dropped on arrival.
fn drain_loop(
    writer: &Mutex<impl Write>,
    pending: &Mutex<PendingState>,
    rx: mpsc::Receiver<Response>,
    sh: &Shared,
    inj: Option<&FaultInjector>,
) {
    loop {
        match rx.recv_timeout(sh.read_timeout) {
            Ok(resp) => {
                let Some(p) = pending.lock().unwrap().take_job(resp.id)
                else {
                    // deadline fired first; the timeout error frame
                    // already went out under this request id
                    continue;
                };
                let msg = job_response_msg(resp);
                if let (Some(cache), Some(key)) = (&sh.cache, p.cache_key)
                {
                    if let ResponseMsg::Compressed {
                        lane,
                        psnr_db,
                        container,
                    } = &msg
                    {
                        cache.insert(
                            key,
                            CachedReply {
                                lane: *lane,
                                psnr_db: *psnr_db,
                                container: Arc::new(container.clone()),
                            },
                        );
                    }
                }
                // a dead socket is the reader's problem to notice; the
                // drainer keeps consuming so workers never block
                let _ = send_v2(writer, sh, inj, p.request_id, &msg);
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let expired =
                    pending.lock().unwrap().take_expired(Instant::now());
                for p in expired {
                    let resp = ResponseMsg::Error {
                        code: ERR_JOB_TIMEOUT,
                        message: format!(
                            "job exceeded the {} ms serve timeout",
                            sh.job_timeout.as_millis()
                        ),
                    };
                    let _ =
                        send_v2(writer, sh, inj, p.request_id, &resp);
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        }
    }
}

/// Count, wrap, and write one v2 response under the shared writer.
fn send_v2(
    writer: &Mutex<impl Write>,
    sh: &Shared,
    inj: Option<&FaultInjector>,
    request_id: u64,
    msg: &ResponseMsg,
) -> Result<()> {
    count_response(sh, msg);
    let (kind, body) = protocol::encode_v2_response(request_id, msg);
    send_frame(writer, inj, kind, body)
}

/// Apply outbound chaos (bit-flips happen after encoding — and after
/// any cache insert, so stored bytes stay pristine) and write one frame
/// atomically under the writer mutex.
fn send_frame(
    writer: &Mutex<impl Write>,
    inj: Option<&FaultInjector>,
    kind: u8,
    mut body: Vec<u8>,
) -> Result<()> {
    if let Some(f) = inj {
        // corrupt the encoded payload, not the framing, so the client
        // sees a well-formed frame carrying a damaged container — the
        // hardest case to detect
        f.flip_bit(&mut body);
    }
    let mut w = writer.lock().unwrap();
    framing::write_frame(&mut *w, kind, &body)
}

/// Response-frame counter accounting, shared by the v1 and v2 paths.
fn count_response(sh: &Shared, resp: &ResponseMsg) {
    let ctr = match resp {
        ResponseMsg::Error { .. } | ResponseMsg::Overloaded => {
            &sh.counters.frames_error
        }
        ResponseMsg::Degraded { .. } => {
            sh.counters.degraded.fetch_add(1, Ordering::SeqCst);
            &sh.counters.frames_ok
        }
        _ => &sh.counters.frames_ok,
    };
    ctr.fetch_add(1, Ordering::SeqCst);
}

/// Turn one v1 request frame into one response frame. Never panics:
/// every failure path is a structured frame.
fn process(sh: &Shared, kind: u8, payload: &[u8]) -> ResponseMsg {
    let msg = match RequestMsg::decode(kind, payload) {
        Ok(m) => m,
        Err(e) => {
            return ResponseMsg::Error {
                code: ERR_BAD_FRAME,
                message: format!("{e:#}"),
            }
        }
    };
    let cache_key = sh.cache.as_ref().and_then(|_| {
        CacheKey::for_request(&msg, sh.quality, sh.restart_interval)
    });
    if let (Some(cache), Some(key)) = (&sh.cache, cache_key) {
        if let Some(hit) = cache.get(&key) {
            return ResponseMsg::Compressed {
                lane: hit.lane,
                psnr_db: hit.psnr_db,
                container: (*hit.container).clone(),
            };
        }
    }
    let resp = match msg {
        RequestMsg::Ping => ResponseMsg::Pong,
        RequestMsg::Stats => ResponseMsg::StatsJson(stats_json(sh)),
        RequestMsg::CompressGray {
            image,
            variant,
            lane,
            want_psnr,
        } => {
            let resp = submit_and_wait(sh, |svc| {
                svc.compress_opts(image, variant, lane, want_psnr)
            });
            degrade_if_overloaded(sh, kind, payload, resp)
        }
        RequestMsg::CompressColor {
            image,
            variant,
            lane,
            subsampling,
            want_psnr,
        } => {
            let resp = submit_and_wait(sh, |svc| {
                svc.compress_color_opts(
                    image,
                    variant,
                    lane,
                    subsampling,
                    want_psnr,
                )
            });
            degrade_if_overloaded(sh, kind, payload, resp)
        }
        RequestMsg::Decode { container, lane } => {
            submit_and_wait(sh, |svc| svc.decode(container, lane))
        }
        RequestMsg::DecodeSalvage { container, lane } => {
            submit_and_wait(sh, |svc| svc.decode_salvage(container, lane))
        }
        RequestMsg::Histeq { image, lane } => {
            submit_and_wait(sh, |svc| svc.histeq(image, lane))
        }
    };
    // only fresh full-quality results are cached; Degraded replies used
    // a different quality and must never shadow the real bytes
    if let (Some(cache), Some(key)) = (&sh.cache, cache_key) {
        if let ResponseMsg::Compressed {
            lane,
            psnr_db,
            container,
        } = &resp
        {
            cache.insert(
                key,
                CachedReply {
                    lane: *lane,
                    psnr_db: *psnr_db,
                    container: Arc::new(container.clone()),
                },
            );
        }
    }
    resp
}

/// Load shedding: an Overloaded answer to a compress request becomes a
/// reduced-quality [`ResponseMsg::Degraded`] reply when the server was
/// started with `--degrade`. Non-compress requests (and every other
/// response) pass through untouched.
fn degrade_if_overloaded(
    sh: &Shared,
    kind: u8,
    payload: &[u8],
    resp: ResponseMsg,
) -> ResponseMsg {
    if !sh.degrade || !matches!(resp, ResponseMsg::Overloaded) {
        return resp;
    }
    // re-decode the request: the frame already parsed once, so the only
    // way this fails is a logic bug — fall back to the plain refusal
    // rather than risking a panic on the degrade path
    match RequestMsg::decode(kind, payload) {
        Ok(msg) => {
            degraded_reply(sh, msg).unwrap_or(ResponseMsg::Overloaded)
        }
        Err(_) => ResponseMsg::Overloaded,
    }
}

/// Compute the reduced-quality result inline on the connection thread:
/// serial CPU lane at half the service quality (floor 10). The work
/// deliberately bypasses the saturated queue — shedding trades fidelity
/// and this one thread's latency for availability, which beats making
/// the client retry against a queue that is already full.
fn degraded_reply(sh: &Shared, msg: RequestMsg) -> Option<ResponseMsg> {
    let dq = (sh.service.quality() / 2).max(10);
    match msg {
        RequestMsg::CompressGray {
            image,
            variant,
            want_psnr,
            ..
        } => {
            let pipe = CpuPipeline::new(variant, dq);
            let (psnr_db, scanned) = if want_psnr {
                let out = pipe.compress_fused(&image);
                (Some(psnr(&image, &out.recon)), out.scanned)
            } else {
                (None, pipe.analyze_scanned(&image))
            };
            let header = Header {
                width: image.width as u32,
                height: image.height as u32,
                padded_width: scanned.padded_width as u32,
                padded_height: scanned.padded_height as u32,
                quality: dq,
                variant: variant_tag(variant),
            };
            let container =
                encoder::encode_scanned(&header, &scanned).ok()?;
            Some(ResponseMsg::Degraded {
                lane: Lane::Cpu,
                psnr_db,
                container,
            })
        }
        RequestMsg::CompressColor {
            image,
            variant,
            subsampling,
            want_psnr,
            ..
        } => {
            let pipe = ColorPipeline::new_with(
                variant,
                dq,
                subsampling,
                EngineConfig::default(),
            );
            let (psnr_db, planes) = if want_psnr {
                let out = pipe.compress_fused(&image);
                (
                    Some(psnr_color(&image, &out.recon).weighted),
                    out.scanned,
                )
            } else {
                (None, pipe.analyze_scanned(&image))
            };
            let header = color_codec::ColorHeader {
                width: image.width as u32,
                height: image.height as u32,
                quality: dq,
                variant: variant_tag(variant),
                subsampling: color_codec::subsampling_tag(subsampling),
            };
            let container =
                color_codec::encode_scanned(&header, &planes).ok()?;
            Some(ResponseMsg::Degraded {
                lane: Lane::Cpu,
                psnr_db,
                container,
            })
        }
        _ => None,
    }
}

fn submit_and_wait(
    sh: &Shared,
    submit: impl FnOnce(&Service) -> Result<JobHandle>,
) -> ResponseMsg {
    let handle = match submit(&sh.service) {
        Ok(h) => h,
        Err(e) => {
            let message = format!("{e:#}");
            // the queue's Reject policy phrases exactly one error this
            // way; it is backpressure, not failure
            if message.contains("queue full") {
                return ResponseMsg::Overloaded;
            }
            return ResponseMsg::Error {
                code: ERR_JOB_FAILED,
                message,
            };
        }
    };
    let Some(resp) = handle.wait_timeout(sh.job_timeout) else {
        return ResponseMsg::Error {
            code: ERR_JOB_TIMEOUT,
            message: format!(
                "job exceeded the {} ms serve timeout",
                sh.job_timeout.as_millis()
            ),
        };
    };
    job_response_msg(resp)
}

/// Map a completed coordinator response to its wire shape — shared by
/// the closed-loop (v1) and drainer (v2) paths.
fn job_response_msg(resp: Response) -> ResponseMsg {
    match resp.result {
        Ok(out) => output_msg(resp.lane, out),
        Err(e) => {
            let message = format!("{e:#}");
            // a panicked job already cost a worker respawn; answer the
            // dedicated code so clients can distinguish it from a
            // deterministic job failure (and avoid retrying it blindly)
            if message.contains(JOB_PANIC_TAG) {
                return ResponseMsg::Error {
                    code: ERR_WORKER_PANIC,
                    message,
                };
            }
            let code = classify_decode_error(&e)
                .map(decode_error_code)
                .unwrap_or(ERR_JOB_FAILED);
            ResponseMsg::Error { code, message }
        }
    }
}

fn output_msg(lane: Lane, out: JobOutput) -> ResponseMsg {
    // a salvage decode always answers a Salvaged frame, damaged or not,
    // so the client can tell an honest clean report from a strict decode
    if let Some(report) = out.salvage {
        let image = if let Some(c) = out.color_image {
            ImagePayload::Color(c)
        } else if let Some(g) = out.image {
            ImagePayload::Gray(g)
        } else {
            return ResponseMsg::Error {
                code: ERR_JOB_FAILED,
                message: "salvage decode produced no pixels".into(),
            };
        };
        return ResponseMsg::Salvaged {
            lane,
            segments_total: report.segments_total,
            segments_damaged: report.segments_damaged,
            segments_concealed: report.segments_concealed,
            bytes_skipped: report.bytes_skipped,
            image,
        };
    }
    if let Some(container) = out.container {
        ResponseMsg::Compressed {
            lane,
            psnr_db: out.psnr_db,
            container,
        }
    } else if let Some(c) = out.color_image {
        ResponseMsg::Image {
            lane,
            image: ImagePayload::Color(c),
        }
    } else if let Some(g) = out.image {
        ResponseMsg::Image {
            lane,
            image: ImagePayload::Gray(g),
        }
    } else {
        ResponseMsg::Error {
            code: ERR_JOB_FAILED,
            message: "job produced no output".into(),
        }
    }
}

fn stats_json(sh: &Shared) -> String {
    let s = sh.service.stats();
    let c = &sh.counters;
    let mut fields = vec![
        ("submitted", Json::num(s.submitted as f64)),
        ("queue_depth", s.queue_depth.into()),
        ("queue_wait_ms_mean", Json::num(s.queue_wait.1)),
        ("queue_wait_ms_p95", Json::num(s.queue_wait.2)),
        ("process_ms_mean", Json::num(s.process.1)),
        ("process_ms_p95", Json::num(s.process.2)),
        ("compiled_executables", s.compiled_executables.into()),
        (
            "worker_restarts",
            Json::num(s.worker_restarts as f64),
        ),
        (
            "active_connections",
            sh.active.load(Ordering::SeqCst).into(),
        ),
        (
            "accepted",
            Json::num(c.accepted.load(Ordering::SeqCst) as f64),
        ),
        (
            "frames_ok",
            Json::num(c.frames_ok.load(Ordering::SeqCst) as f64),
        ),
        (
            "frames_error",
            Json::num(c.frames_error.load(Ordering::SeqCst) as f64),
        ),
        (
            "overload_rejects",
            Json::num(c.overload_rejects.load(Ordering::SeqCst) as f64),
        ),
        (
            "degraded_replies",
            Json::num(c.degraded.load(Ordering::SeqCst) as f64),
        ),
        (
            "decode_strict_failures",
            Json::num(s.decode_strict_failures as f64),
        ),
        (
            "decode_salvaged",
            Json::num(s.decode_salvaged as f64),
        ),
        (
            "segments_concealed_total",
            Json::num(s.segments_concealed_total as f64),
        ),
    ];
    if let Some(cache) = &sh.cache {
        let cs = cache.stats();
        fields.push(("cache_hits", Json::num(cs.hits as f64)));
        fields.push(("cache_misses", Json::num(cs.misses as f64)));
        fields.push(("cache_evictions", Json::num(cs.evictions as f64)));
        fields.push(("cache_entries", cs.entries.into()));
        fields.push(("cache_bytes", cs.bytes.into()));
    }
    Json::obj(fields).to_string()
}
