//! Per-connection frame loop: read a request frame, run it through the
//! coordinator, answer exactly one response frame.
//!
//! Error containment is the whole design: malformed frames, hostile
//! containers, queue overload, and job failures all come back as
//! structured frames ([`ResponseMsg::Error`] / `Overloaded`) on a still-
//! healthy connection, never as a panic or a silent drop. Only a
//! desynchronized byte stream (bad length prefix, mid-frame stall or
//! disconnect) closes the connection — after a best-effort error frame —
//! because framing cannot resynchronize.

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::sync::atomic::Ordering;

use anyhow::Result;

use crate::codec::classify_decode_error;
use crate::coordinator::{JobHandle, JobOutput, Lane, Service};
use crate::log_debug;
use crate::util::json::Json;

use super::framing::{self, FrameEvent};
use super::protocol::{
    decode_error_code, ImagePayload, RequestMsg, ResponseMsg,
    ERR_BAD_FRAME, ERR_JOB_FAILED, ERR_JOB_TIMEOUT,
};
use super::server::Shared;

/// Entry point for the connection pool; errors end the connection and
/// are logged, not propagated.
pub(crate) fn handle(stream: TcpStream, sh: &Shared) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "<unknown>".into());
    if let Err(e) = serve_conn(stream, sh) {
        log_debug!("serve", "connection {peer} closed: {e:#}");
    }
}

fn serve_conn(stream: TcpStream, sh: &Shared) -> Result<()> {
    stream.set_read_timeout(Some(sh.read_timeout))?;
    stream.set_write_timeout(Some(sh.write_timeout))?;
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        match framing::read_frame(&mut reader, sh.max_frame_len) {
            Ok(FrameEvent::Eof) => return Ok(()),
            Ok(FrameEvent::Idle) => {
                if sh.shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
            }
            Ok(FrameEvent::Frame { kind, payload }) => {
                let resp = process(sh, kind, &payload);
                let ctr = match resp {
                    ResponseMsg::Error { .. }
                    | ResponseMsg::Overloaded => &sh.counters.frames_error,
                    _ => &sh.counters.frames_ok,
                };
                ctr.fetch_add(1, Ordering::SeqCst);
                let (k, body) = resp.encode();
                framing::write_frame(&mut writer, k, &body)?;
            }
            Err(e) => {
                // the stream is desynchronized; tell the client why if
                // the socket still accepts a write, then drop it
                sh.counters.frames_error.fetch_add(1, Ordering::SeqCst);
                let (k, body) = ResponseMsg::Error {
                    code: ERR_BAD_FRAME,
                    message: format!("{e:#}"),
                }
                .encode();
                let _ = framing::write_frame(&mut writer, k, &body);
                return Err(e);
            }
        }
    }
}

/// Turn one request frame into one response frame. Never panics: every
/// failure path is a structured frame.
fn process(sh: &Shared, kind: u8, payload: &[u8]) -> ResponseMsg {
    let msg = match RequestMsg::decode(kind, payload) {
        Ok(m) => m,
        Err(e) => {
            return ResponseMsg::Error {
                code: ERR_BAD_FRAME,
                message: format!("{e:#}"),
            }
        }
    };
    match msg {
        RequestMsg::Ping => ResponseMsg::Pong,
        RequestMsg::Stats => ResponseMsg::StatsJson(stats_json(sh)),
        RequestMsg::CompressGray {
            image,
            variant,
            lane,
            want_psnr,
        } => submit_and_wait(sh, |svc| {
            svc.compress_opts(image, variant, lane, want_psnr)
        }),
        RequestMsg::CompressColor {
            image,
            variant,
            lane,
            subsampling,
            want_psnr,
        } => submit_and_wait(sh, |svc| {
            svc.compress_color_opts(
                image,
                variant,
                lane,
                subsampling,
                want_psnr,
            )
        }),
        RequestMsg::Decode { container, lane } => {
            submit_and_wait(sh, |svc| svc.decode(container, lane))
        }
        RequestMsg::Histeq { image, lane } => {
            submit_and_wait(sh, |svc| svc.histeq(image, lane))
        }
    }
}

fn submit_and_wait(
    sh: &Shared,
    submit: impl FnOnce(&Service) -> Result<JobHandle>,
) -> ResponseMsg {
    let handle = match submit(&sh.service) {
        Ok(h) => h,
        Err(e) => {
            let message = format!("{e:#}");
            // the queue's Reject policy phrases exactly one error this
            // way; it is backpressure, not failure
            if message.contains("queue full") {
                return ResponseMsg::Overloaded;
            }
            return ResponseMsg::Error {
                code: ERR_JOB_FAILED,
                message,
            };
        }
    };
    let Some(resp) = handle.wait_timeout(sh.job_timeout) else {
        return ResponseMsg::Error {
            code: ERR_JOB_TIMEOUT,
            message: format!(
                "job exceeded the {} ms serve timeout",
                sh.job_timeout.as_millis()
            ),
        };
    };
    match resp.result {
        Ok(out) => output_msg(resp.lane, out),
        Err(e) => {
            let code = classify_decode_error(&e)
                .map(decode_error_code)
                .unwrap_or(ERR_JOB_FAILED);
            ResponseMsg::Error {
                code,
                message: format!("{e:#}"),
            }
        }
    }
}

fn output_msg(lane: Lane, out: JobOutput) -> ResponseMsg {
    if let Some(container) = out.container {
        ResponseMsg::Compressed {
            lane,
            psnr_db: out.psnr_db,
            container,
        }
    } else if let Some(c) = out.color_image {
        ResponseMsg::Image {
            lane,
            image: ImagePayload::Color(c),
        }
    } else if let Some(g) = out.image {
        ResponseMsg::Image {
            lane,
            image: ImagePayload::Gray(g),
        }
    } else {
        ResponseMsg::Error {
            code: ERR_JOB_FAILED,
            message: "job produced no output".into(),
        }
    }
}

fn stats_json(sh: &Shared) -> String {
    let s = sh.service.stats();
    let c = &sh.counters;
    Json::obj(vec![
        ("submitted", Json::num(s.submitted as f64)),
        ("queue_depth", s.queue_depth.into()),
        ("queue_wait_ms_mean", Json::num(s.queue_wait.1)),
        ("queue_wait_ms_p95", Json::num(s.queue_wait.2)),
        ("process_ms_mean", Json::num(s.process.1)),
        ("process_ms_p95", Json::num(s.process.2)),
        ("compiled_executables", s.compiled_executables.into()),
        (
            "active_connections",
            sh.active.load(Ordering::SeqCst).into(),
        ),
        (
            "accepted",
            Json::num(c.accepted.load(Ordering::SeqCst) as f64),
        ),
        (
            "frames_ok",
            Json::num(c.frames_ok.load(Ordering::SeqCst) as f64),
        ),
        (
            "frames_error",
            Json::num(c.frames_error.load(Ordering::SeqCst) as f64),
        ),
        (
            "overload_rejects",
            Json::num(c.overload_rejects.load(Ordering::SeqCst) as f64),
        ),
    ])
    .to_string()
}
