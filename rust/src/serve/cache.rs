//! Content-addressed response cache for the serve tier.
//!
//! Repeated compressions of the same image redo identical work — the
//! pipelines are deterministic, so the container bytes for a given
//! (pixels, variant, quality, chroma, restart interval, lane) tuple
//! never change for the lifetime of a server. The cache stores the
//! **exact encoded container bytes** (plus the PSNR figure when the
//! request asked for one), which is what makes a hit trivially correct:
//! the client receives the same bytes a cold compress would have
//! produced, bit for bit.
//!
//! ```text
//!  CacheKey = ( fnv1a64(dims ‖ pixels), w, h, color,
//!               variant, lane, chroma, want_psnr,
//!               quality, restart_interval )
//!                 │ digest % shards
//!                 ▼
//!  Shard { HashMap<CacheKey, Entry>, LRU ticks, byte gauge }
//! ```
//!
//! Design points:
//!
//! * **Sharded locking** — the key digest picks one of N mutexed
//!   shards, so concurrent connections rarely contend on one lock.
//! * **Byte budget, not entry count** — each shard owns
//!   `budget / shards` bytes; inserting past it evicts
//!   least-recently-used entries until the new entry fits. An entry
//!   larger than a whole shard's budget is simply not cached.
//! * **Only full-quality compress results are cached.** Degraded
//!   (load-shed) replies use a different quality, errors are cheap to
//!   recompute, and decode/histeq payloads are client-supplied bytes
//!   with no reuse signal.
//! * Hit/miss/eviction counters are exported through the server's
//!   stats frame.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::Lane;

use super::protocol::{lane_tag, RequestMsg};

/// 64-bit FNV-1a over the image dimensions and pixel bytes — the
/// content-address half of a [`CacheKey`]. Dimensions are mixed in so
/// two images with identical bytes at different geometry never share a
/// digest.
pub fn fnv1a64(dims: (u32, u32, u8), bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let (w, hgt, ch) = dims;
    for b in w
        .to_le_bytes()
        .iter()
        .chain(hgt.to_le_bytes().iter())
        .chain(std::iter::once(&ch))
    {
        h = (h ^ u64::from(*b)).wrapping_mul(PRIME);
    }
    for &b in bytes {
        h = (h ^ u64::from(b)).wrapping_mul(PRIME);
    }
    h
}

/// Everything that determines a compress result's bytes. Two requests
/// with equal keys are guaranteed (deterministic pipelines + fixed
/// server quality) to produce identical containers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// FNV-1a of dims + pixels (the content address).
    pub digest: u64,
    /// Dimensions and color flag, kept explicit so a digest collision
    /// across different geometries cannot alias.
    pub width: u32,
    pub height: u32,
    pub color: bool,
    pub variant: u8,
    pub lane: u8,
    /// Chroma subsampling tag for color jobs; `0xFF` for grayscale.
    pub chroma: u8,
    pub want_psnr: bool,
    /// Server-side quality the container was encoded at.
    pub quality: u8,
    /// Restart interval of the emitted CDC2 segments.
    pub restart_interval: u16,
}

impl CacheKey {
    /// Derive the key for a request, or `None` when the request shape
    /// is not cacheable (anything but a compress).
    pub fn for_request(
        msg: &RequestMsg,
        quality: u8,
        restart_interval: u16,
    ) -> Option<CacheKey> {
        match msg {
            RequestMsg::CompressGray {
                image,
                variant,
                lane,
                want_psnr,
            } => Some(CacheKey {
                digest: fnv1a64(
                    (image.width as u32, image.height as u32, 1),
                    &image.data,
                ),
                width: image.width as u32,
                height: image.height as u32,
                color: false,
                variant: crate::codec::variant_tag(*variant),
                lane: lane_tag(*lane),
                chroma: 0xFF,
                want_psnr: *want_psnr,
                quality,
                restart_interval,
            }),
            RequestMsg::CompressColor {
                image,
                variant,
                lane,
                subsampling,
                want_psnr,
            } => Some(CacheKey {
                digest: fnv1a64(
                    (image.width as u32, image.height as u32, 3),
                    &image.data,
                ),
                width: image.width as u32,
                height: image.height as u32,
                color: true,
                variant: crate::codec::variant_tag(*variant),
                lane: lane_tag(*lane),
                chroma: crate::codec::color::subsampling_tag(
                    *subsampling,
                ),
                want_psnr: *want_psnr,
                quality,
                restart_interval,
            }),
            _ => None,
        }
    }
}

/// A cached compress reply: the exact container bytes (shared, not
/// copied, between the cache and in-flight responses) plus the lane
/// that produced them and the PSNR figure when one was computed.
#[derive(Clone, Debug)]
pub struct CachedReply {
    pub lane: Lane,
    pub psnr_db: Option<f64>,
    pub container: Arc<Vec<u8>>,
}

struct Entry {
    reply: CachedReply,
    /// Shard-local LRU clock value at last touch.
    tick: u64,
}

struct Shard {
    map: HashMap<CacheKey, Entry>,
    clock: u64,
    bytes: usize,
}

/// Fixed accounting overhead charged per entry on top of the container
/// bytes (key + entry bookkeeping, hash-map slot).
const ENTRY_OVERHEAD: usize = 96;

fn entry_cost(container: &[u8]) -> usize {
    container.len() + ENTRY_OVERHEAD
}

/// Monotonic cache counters (exported via the stats frame).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
    pub bytes: usize,
    pub budget_bytes: usize,
}

/// Sharded LRU response cache with a byte-size budget.
pub struct ResponseCache {
    shards: Vec<Mutex<Shard>>,
    shard_budget: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl ResponseCache {
    /// `budget_bytes` is split evenly across `shards` mutexed shards
    /// (both floored at 1). The budget bounds container bytes plus a
    /// fixed per-entry overhead.
    pub fn new(budget_bytes: usize, shards: usize) -> ResponseCache {
        let shards = shards.max(1);
        let shard_budget = (budget_bytes / shards).max(1);
        ResponseCache {
            shards: (0..shards)
                .map(|_| {
                    Mutex::new(Shard {
                        map: HashMap::new(),
                        clock: 0,
                        bytes: 0,
                    })
                })
                .collect(),
            shard_budget,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard(&self, key: &CacheKey) -> &Mutex<Shard> {
        &self.shards[(key.digest as usize) % self.shards.len()]
    }

    /// Look up a key, refreshing its LRU position on a hit.
    pub fn get(&self, key: &CacheKey) -> Option<CachedReply> {
        let mut shard = self.shard(key).lock().unwrap();
        shard.clock += 1;
        let tick = shard.clock;
        match shard.map.get_mut(key) {
            Some(e) => {
                e.tick = tick;
                let reply = e.reply.clone();
                drop(shard);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(reply)
            }
            None => {
                drop(shard);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert a reply, evicting least-recently-used entries until it
    /// fits the shard's byte budget. A reply larger than the whole
    /// shard budget is not cached at all.
    pub fn insert(&self, key: CacheKey, reply: CachedReply) {
        let cost = entry_cost(&reply.container);
        if cost > self.shard_budget {
            return;
        }
        let mut evicted = 0u64;
        let mut shard = self.shard(&key).lock().unwrap();
        // replacing an existing entry releases its bytes first
        if let Some(old) = shard.map.remove(&key) {
            shard.bytes -= entry_cost(&old.reply.container);
        }
        while shard.bytes + cost > self.shard_budget {
            // O(n) LRU scan: entry counts stay small (a shard holds at
            // most budget/overhead entries) and eviction is off the
            // hit path, so a heap buys nothing here
            let Some(oldest) = shard
                .map
                .iter()
                .min_by_key(|(_, e)| e.tick)
                .map(|(k, _)| *k)
            else {
                break;
            };
            let old = shard.map.remove(&oldest).expect("key just seen");
            shard.bytes -= entry_cost(&old.reply.container);
            evicted += 1;
        }
        shard.clock += 1;
        let tick = shard.clock;
        shard.bytes += cost;
        shard.map.insert(key, Entry { reply, tick });
        drop(shard);
        if evicted > 0 {
            self.evictions.fetch_add(evicted, Ordering::Relaxed);
        }
    }

    /// Counter + occupancy snapshot.
    pub fn stats(&self) -> CacheStats {
        let (mut entries, mut bytes) = (0usize, 0usize);
        for s in &self.shards {
            let s = s.lock().unwrap();
            entries += s.map.len();
            bytes += s.bytes;
        }
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries,
            bytes,
            budget_bytes: self.shard_budget * self.shards.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dct::Variant;
    use crate::image::synthetic;

    fn key_for(seed: u64, quality: u8) -> CacheKey {
        let msg = RequestMsg::CompressGray {
            image: synthetic::lena_like(16, 16, seed),
            variant: Variant::Cordic,
            lane: Lane::Cpu,
            want_psnr: false,
        };
        CacheKey::for_request(&msg, quality, 4).unwrap()
    }

    fn reply(n: usize) -> CachedReply {
        CachedReply {
            lane: Lane::Cpu,
            psnr_db: None,
            container: Arc::new(vec![7u8; n]),
        }
    }

    #[test]
    fn hit_returns_inserted_bytes_and_counts() {
        let cache = ResponseCache::new(1 << 20, 4);
        let k = key_for(1, 50);
        assert!(cache.get(&k).is_none());
        cache.insert(k, reply(100));
        let hit = cache.get(&k).expect("hit");
        assert_eq!(hit.container.as_slice(), &[7u8; 100][..]);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn distinct_request_shapes_get_distinct_keys() {
        // same pixels, different knobs: every knob must split the key
        let img = synthetic::lena_like(16, 16, 3);
        let base = RequestMsg::CompressGray {
            image: img.clone(),
            variant: Variant::Cordic,
            lane: Lane::Cpu,
            want_psnr: false,
        };
        let k0 = CacheKey::for_request(&base, 50, 4).unwrap();
        assert_ne!(k0, CacheKey::for_request(&base, 70, 4).unwrap());
        assert_ne!(k0, CacheKey::for_request(&base, 50, 8).unwrap());
        let psnr = RequestMsg::CompressGray {
            image: img.clone(),
            variant: Variant::Cordic,
            lane: Lane::Cpu,
            want_psnr: true,
        };
        assert_ne!(k0, CacheKey::for_request(&psnr, 50, 4).unwrap());
        let dct = RequestMsg::CompressGray {
            image: img,
            variant: Variant::Dct,
            lane: Lane::Cpu,
            want_psnr: false,
        };
        assert_ne!(k0, CacheKey::for_request(&dct, 50, 4).unwrap());
        // different pixels: different digest
        assert_ne!(k0, key_for(2, 50));
        // non-compress requests are never cacheable
        assert!(CacheKey::for_request(&RequestMsg::Ping, 50, 4)
            .is_none());
        assert!(CacheKey::for_request(
            &RequestMsg::Decode {
                container: vec![1, 2, 3],
                lane: Lane::Cpu
            },
            50,
            4
        )
        .is_none());
    }

    #[test]
    fn budget_evicts_lru_and_never_overflows() {
        // budget fits two 100-byte entries per shard, not three
        let per_entry = entry_cost(&[0u8; 100]);
        let cache = ResponseCache::new(2 * per_entry + 50, 1);
        let (a, b, c) = (key_for(1, 50), key_for(2, 50), key_for(3, 50));
        cache.insert(a, reply(100));
        cache.insert(b, reply(100));
        // touch `a` so `b` is the LRU victim
        assert!(cache.get(&a).is_some());
        cache.insert(c, reply(100));
        assert!(cache.get(&a).is_some(), "recently used survives");
        assert!(cache.get(&b).is_none(), "LRU entry evicted");
        assert!(cache.get(&c).is_some());
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert!(s.bytes <= s.budget_bytes, "{s:?}");
    }

    #[test]
    fn oversized_entries_are_not_cached() {
        let cache = ResponseCache::new(256, 1);
        let k = key_for(1, 50);
        cache.insert(k, reply(10_000));
        assert!(cache.get(&k).is_none());
        assert_eq!(cache.stats().entries, 0);
    }

    #[test]
    fn reinsert_replaces_without_double_counting() {
        let cache = ResponseCache::new(1 << 16, 1);
        let k = key_for(1, 50);
        cache.insert(k, reply(100));
        let before = cache.stats().bytes;
        cache.insert(k, reply(100));
        assert_eq!(cache.stats().bytes, before);
        assert_eq!(cache.stats().entries, 1);
    }

    #[test]
    fn digest_mixes_dims_and_bytes() {
        let a = fnv1a64((8, 8, 1), &[1, 2, 3]);
        assert_ne!(a, fnv1a64((8, 4, 1), &[1, 2, 3]));
        assert_ne!(a, fnv1a64((8, 8, 3), &[1, 2, 3]));
        assert_ne!(a, fnv1a64((8, 8, 1), &[1, 2, 4]));
        assert_eq!(a, fnv1a64((8, 8, 1), &[1, 2, 3]));
    }
}
