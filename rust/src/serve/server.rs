//! The TCP front-end: listener, admission control, connection pool, and
//! graceful shutdown over a [`Service`].
//!
//! Shape:
//!
//! ```text
//!  TcpListener ──► accept thread ──► ThreadPool (max_connections slots)
//!                     │                  │ one conn::handle per socket
//!                     │ admission gate:  │ frame loop ──► Service queue
//!                     │ at capacity ──►  │ (Backpressure::Reject)
//!                     │ Overloaded frame │
//! ```
//!
//! Two backpressure layers answer with the same structured
//! [`super::protocol::ResponseMsg::Overloaded`] frame: the accept-time
//! admission gate (too many connections) and the coordinator queue
//! (Reject policy — the server forces it so a full queue can never block
//! a connection thread). With [`ServeConfig::degrade`] set, queue-level
//! rejections of compress requests are served a reduced-quality
//! `Degraded` result inline instead of a bare refusal. Shutdown is
//! graceful: the flag flips, the accept loop is unblocked with a
//! best-effort self-connection, and every connection handler finishes
//! its in-flight request before the pool joins.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{Backpressure, Service, ServiceConfig};
use crate::faults::{FaultInjector, FaultPlan};
use crate::log_info;
use crate::util::threadpool::ThreadPool;

use super::cache::ResponseCache;
use super::conn;
use super::framing;
use super::protocol::ResponseMsg;

/// How many cache shards a [`ResponseCache`] is split into.
const CACHE_SHARDS: usize = 8;

/// TCP front-end configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// The coordinator under the socket. `backpressure` is forced to
    /// [`Backpressure::Reject`] at bind time — a full queue must answer
    /// an Overloaded frame, never block a connection thread.
    pub service: ServiceConfig,
    /// Admission-control cap; also the connection pool size, so every
    /// admitted connection owns a handler thread.
    pub max_connections: usize,
    /// Cap on a single request frame's length field.
    pub max_frame_len: usize,
    /// Socket read tick: an idle connection wakes this often to poll the
    /// shutdown flag; a *mid-frame* stall of this long drops the client.
    pub read_timeout: Duration,
    /// A client that cannot absorb its response within this long is
    /// dropped rather than allowed to pin a connection slot.
    pub write_timeout: Duration,
    /// Upper bound on one job's queue + processing time before the
    /// server answers a timeout error frame.
    pub job_timeout: Duration,
    /// Fault-injection plan for chaos testing (socket faults + outbound
    /// bit-flips here; worker faults propagate into the service config
    /// at bind time unless it already has its own plan). `None` — the
    /// default — keeps every injection site at one `Option` check.
    pub faults: Option<FaultPlan>,
    /// Shed load instead of refusing it: when the job queue rejects a
    /// compress request, answer a reduced-quality
    /// [`super::protocol::ResponseMsg::Degraded`] result computed
    /// inline on the serial lane, rather than a bare Overloaded frame.
    pub degrade: bool,
    /// Per-connection cap on in-flight v2 (pipelined) requests. A v2
    /// frame arriving with the window full is answered with a
    /// structured Busy frame carrying this cap. v1 traffic is
    /// unaffected (closed-loop by construction).
    pub max_inflight: usize,
    /// Byte budget for the content-addressed response cache; `0` (the
    /// default) disables caching entirely, keeping library behavior
    /// bit-identical to previous versions unless opted in.
    pub cache_bytes: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            service: ServiceConfig::default(),
            max_connections: 32,
            max_frame_len: framing::MAX_FRAME_LEN_DEFAULT,
            read_timeout: Duration::from_millis(200),
            write_timeout: Duration::from_secs(5),
            job_timeout: Duration::from_secs(30),
            faults: None,
            degrade: false,
            max_inflight: 32,
            cache_bytes: 0,
        }
    }
}

/// Monotonic server counters (exposed through the Stats frame).
#[derive(Debug, Default)]
pub struct Counters {
    pub accepted: AtomicU64,
    pub frames_ok: AtomicU64,
    pub frames_error: AtomicU64,
    pub overload_rejects: AtomicU64,
    /// Load-shed replies served by the `--degrade` path.
    pub degraded: AtomicU64,
}

/// State shared between the accept loop and every connection handler.
pub(crate) struct Shared {
    pub service: Service,
    pub max_frame_len: usize,
    pub read_timeout: Duration,
    pub write_timeout: Duration,
    pub job_timeout: Duration,
    pub shutdown: AtomicBool,
    pub active: AtomicUsize,
    pub counters: Counters,
    /// Root fault injector; each connection forks its own stream keyed
    /// by `fault_seq`.
    pub faults: Option<Arc<FaultInjector>>,
    pub fault_seq: AtomicU64,
    pub degrade: bool,
    pub max_inflight: usize,
    /// Content-addressed response cache; `None` when `cache_bytes` is 0.
    pub cache: Option<Arc<ResponseCache>>,
    /// Copies of the service-side encode knobs that go into cache keys.
    pub quality: u8,
    pub restart_interval: u16,
}

/// Decrements the active-connection gauge when a handler exits — by any
/// path, including a panic unwinding into the pool's catch.
pub(crate) struct ActiveGuard<'a>(pub &'a AtomicUsize);

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A running TCP server. Dropping it (or calling
/// [`TcpServer::shutdown`]) drains in-flight connections and stops the
/// coordinator.
pub struct TcpServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl TcpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving.
    pub fn bind(addr: &str, cfg: ServeConfig) -> Result<TcpServer> {
        let mut svc_cfg = cfg.service.clone();
        svc_cfg.backpressure = Backpressure::Reject;
        // one --faults knob drives both layers: unless the service was
        // given its own plan, the worker-side faults (panic, latency)
        // come from the serve plan too
        if svc_cfg.faults.is_none() {
            svc_cfg.faults = cfg.faults.clone();
        }
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let (quality, restart_interval) =
            (svc_cfg.quality, svc_cfg.restart_interval);
        let service = Service::start(svc_cfg)?;
        let shared = Arc::new(Shared {
            service,
            max_frame_len: cfg.max_frame_len,
            read_timeout: cfg.read_timeout,
            write_timeout: cfg.write_timeout,
            job_timeout: cfg.job_timeout,
            shutdown: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            counters: Counters::default(),
            faults: cfg.faults.as_ref().map(|p| {
                // decorrelate the socket-level stream from the
                // worker-level one (the service builds its own root
                // from the same plan and forks it by worker index)
                let mut plan = p.clone();
                plan.seed = plan.seed.wrapping_add(0x9E37_79B9);
                Arc::new(FaultInjector::new(plan))
            }),
            fault_seq: AtomicU64::new(0),
            degrade: cfg.degrade,
            max_inflight: cfg.max_inflight.max(1),
            cache: (cfg.cache_bytes > 0).then(|| {
                Arc::new(ResponseCache::new(cfg.cache_bytes, CACHE_SHARDS))
            }),
            quality,
            restart_interval,
        });
        let max_conns = cfg.max_connections.max(1);
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("serve-accept".into())
            .spawn(move || accept_loop(listener, accept_shared, max_conns))
            .context("spawning accept thread")?;
        log_info!(
            "serve",
            "listening on {local} ({} connection slots, {} ms read tick)",
            max_conns,
            cfg.read_timeout.as_millis()
        );
        Ok(TcpServer {
            addr: local,
            shared,
            accept: Some(accept),
        })
    }

    /// The bound address (resolves the ephemeral port of `:0` binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Currently admitted connections.
    pub fn active_connections(&self) -> usize {
        self.shared.active.load(Ordering::SeqCst)
    }

    /// Accept-time overload rejections so far.
    pub fn overload_rejects(&self) -> u64 {
        self.shared.counters.overload_rejects.load(Ordering::SeqCst)
    }

    /// Graceful shutdown: stop accepting, let every connection finish
    /// its in-flight request, drain the coordinator workers.
    pub fn shutdown(mut self) {
        self.stop();
        // dropping the last Shared reference runs Service's Drop, which
        // closes the queue and joins the workers
    }

    fn stop(&mut self) {
        // taking the handle makes repeated stops (shutdown() followed by
        // Drop, or a double Drop path) a no-op instead of a second join
        let Some(handle) = self.accept.take() else {
            return;
        };
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // unblock the blocking accept() with a throwaway self-connect.
        // Strictly best-effort with a bounded timeout: if the listener
        // is already gone (raced shutdown, torn-down netns), a failed or
        // hanging connect must not turn a graceful stop into a panic or
        // a wedge — the accept thread also exits on listener errors.
        let _ =
            TcpStream::connect_timeout(&self.addr, Duration::from_secs(1));
        let _ = handle.join();
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(
    listener: TcpListener,
    shared: Arc<Shared>,
    max_conns: usize,
) {
    let pool = ThreadPool::new(max_conns);
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = stream else { continue };
        shared.counters.accepted.fetch_add(1, Ordering::SeqCst);
        // admission control: answer a structured Overloaded frame rather
        // than queueing the socket behind a full pool
        if shared.active.load(Ordering::SeqCst) >= max_conns {
            shared
                .counters
                .overload_rejects
                .fetch_add(1, Ordering::SeqCst);
            let _ = stream.set_write_timeout(Some(shared.write_timeout));
            let (kind, body) = ResponseMsg::Overloaded.encode();
            let mut w = stream;
            let _ = framing::write_frame(&mut w, kind, &body);
            continue;
        }
        shared.active.fetch_add(1, Ordering::SeqCst);
        let sh = Arc::clone(&shared);
        pool.execute(move || {
            let _guard = ActiveGuard(&sh.active);
            conn::handle(stream, &sh);
        });
    }
    // drain: every admitted connection notices the shutdown flag at its
    // next idle tick (or after its in-flight request) and returns
    drop(pool);
}

/// N shared-nothing [`TcpServer`]s, one listener (port-per-shard) each,
/// every shard owning its own coordinator, workers, and response cache.
///
/// std has no portable `SO_REUSEPORT`, so sharding is port-per-shard:
/// shard `i` binds `base_port + i` (an explicit `:0` base gives every
/// shard its own ephemeral port instead). Clients spread load with
/// [`super::client::ShardedClient`]'s round-robin, so there is no
/// shared accept queue — and no shared anything — between shards.
pub struct ShardGroup {
    servers: Vec<TcpServer>,
}

impl ShardGroup {
    /// Bind `shards` servers starting at `addr`. Each shard gets its
    /// own clone of `cfg` with the fault seed decorrelated (shard `i`
    /// adds `i` odd-constant steps) so chaos runs don't fire identical
    /// fault schedules in lockstep across shards.
    pub fn bind(addr: &str, shards: usize, cfg: ServeConfig) -> Result<ShardGroup> {
        let shards = shards.max(1);
        let (host, base_port) = split_host_port(addr)?;
        let mut servers = Vec::with_capacity(shards);
        for i in 0..shards {
            let mut shard_cfg = cfg.clone();
            if let Some(plan) = shard_cfg.faults.as_mut() {
                plan.seed =
                    plan.seed.wrapping_add(i as u64 * 0x6C62_272E_07BB_0143);
            }
            let shard_addr = if base_port == 0 {
                format!("{host}:0")
            } else {
                let port = base_port
                    .checked_add(i as u16)
                    .context("shard port range overflows u16")?;
                format!("{host}:{port}")
            };
            servers.push(TcpServer::bind(&shard_addr, shard_cfg)?);
        }
        Ok(ShardGroup { servers })
    }

    /// Bound address of every shard, in shard order.
    pub fn addrs(&self) -> Vec<SocketAddr> {
        self.servers.iter().map(|s| s.local_addr()).collect()
    }

    pub fn len(&self) -> usize {
        self.servers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.servers.is_empty()
    }

    /// Graceful shutdown of every shard, in order.
    pub fn shutdown(self) {
        for srv in self.servers {
            srv.shutdown();
        }
    }
}

fn split_host_port(addr: &str) -> Result<(&str, u16)> {
    let (host, port) = addr
        .rsplit_once(':')
        .with_context(|| format!("address {addr:?} has no port"))?;
    let port: u16 = port
        .parse()
        .with_context(|| format!("bad port in address {addr:?}"))?;
    Ok((host, port))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ServeConfig {
        ServeConfig {
            service: ServiceConfig {
                workers: 1,
                artifact_dir: None,
                ..Default::default()
            },
            max_connections: 2,
            ..Default::default()
        }
    }

    #[test]
    fn double_shutdown_is_idempotent() {
        let mut srv = TcpServer::bind("127.0.0.1:0", tiny_cfg()).unwrap();
        srv.stop();
        // the second stop models shutdown() followed by Drop (or any
        // re-entry after the listener is gone): it must be a no-op,
        // never a panic or a second blocking join
        srv.stop();
        drop(srv); // Drop's stop() is the third call
    }

    #[test]
    fn shard_group_binds_distinct_ports() {
        let group = ShardGroup::bind("127.0.0.1:0", 3, tiny_cfg()).unwrap();
        let addrs = group.addrs();
        assert_eq!(addrs.len(), 3);
        for (i, a) in addrs.iter().enumerate() {
            for b in &addrs[i + 1..] {
                assert_ne!(a, b, "shards must not share a listener");
            }
        }
        group.shutdown();
    }

    #[test]
    fn split_host_port_parses_and_rejects() {
        assert_eq!(split_host_port("127.0.0.1:7070").unwrap(), ("127.0.0.1", 7070));
        assert_eq!(split_host_port("0.0.0.0:0").unwrap(), ("0.0.0.0", 0));
        assert!(split_host_port("no-port-here").is_err());
        assert!(split_host_port("host:notaport").is_err());
    }

    #[test]
    fn bind_propagates_faults_into_the_service() {
        let mut cfg = tiny_cfg();
        cfg.faults =
            Some(FaultPlan::parse("seed=4,short-read=0.5").unwrap());
        let srv = TcpServer::bind("127.0.0.1:0", cfg).unwrap();
        assert!(srv.shared.faults.is_some());
        srv.shutdown();
    }
}
