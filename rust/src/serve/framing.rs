//! Length-prefixed binary framing over any `Read`/`Write` byte stream.
//!
//! Wire format of one frame:
//!
//! ```text
//! u32 LE length | u8 kind | payload bytes
//! ```
//!
//! `length` covers the kind byte plus the payload, so the smallest legal
//! frame (an empty body) has length 1 and length 0 is a protocol error.
//! The reader enforces a caller-supplied length cap *before* allocating,
//! so a hostile 4-byte prefix cannot balloon server memory.
//!
//! Timeout semantics (the serve path sets a short `read_timeout` on the
//! socket as its poll tick): a timeout with **zero** bytes of the next
//! frame consumed is a benign [`FrameEvent::Idle`] — the connection loop
//! uses it to poll the shutdown flag; a timeout **mid-frame** is a hard
//! error, because a stalled client must not pin a connection slot
//! forever. Likewise EOF is clean only on a frame boundary.

use std::io::{self, Read, Write};

use anyhow::{bail, ensure, Result};

/// Default cap on a single frame's length field (64 MiB — comfortably
/// above the largest legal image payload the protocol accepts).
pub const MAX_FRAME_LEN_DEFAULT: usize = 64 * 1024 * 1024;

/// One read attempt's outcome.
#[derive(Debug)]
pub enum FrameEvent {
    /// A complete frame.
    Frame { kind: u8, payload: Vec<u8> },
    /// The peer closed the stream cleanly between frames.
    Eof,
    /// The read timed out with no bytes of a new frame consumed; the
    /// caller should poll its shutdown flag and retry.
    Idle,
}

fn is_timeout(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
    )
}

/// Fill `buf` completely once a frame has started: EOF and timeouts are
/// hard errors here (`what` names the missing piece for the message).
fn read_exact_started(
    r: &mut impl Read,
    buf: &mut [u8],
    what: &str,
) -> Result<()> {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => bail!(
                "connection closed mid-frame ({got}/{} {what} bytes)",
                buf.len()
            ),
            Ok(n) => got += n,
            Err(e) if is_timeout(&e) => {
                bail!("read timed out mid-frame ({what})")
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Read one frame. `max_len` bounds the length field (see
/// [`MAX_FRAME_LEN_DEFAULT`]).
pub fn read_frame(r: &mut impl Read, max_len: usize) -> Result<FrameEvent> {
    let mut header = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(FrameEvent::Eof),
            Ok(0) => bail!(
                "connection closed mid-frame ({got}/4 length bytes)"
            ),
            Ok(n) => got += n,
            Err(e) if is_timeout(&e) && got == 0 => {
                return Ok(FrameEvent::Idle)
            }
            Err(e) if is_timeout(&e) => {
                bail!("read timed out mid-frame (length prefix)")
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_le_bytes(header) as usize;
    ensure!(len >= 1, "invalid frame: zero length");
    ensure!(
        len <= max_len,
        "frame length {len} exceeds the {max_len}-byte cap"
    );
    let mut kind = [0u8; 1];
    read_exact_started(r, &mut kind, "kind")?;
    let mut payload = vec![0u8; len - 1];
    read_exact_started(r, &mut payload, "payload")?;
    Ok(FrameEvent::Frame {
        kind: kind[0],
        payload,
    })
}

/// Encode one frame to bytes — exactly what [`write_frame`] would put
/// on the wire. Used by tests and tools that need to dribble a frame
/// onto a socket in deliberate fragments (mid-frame fault coverage).
pub fn encode_frame(kind: u8, payload: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(payload.len() + 5);
    write_frame(&mut out, kind, payload)?;
    Ok(out)
}

/// Write one frame and flush it.
pub fn write_frame(
    w: &mut impl Write,
    kind: u8,
    payload: &[u8],
) -> Result<()> {
    let len = u32::try_from(payload.len() + 1)
        .map_err(|_| anyhow::anyhow!("frame payload too large"))?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(&[kind])?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(kind: u8, payload: &[u8]) -> (u8, Vec<u8>) {
        let mut buf = Vec::new();
        write_frame(&mut buf, kind, payload).unwrap();
        match read_frame(&mut Cursor::new(buf), MAX_FRAME_LEN_DEFAULT)
            .unwrap()
        {
            FrameEvent::Frame { kind, payload } => (kind, payload),
            other => panic!("expected frame, got {other:?}"),
        }
    }

    #[test]
    fn frame_roundtrip() {
        let (k, p) = roundtrip(7, b"hello");
        assert_eq!((k, p.as_slice()), (7, b"hello".as_slice()));
        let (k, p) = roundtrip(0xE0, &[]);
        assert_eq!((k, p.len()), (0xE0, 0));
    }

    #[test]
    fn encode_frame_matches_write_frame() {
        let mut written = Vec::new();
        write_frame(&mut written, 5, b"abc").unwrap();
        assert_eq!(encode_frame(5, b"abc").unwrap(), written);
        match read_frame(&mut Cursor::new(written), 1024).unwrap() {
            FrameEvent::Frame { kind, payload } => {
                assert_eq!((kind, payload.as_slice()), (5, b"abc".as_slice()));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn eof_on_boundary_is_clean() {
        let mut empty = Cursor::new(Vec::new());
        assert!(matches!(
            read_frame(&mut empty, 1024).unwrap(),
            FrameEvent::Eof
        ));
    }

    #[test]
    fn zero_length_rejected() {
        let mut c = Cursor::new(vec![0, 0, 0, 0]);
        let e = read_frame(&mut c, 1024).unwrap_err();
        assert!(e.to_string().contains("zero length"), "{e:#}");
    }

    #[test]
    fn oversized_length_rejected_before_alloc() {
        // declares u32::MAX bytes; must fail on the cap, not try to
        // allocate 4 GiB
        let mut c = Cursor::new(vec![0xFF, 0xFF, 0xFF, 0xFF]);
        let e = read_frame(&mut c, 1024).unwrap_err();
        assert!(e.to_string().contains("exceeds"), "{e:#}");
    }

    #[test]
    fn truncated_mid_frame_errors() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 3, b"abcdef").unwrap();
        for cut in 1..buf.len() {
            let mut c = Cursor::new(buf[..cut].to_vec());
            let r = read_frame(&mut c, 1024);
            assert!(
                r.is_err(),
                "cut at {cut}/{} should error",
                buf.len()
            );
        }
    }

    #[test]
    fn back_to_back_frames() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 1, b"a").unwrap();
        write_frame(&mut buf, 2, b"bb").unwrap();
        let mut c = Cursor::new(buf);
        match read_frame(&mut c, 1024).unwrap() {
            FrameEvent::Frame { kind, payload } => {
                assert_eq!((kind, payload.as_slice()), (1, b"a".as_slice()));
            }
            other => panic!("{other:?}"),
        }
        match read_frame(&mut c, 1024).unwrap() {
            FrameEvent::Frame { kind, payload } => {
                assert_eq!((kind, payload.as_slice()), (2, b"bb".as_slice()));
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(
            read_frame(&mut c, 1024).unwrap(),
            FrameEvent::Eof
        ));
    }

    /// A reader that times out before yielding any bytes, then serves a
    /// frame — models the serve loop's idle poll tick.
    struct TimeoutThen {
        timeouts: usize,
        inner: Cursor<Vec<u8>>,
    }

    impl Read for TimeoutThen {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            if self.timeouts > 0 {
                self.timeouts -= 1;
                return Err(io::Error::from(io::ErrorKind::WouldBlock));
            }
            self.inner.read(buf)
        }
    }

    #[test]
    fn timeout_between_frames_is_idle() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 9, b"zz").unwrap();
        let mut r = TimeoutThen {
            timeouts: 2,
            inner: Cursor::new(buf),
        };
        assert!(matches!(
            read_frame(&mut r, 1024).unwrap(),
            FrameEvent::Idle
        ));
        assert!(matches!(
            read_frame(&mut r, 1024).unwrap(),
            FrameEvent::Idle
        ));
        assert!(matches!(
            read_frame(&mut r, 1024).unwrap(),
            FrameEvent::Frame { kind: 9, .. }
        ));
    }

    /// A reader that yields some bytes, then times out forever.
    struct StallAfter {
        inner: Cursor<Vec<u8>>,
        remaining: usize,
    }

    impl Read for StallAfter {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            if self.remaining == 0 {
                return Err(io::Error::from(io::ErrorKind::TimedOut));
            }
            let n = buf.len().min(self.remaining);
            self.remaining -= n;
            self.inner.read(&mut buf[..n])
        }
    }

    #[test]
    fn timeout_mid_frame_is_fatal() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 9, b"payload").unwrap();
        // stall after the length prefix + 2 payload bytes
        let mut r = StallAfter {
            inner: Cursor::new(buf),
            remaining: 6,
        };
        let e = read_frame(&mut r, 1024).unwrap_err();
        assert!(e.to_string().contains("mid-frame"), "{e:#}");
    }
}
