//! Closed-loop load generator for the TCP front-end: N concurrent
//! clients, each issuing back-to-back requests over its own connection,
//! with exact (sorted-sample) latency percentiles.
//!
//! Shared by the `ablation_serve_load` bench target and the `loadgen`
//! CLI subcommand. Percentiles here are computed from the full sample
//! vector rather than [`crate::metrics::stats::LatencyHistogram`]'s log
//! buckets — a load report is small enough to keep every sample, and
//! tail latency is the headline number, so approximation is the wrong
//! trade.

use std::net::SocketAddr;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::Lane;
use crate::dct::Variant;
use crate::image::synthetic;
use crate::util::json::Json;

use super::client::Client;
use super::protocol::{RequestMsg, ResponseMsg};

/// One load run's shape.
#[derive(Clone, Debug)]
pub struct LoadSpec {
    pub addr: SocketAddr,
    /// Concurrent connections.
    pub clients: usize,
    /// Requests each client issues back-to-back.
    pub requests_per_client: usize,
    /// Square synthetic image edge length.
    pub size: usize,
    /// Submit color (CDC3) jobs instead of grayscale.
    pub color: bool,
    pub variant: Variant,
    pub lane: Lane,
    /// `false` exercises the recon-free fast path.
    pub want_psnr: bool,
}

impl LoadSpec {
    pub fn new(addr: SocketAddr) -> LoadSpec {
        LoadSpec {
            addr,
            clients: 4,
            requests_per_client: 16,
            size: 128,
            color: false,
            variant: Variant::Cordic,
            lane: Lane::Cpu,
            want_psnr: false,
        }
    }
}

/// Aggregate results of one load run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub clients: usize,
    pub total: usize,
    pub ok: usize,
    /// Structured Overloaded replies (backpressure, not failure).
    pub overloaded: usize,
    /// Error frames.
    pub failed: usize,
    pub elapsed_s: f64,
    /// Successful requests per wall-clock second.
    pub throughput_rps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LoadReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("clients", self.clients.into()),
            ("total", self.total.into()),
            ("ok", self.ok.into()),
            ("overloaded", self.overloaded.into()),
            ("failed", self.failed.into()),
            ("elapsed_s", Json::num(self.elapsed_s)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("mean_ms", Json::num(self.mean_ms)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p95_ms", Json::num(self.p95_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("max_ms", Json::num(self.max_ms)),
        ])
    }
}

impl std::fmt::Display for LoadReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} clients: {} ok / {} overloaded / {} failed in {:.2}s \
             = {:.1} req/s; latency mean {:.2} p50 {:.2} p95 {:.2} \
             p99 {:.2} max {:.2} ms",
            self.clients,
            self.ok,
            self.overloaded,
            self.failed,
            self.elapsed_s,
            self.throughput_rps,
            self.mean_ms,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.max_ms
        )
    }
}

/// Exact percentile over an ascending-sorted sample (nearest-rank).
pub fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted_ms.len() - 1) as f64 * p).round() as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

#[derive(Default)]
struct ClientOut {
    latencies_ms: Vec<f64>,
    ok: usize,
    overloaded: usize,
    failed: usize,
}

fn client_loop(spec: &LoadSpec, ci: usize) -> Result<ClientOut> {
    let mut client = Client::connect(spec.addr)
        .with_context(|| format!("loadgen client {ci}"))?;
    // build the request once outside the timed loop — the generator
    // measures the server, not synthetic-image synthesis
    let seed = ci as u64 + 1;
    let msg = if spec.color {
        RequestMsg::CompressColor {
            image: synthetic::lena_like_rgb(spec.size, spec.size, seed),
            variant: spec.variant,
            lane: spec.lane,
            subsampling: crate::image::ycbcr::Subsampling::S420,
            want_psnr: spec.want_psnr,
        }
    } else {
        RequestMsg::CompressGray {
            image: synthetic::lena_like(spec.size, spec.size, seed),
            variant: spec.variant,
            lane: spec.lane,
            want_psnr: spec.want_psnr,
        }
    };
    let mut out = ClientOut::default();
    for i in 0..spec.requests_per_client {
        let t = Instant::now();
        let resp = client
            .request(&msg)
            .with_context(|| format!("client {ci} request {i}"))?;
        let ms = t.elapsed().as_secs_f64() * 1e3;
        match resp {
            ResponseMsg::Compressed { .. } => {
                out.latencies_ms.push(ms);
                out.ok += 1;
            }
            ResponseMsg::Overloaded => out.overloaded += 1,
            _ => out.failed += 1,
        }
    }
    Ok(out)
}

/// Run one closed-loop load test against a live server.
pub fn run_load(spec: &LoadSpec) -> Result<LoadReport> {
    let t0 = Instant::now();
    let outs: Vec<Result<ClientOut>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..spec.clients)
            .map(|ci| s.spawn(move || client_loop(spec, ci)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("loadgen client thread panicked"))
            .collect()
    });
    let elapsed_s = t0.elapsed().as_secs_f64();
    let mut all = Vec::new();
    let (mut ok, mut overloaded, mut failed) = (0usize, 0usize, 0usize);
    for out in outs {
        let out = out?;
        all.extend_from_slice(&out.latencies_ms);
        ok += out.ok;
        overloaded += out.overloaded;
        failed += out.failed;
    }
    all.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean_ms = if all.is_empty() {
        f64::NAN
    } else {
        all.iter().sum::<f64>() / all.len() as f64
    };
    Ok(LoadReport {
        clients: spec.clients,
        total: spec.clients * spec.requests_per_client,
        ok,
        overloaded,
        failed,
        elapsed_s,
        throughput_rps: ok as f64 / elapsed_s.max(1e-9),
        mean_ms,
        p50_ms: percentile(&all, 0.50),
        p95_ms: percentile(&all, 0.95),
        p99_ms: percentile(&all, 0.99),
        max_ms: all.last().copied().unwrap_or(f64::NAN),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_exact_on_small_samples() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&v, 0.50), 51.0); // nearest-rank on 0..=99
        assert_eq!(percentile(&v, 0.95), 95.0);
        assert!(percentile(&[], 0.5).is_nan());
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
    }
}
