//! Load generator for the TCP front-end: N concurrent clients, each
//! over its own connection, with exact (sorted-sample) latency
//! percentiles. Closed-loop by default; [`LoadSpec::pipeline`] ≥ 2
//! switches each client to a [`MuxClient`] keeping that many requests
//! in flight (protocol v2), which is what actually measures server
//! throughput instead of round-trip latency. [`LoadSpec::addrs`]
//! spreads clients round-robin over a shard group, and
//! [`LoadSpec::mix`] picks the image workload — one image per client
//! (the historical shape), unique per request (cache-cold), or a small
//! shared pool (cache-hot).
//!
//! Shared by the `ablation_serve_load` / `ablation_chaos` bench targets
//! and the `loadgen` CLI subcommand. Percentiles here are computed from
//! the full sample vector rather than
//! [`crate::metrics::stats::LatencyHistogram`]'s log buckets — a load
//! report is small enough to keep every sample, and tail latency is the
//! headline number, so approximation is the wrong trade. In chaos mode
//! the percentile samples use [`RetryClient::last_service_time`] — the
//! wire time of the attempt that answered — not the caller's total
//! elapsed time, which would conflate server latency with connect,
//! backoff, and failed-attempt recovery.
//!
//! With [`LoadSpec::faults`] set, the generator becomes the chaos-soak
//! harness: each client switches to a [`RetryClient`] (backoff + circuit
//! breaker, per-attempt deadline) and the loop verifies the resilience
//! invariants instead of bailing on the first transport error —
//!
//! 1. no request may outlive the retry policy's worst-case budget
//!    ([`RetryPolicy::total_budget`]), and
//! 2. every success must carry a decodable container that is bit-exact
//!    against the client's first intact reply (the protocol has no
//!    checksum, so an injected bit-flip must be *caught here* as a
//!    decode error, never silently counted as a success), and
//! 3. a corrupted container must be *detectably* corrupted: the salvage
//!    decoder may recover it (counted in [`ErrorCounts::salvaged`],
//!    never as a bit-exact success), but if it reports the damaged
//!    bytes as clean the detection contract is broken.
//!
//! Violations are tallied in [`LoadReport::invariant_violations`]; the
//! CI chaos job fails when the count is nonzero.

use std::collections::HashMap;
use std::net::SocketAddr;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::Lane;
use crate::dct::Variant;
use crate::image::synthetic;
use crate::util::json::Json;

use super::client::{
    Client, MuxClient, MuxEvent, RequestError, RetryClient, RetryPolicy,
};
use super::protocol::{
    RequestMsg, ResponseMsg, ERR_DECODE_CORRUPT, ERR_DECODE_TRUNCATED,
    ERR_JOB_TIMEOUT, ERR_WORKER_PANIC,
};

/// Which image(s) the clients compress — the cache-hit-ratio knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ImageMix {
    /// One image per client (seed = client index + 1) — the historical
    /// closed-loop shape; repeat requests hit the cache after each
    /// client's first.
    PerClient,
    /// A fresh image for every request: every compress is cold, the
    /// cache never hits.
    Unique,
    /// All clients draw round-robin from a shared pool of `k` images:
    /// after at most `k` cold compressions per shard the steady state
    /// is (nearly) all hits — `Shared(1)` gives a ≥90% hit ratio on
    /// any run of ≥10 requests.
    Shared(usize),
}

/// One load run's shape.
#[derive(Clone, Debug)]
pub struct LoadSpec {
    pub addr: SocketAddr,
    /// Shard addresses; empty means "just [`LoadSpec::addr`]". Client
    /// `i` connects to `addrs[i % addrs.len()]`, spreading a multi-
    /// client run over every shard.
    pub addrs: Vec<SocketAddr>,
    /// In-flight requests each client keeps pipelined over its one
    /// connection (protocol v2). `0` or `1` is the classic closed
    /// loop on the v1 protocol.
    pub pipeline: usize,
    /// Image workload shape (cache-hit-ratio knob).
    pub mix: ImageMix,
    /// Concurrent connections.
    pub clients: usize,
    /// Requests each client issues back-to-back.
    pub requests_per_client: usize,
    /// Square synthetic image edge length.
    pub size: usize,
    /// Submit color (CDC3) jobs instead of grayscale.
    pub color: bool,
    pub variant: Variant,
    pub lane: Lane,
    /// `false` exercises the recon-free fast path.
    pub want_psnr: bool,
    /// Chaos mode: retrying clients, invariant checks, and per-frame
    /// error classification instead of fail-fast transport errors.
    pub faults: bool,
    /// Per-attempt response deadline for chaos-mode clients.
    pub deadline: Duration,
    /// Seeds the per-client retry jitter streams (client `i` uses
    /// `seed + i`), so a chaos run's schedule reproduces exactly.
    pub seed: u64,
}

impl LoadSpec {
    pub fn new(addr: SocketAddr) -> LoadSpec {
        LoadSpec {
            addr,
            addrs: Vec::new(),
            pipeline: 0,
            mix: ImageMix::PerClient,
            clients: 4,
            requests_per_client: 16,
            size: 128,
            color: false,
            variant: Variant::Cordic,
            lane: Lane::Cpu,
            want_psnr: false,
            faults: false,
            deadline: Duration::from_secs(10),
            seed: 1,
        }
    }

    /// The shard a given client connects to.
    pub fn addr_for(&self, ci: usize) -> SocketAddr {
        if self.addrs.is_empty() {
            self.addr
        } else {
            self.addrs[ci % self.addrs.len()]
        }
    }
}

/// Failed requests broken down by cause.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ErrorCounts {
    /// Job or response deadline expiries.
    pub timeouts: usize,
    /// Connect failures, dropped connections, open circuit breakers.
    pub connect: usize,
    /// Undecodable or corrupted (non-bit-exact) payloads.
    pub decode: usize,
    /// Structured worker-panic replies.
    pub panics: usize,
    /// Every other server error frame.
    pub server: usize,
    /// Corrupted containers the salvage decoder recovered with an
    /// honest (non-zero) damage report. A distinct outcome: neither a
    /// bit-exact success nor a failure, so [`ErrorCounts::total`]
    /// excludes it.
    pub salvaged: usize,
}

impl ErrorCounts {
    /// Failed requests. `salvaged` is excluded: a recovered-with-damage
    /// decode is an outcome of its own, not a failure.
    pub fn total(&self) -> usize {
        self.timeouts + self.connect + self.decode + self.panics
            + self.server
    }
}

/// Aggregate results of one load run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub clients: usize,
    pub total: usize,
    pub ok: usize,
    /// Structured Overloaded replies (backpressure, not failure).
    pub overloaded: usize,
    /// Error frames.
    pub failed: usize,
    /// Failures by cause (sums to `failed` in chaos mode).
    pub errors: ErrorCounts,
    /// Load-shed `Degraded` replies (verified, but not counted as ok).
    pub degraded: usize,
    /// Chaos-mode retry attempts beyond each request's first try.
    pub retries: u64,
    /// Resilience invariant violations — must be zero for a passing
    /// chaos soak.
    pub invariant_violations: usize,
    /// `(overloaded + failed) / total`.
    pub error_rate: f64,
    pub elapsed_s: f64,
    /// Successful requests per wall-clock second.
    pub throughput_rps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LoadReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("clients", self.clients.into()),
            ("total", self.total.into()),
            ("ok", self.ok.into()),
            ("overloaded", self.overloaded.into()),
            ("failed", self.failed.into()),
            ("timeouts", self.errors.timeouts.into()),
            ("connect_errors", self.errors.connect.into()),
            ("decode_errors", self.errors.decode.into()),
            ("panics", self.errors.panics.into()),
            ("server_errors", self.errors.server.into()),
            ("salvaged", self.errors.salvaged.into()),
            ("degraded", self.degraded.into()),
            ("retries", Json::num(self.retries as f64)),
            (
                "invariant_violations",
                self.invariant_violations.into(),
            ),
            ("error_rate", Json::num(self.error_rate)),
            ("elapsed_s", Json::num(self.elapsed_s)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("mean_ms", Json::num(self.mean_ms)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p95_ms", Json::num(self.p95_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("max_ms", Json::num(self.max_ms)),
        ])
    }
}

impl std::fmt::Display for LoadReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} clients: {} ok / {} overloaded / {} failed / {} degraded \
             / {} salvaged in {:.2}s = {:.1} req/s; latency mean {:.2} \
             p50 {:.2} p95 {:.2} p99 {:.2} max {:.2} ms; {} retries, \
             {} invariant violations",
            self.clients,
            self.ok,
            self.overloaded,
            self.failed,
            self.degraded,
            self.errors.salvaged,
            self.elapsed_s,
            self.throughput_rps,
            self.mean_ms,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.max_ms,
            self.retries,
            self.invariant_violations
        )
    }
}

/// Exact percentile over an ascending-sorted sample (nearest-rank).
pub fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted_ms.len() - 1) as f64 * p).round() as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

#[derive(Default)]
struct ClientOut {
    latencies_ms: Vec<f64>,
    ok: usize,
    overloaded: usize,
    failed: usize,
    errors: ErrorCounts,
    degraded: usize,
    retries: u64,
    violations: usize,
}

/// Bucket a server error frame's code.
fn classify_code(code: u16, errors: &mut ErrorCounts) {
    match code {
        ERR_WORKER_PANIC => errors.panics += 1,
        ERR_JOB_TIMEOUT => errors.timeouts += 1,
        ERR_DECODE_TRUNCATED..=ERR_DECODE_CORRUPT => errors.decode += 1,
        _ => errors.server += 1,
    }
}

/// The image seed for client `ci`'s `ri`-th request under the spec's
/// [`ImageMix`].
fn mix_seed(spec: &LoadSpec, ci: usize, ri: usize) -> u64 {
    match spec.mix {
        ImageMix::PerClient => ci as u64 + 1,
        // offset keeps unique draws disjoint from the per-client and
        // shared-pool seed ranges
        ImageMix::Unique => {
            0x5EED_0000 + (ci * spec.requests_per_client + ri) as u64
        }
        ImageMix::Shared(k) => (ri % k.max(1)) as u64 + 1,
    }
}

/// Build the one request a client repeats for the whole run.
fn build_request(spec: &LoadSpec, ci: usize) -> RequestMsg {
    build_request_seeded(spec, ci as u64 + 1)
}

/// Build a request around the synthetic image drawn from `seed`.
fn build_request_seeded(spec: &LoadSpec, seed: u64) -> RequestMsg {
    if spec.color {
        RequestMsg::CompressColor {
            image: synthetic::lena_like_rgb(spec.size, spec.size, seed),
            variant: spec.variant,
            lane: spec.lane,
            subsampling: crate::image::ycbcr::Subsampling::S420,
            want_psnr: spec.want_psnr,
        }
    } else {
        RequestMsg::CompressGray {
            image: synthetic::lena_like(spec.size, spec.size, seed),
            variant: spec.variant,
            lane: spec.lane,
            want_psnr: spec.want_psnr,
        }
    }
}

/// How a corrupted (non-bit-exact) container fared under the salvage
/// decoder.
enum SalvageVerdict {
    /// Recovered at the requested geometry with a non-zero damage
    /// report — the honest outcome for a detectable bit-flip.
    Recovered,
    /// The salvage decoder called the damaged bytes clean: the
    /// detection contract is broken.
    ClaimedClean,
    /// Salvage failed outright (destroyed head) or came back at the
    /// wrong geometry.
    Unrecoverable,
}

/// Classify a container that failed the bit-exactness check.
fn salvage_check(spec: &LoadSpec, bytes: &[u8]) -> SalvageVerdict {
    let (dims_ok, clean) = if spec.color {
        match crate::codec::color::decode_salvage(bytes) {
            Ok((d, r)) => (
                d.header.width as usize == spec.size
                    && d.header.height as usize == spec.size,
                r.is_clean(),
            ),
            Err(_) => return SalvageVerdict::Unrecoverable,
        }
    } else {
        match crate::codec::decoder::decode_salvage(bytes) {
            Ok((d, r)) => (
                d.header.width as usize == spec.size
                    && d.header.height as usize == spec.size,
                r.is_clean(),
            ),
            Err(_) => return SalvageVerdict::Unrecoverable,
        }
    };
    match (dims_ok, clean) {
        (true, false) => SalvageVerdict::Recovered,
        (true, true) => SalvageVerdict::ClaimedClean,
        (false, _) => SalvageVerdict::Unrecoverable,
    }
}

/// Does the container decode, with the dimensions the client asked for?
fn verify_container(spec: &LoadSpec, bytes: &[u8]) -> bool {
    if spec.color {
        crate::codec::color::decode(bytes)
            .map(|d| {
                d.header.width as usize == spec.size
                    && d.header.height as usize == spec.size
            })
            .unwrap_or(false)
    } else {
        crate::codec::decoder::decode(bytes)
            .map(|d| {
                d.header.width as usize == spec.size
                    && d.header.height as usize == spec.size
            })
            .unwrap_or(false)
    }
}

fn client_loop(spec: &LoadSpec, ci: usize) -> Result<ClientOut> {
    let mut client = Client::connect(spec.addr_for(ci))
        .with_context(|| format!("loadgen client {ci}"))?;
    // build the request once outside the timed loop — the generator
    // measures the server, not synthetic-image synthesis
    let base = build_request(spec, ci);
    let mut out = ClientOut::default();
    for i in 0..spec.requests_per_client {
        let built;
        let msg: &RequestMsg = match spec.mix {
            ImageMix::PerClient => &base,
            _ => {
                // varying mixes synthesize per request — still outside
                // the timed section
                built =
                    build_request_seeded(spec, mix_seed(spec, ci, i));
                &built
            }
        };
        let t = Instant::now();
        let resp = client
            .request(msg)
            .with_context(|| format!("client {ci} request {i}"))?;
        let ms = t.elapsed().as_secs_f64() * 1e3;
        match resp {
            ResponseMsg::Compressed { .. } => {
                out.latencies_ms.push(ms);
                out.ok += 1;
            }
            ResponseMsg::Degraded { .. } => out.degraded += 1,
            ResponseMsg::Overloaded => out.overloaded += 1,
            ResponseMsg::Error { code, .. } => {
                out.failed += 1;
                classify_code(code, &mut out.errors);
            }
            _ => out.failed += 1,
        }
    }
    Ok(out)
}

/// Chaos-mode client: never bails — every outcome is classified, and
/// the two soak invariants are checked per request.
fn chaos_client_loop(spec: &LoadSpec, ci: usize) -> ClientOut {
    let policy = RetryPolicy {
        attempt_deadline: spec.deadline,
        jitter_seed: spec.seed.wrapping_add(ci as u64),
        ..RetryPolicy::default()
    };
    let budget = policy.total_budget();
    let mut client = RetryClient::new(spec.addr_for(ci), policy);
    let base = build_request(spec, ci);
    let mut out = ClientOut::default();
    // first intact container per image seed; later successes for the
    // same seed must match it bit-exactly (same request, deterministic
    // pipeline — cached or not), or a bit-flip got through
    let mut references: HashMap<u64, Vec<u8>> = HashMap::new();
    for i in 0..spec.requests_per_client {
        let seed = mix_seed(spec, ci, i);
        let built;
        let msg: &RequestMsg = match spec.mix {
            ImageMix::PerClient => &base,
            _ => {
                built = build_request_seeded(spec, seed);
                &built
            }
        };
        let t = Instant::now();
        let resp = client.request(msg);
        let elapsed = t.elapsed();
        if elapsed > budget {
            out.violations += 1;
        }
        match resp {
            Ok(ResponseMsg::Compressed { container, .. }) => {
                let reference = references.get(&seed);
                let intact = verify_container(spec, &container)
                    && reference
                        .map_or(true, |r| *r == container);
                if intact {
                    // sample the answering attempt's wire time, not the
                    // total elapsed (which absorbs connects + backoff)
                    let service = client
                        .last_service_time()
                        .unwrap_or(elapsed);
                    if reference.is_none() {
                        references.insert(seed, container);
                    }
                    out.latencies_ms
                        .push(service.as_secs_f64() * 1e3);
                    out.ok += 1;
                } else {
                    match salvage_check(spec, &container) {
                        SalvageVerdict::Recovered => {
                            out.errors.salvaged += 1;
                        }
                        SalvageVerdict::ClaimedClean => {
                            // corrupted bytes reported clean — the
                            // damage-detection invariant is broken
                            out.violations += 1;
                            out.failed += 1;
                            out.errors.decode += 1;
                        }
                        SalvageVerdict::Unrecoverable => {
                            out.failed += 1;
                            out.errors.decode += 1;
                        }
                    }
                }
            }
            // degraded containers use a different quality, so they are
            // checked for decodability but not against the reference
            Ok(ResponseMsg::Degraded { container, .. }) => {
                if verify_container(spec, &container) {
                    out.degraded += 1;
                } else {
                    out.failed += 1;
                    out.errors.decode += 1;
                }
            }
            Ok(ResponseMsg::Overloaded) => out.overloaded += 1,
            Ok(ResponseMsg::Error { code, .. }) => {
                out.failed += 1;
                classify_code(code, &mut out.errors);
            }
            Ok(_) => out.failed += 1,
            Err(RequestError::Overloaded) => out.overloaded += 1,
            Err(RequestError::Timeout(_)) => {
                out.failed += 1;
                out.errors.timeouts += 1;
            }
            Err(RequestError::Connect(_))
            | Err(RequestError::CircuitOpen) => {
                out.failed += 1;
                out.errors.connect += 1;
            }
            Err(RequestError::Malformed(_)) => {
                out.failed += 1;
                out.errors.decode += 1;
            }
            Err(RequestError::Server { code, .. }) => {
                out.failed += 1;
                classify_code(code, &mut out.errors);
            }
        }
    }
    out.retries = client.retries();
    out
}

/// Pipelined (protocol v2) client: keep `spec.pipeline` requests in
/// flight, match completions by request id, fail fast on transport
/// errors (the chaos-tolerant variant is [`chaos_mux_loop`]).
///
/// Latency samples span send → completion, so under a deep window they
/// include server-side queueing — that is the point: the closed-loop
/// sweep measures round trips, this one measures the server's ability
/// to overlap work.
fn mux_client_loop(spec: &LoadSpec, ci: usize) -> Result<ClientOut> {
    let depth = spec.pipeline.max(2);
    let mut client = MuxClient::connect(spec.addr_for(ci))
        .with_context(|| format!("loadgen mux client {ci}"))?
        .with_deadline(spec.deadline);
    let mut out = ClientOut::default();
    let mut inflight: HashMap<u64, Instant> = HashMap::new();
    let total = spec.requests_per_client;
    let mut sent = 0usize;
    while sent < total || !inflight.is_empty() {
        while sent < total && inflight.len() < depth {
            let msg =
                build_request_seeded(spec, mix_seed(spec, ci, sent));
            let id = client
                .send(&msg)
                .with_context(|| format!("client {ci} send {sent}"))?;
            inflight.insert(id, Instant::now());
            sent += 1;
        }
        let event = client
            .recv()
            .with_context(|| format!("client {ci} recv"))?;
        match event {
            MuxEvent::Response { request_id, msg } => {
                let Some(t) = inflight.remove(&request_id) else {
                    bail!(
                        "client {ci}: response for unknown request id \
                         {request_id}"
                    );
                };
                let ms = t.elapsed().as_secs_f64() * 1e3;
                match msg {
                    ResponseMsg::Compressed { .. } => {
                        out.latencies_ms.push(ms);
                        out.ok += 1;
                    }
                    ResponseMsg::Degraded { .. } => out.degraded += 1,
                    ResponseMsg::Overloaded => out.overloaded += 1,
                    ResponseMsg::Error { code, .. } => {
                        out.failed += 1;
                        classify_code(code, &mut out.errors);
                    }
                    _ => out.failed += 1,
                }
            }
            MuxEvent::Busy { request_id, .. } => {
                // nothing ran; the slot is free again immediately
                inflight.remove(&request_id);
                out.overloaded += 1;
            }
        }
    }
    Ok(out)
}

/// Write off every in-flight request on a dead connection.
fn write_off_pending(
    pending: &mut HashMap<u64, (u64, Instant)>,
    out: &mut ClientOut,
    done: &mut usize,
    timeouts: bool,
) {
    for _ in pending.drain() {
        out.failed += 1;
        if timeouts {
            out.errors.timeouts += 1;
        } else {
            out.errors.connect += 1;
        }
        *done += 1;
    }
}

/// Chaos-tolerant pipelined client: reconnects on transport errors
/// (writing off in-flight requests), classifies every completion, and
/// checks the bit-exactness/salvage invariants per image seed — a
/// cached reply that survived corruption must still never count as
/// success.
fn chaos_mux_loop(spec: &LoadSpec, ci: usize) -> ClientOut {
    let addr = spec.addr_for(ci);
    let depth = spec.pipeline.max(2);
    let total = spec.requests_per_client;
    let mut out = ClientOut::default();
    let mut references: HashMap<u64, Vec<u8>> = HashMap::new();
    // request id -> (image seed, send time)
    let mut pending: HashMap<u64, (u64, Instant)> = HashMap::new();
    let mut client: Option<MuxClient> = None;
    let mut connected_once = false;
    let mut sent = 0usize;
    let mut done = 0usize;
    'outer: while done < total {
        let c = match client.as_mut() {
            Some(c) => c,
            None => {
                match MuxClient::connect_timeout(
                    &addr,
                    Duration::from_secs(2),
                ) {
                    Ok(c) => {
                        // reconnects (not the first connect) count as
                        // retries in the report
                        if connected_once {
                            out.retries += 1;
                        }
                        connected_once = true;
                        client = Some(c.with_deadline(spec.deadline));
                        client.as_mut().expect("just connected")
                    }
                    Err(_) => {
                        // a dead shard consumes one request slot per
                        // failed connect so the soak always terminates
                        if sent < total {
                            sent += 1;
                        }
                        out.failed += 1;
                        out.errors.connect += 1;
                        done += 1;
                        std::thread::sleep(Duration::from_millis(50));
                        continue;
                    }
                }
            }
        };
        while sent < total && pending.len() < depth {
            let seed = mix_seed(spec, ci, sent);
            let msg = build_request_seeded(spec, seed);
            match c.send(&msg) {
                Ok(id) => {
                    pending.insert(id, (seed, Instant::now()));
                    sent += 1;
                }
                Err(_) => {
                    write_off_pending(
                        &mut pending,
                        &mut out,
                        &mut done,
                        false,
                    );
                    client = None;
                    continue 'outer;
                }
            }
        }
        if pending.is_empty() {
            continue;
        }
        match c.recv() {
            Ok(MuxEvent::Response { request_id, msg }) => {
                let Some((seed, t0)) = pending.remove(&request_id)
                else {
                    // an id this client never sent (or already wrote
                    // off): a correlation bug on the server
                    out.violations += 1;
                    continue;
                };
                done += 1;
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                match msg {
                    ResponseMsg::Compressed { container, .. } => {
                        let reference = references.get(&seed);
                        let intact = verify_container(spec, &container)
                            && reference
                                .map_or(true, |r| *r == container);
                        if intact {
                            if reference.is_none() {
                                references.insert(seed, container);
                            }
                            out.latencies_ms.push(ms);
                            out.ok += 1;
                        } else {
                            match salvage_check(spec, &container) {
                                SalvageVerdict::Recovered => {
                                    out.errors.salvaged += 1;
                                }
                                SalvageVerdict::ClaimedClean => {
                                    out.violations += 1;
                                    out.failed += 1;
                                    out.errors.decode += 1;
                                }
                                SalvageVerdict::Unrecoverable => {
                                    out.failed += 1;
                                    out.errors.decode += 1;
                                }
                            }
                        }
                    }
                    ResponseMsg::Degraded { container, .. } => {
                        if verify_container(spec, &container) {
                            out.degraded += 1;
                        } else {
                            out.failed += 1;
                            out.errors.decode += 1;
                        }
                    }
                    ResponseMsg::Overloaded => out.overloaded += 1,
                    ResponseMsg::Error { code, .. } => {
                        out.failed += 1;
                        classify_code(code, &mut out.errors);
                    }
                    _ => out.failed += 1,
                }
            }
            Ok(MuxEvent::Busy { request_id, .. }) => {
                if pending.remove(&request_id).is_some() {
                    out.overloaded += 1;
                    done += 1;
                }
            }
            Err(RequestError::Timeout(_)) => {
                // no frame at all within the deadline: everything in
                // flight is written off as timed out
                write_off_pending(&mut pending, &mut out, &mut done, true);
                client = None;
            }
            Err(RequestError::Malformed(_)) => {
                // an undecodable frame has no id to correlate; the
                // stream is unusable and in-flight attribution is lost
                for _ in pending.drain() {
                    out.failed += 1;
                    out.errors.decode += 1;
                    done += 1;
                }
                client = None;
            }
            Err(_) => {
                write_off_pending(
                    &mut pending,
                    &mut out,
                    &mut done,
                    false,
                );
                client = None;
            }
        }
    }
    out
}

/// Run one load test against a live server (closed-loop, or pipelined
/// when [`LoadSpec::pipeline`] ≥ 2).
pub fn run_load(spec: &LoadSpec) -> Result<LoadReport> {
    let pipelined = spec.pipeline >= 2;
    let t0 = Instant::now();
    let outs: Vec<Result<ClientOut>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..spec.clients)
            .map(|ci| {
                s.spawn(move || match (spec.faults, pipelined) {
                    (true, true) => Ok(chaos_mux_loop(spec, ci)),
                    (true, false) => Ok(chaos_client_loop(spec, ci)),
                    (false, true) => mux_client_loop(spec, ci),
                    (false, false) => client_loop(spec, ci),
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("loadgen client thread panicked"))
            .collect()
    });
    let elapsed_s = t0.elapsed().as_secs_f64();
    let mut all = Vec::new();
    let (mut ok, mut overloaded, mut failed) = (0usize, 0usize, 0usize);
    let mut errors = ErrorCounts::default();
    let (mut degraded, mut retries) = (0usize, 0u64);
    let mut violations = 0usize;
    for out in outs {
        let out = out?;
        all.extend_from_slice(&out.latencies_ms);
        ok += out.ok;
        overloaded += out.overloaded;
        failed += out.failed;
        errors.timeouts += out.errors.timeouts;
        errors.connect += out.errors.connect;
        errors.decode += out.errors.decode;
        errors.panics += out.errors.panics;
        errors.server += out.errors.server;
        errors.salvaged += out.errors.salvaged;
        degraded += out.degraded;
        retries += out.retries;
        violations += out.violations;
    }
    all.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean_ms = if all.is_empty() {
        f64::NAN
    } else {
        all.iter().sum::<f64>() / all.len() as f64
    };
    let total = spec.clients * spec.requests_per_client;
    Ok(LoadReport {
        clients: spec.clients,
        total,
        ok,
        overloaded,
        failed,
        errors,
        degraded,
        retries,
        invariant_violations: violations,
        error_rate: (overloaded + failed) as f64 / total.max(1) as f64,
        elapsed_s,
        throughput_rps: ok as f64 / elapsed_s.max(1e-9),
        mean_ms,
        p50_ms: percentile(&all, 0.50),
        p95_ms: percentile(&all, 0.95),
        p99_ms: percentile(&all, 0.99),
        max_ms: all.last().copied().unwrap_or(f64::NAN),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_exact_on_small_samples() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&v, 0.50), 51.0); // nearest-rank on 0..=99
        assert_eq!(percentile(&v, 0.95), 95.0);
        assert!(percentile(&[], 0.5).is_nan());
        assert_eq!(percentile(&[7.0], 0.99), 7.0);
    }

    #[test]
    fn error_codes_bucket_into_counts() {
        let mut e = ErrorCounts::default();
        classify_code(ERR_WORKER_PANIC, &mut e);
        classify_code(ERR_JOB_TIMEOUT, &mut e);
        classify_code(ERR_DECODE_TRUNCATED, &mut e);
        classify_code(ERR_DECODE_CORRUPT, &mut e);
        classify_code(1, &mut e); // bad frame → generic server bucket
        assert_eq!(
            (e.panics, e.timeouts, e.decode, e.server),
            (1, 1, 2, 1)
        );
        assert_eq!(e.total(), 5);
        // salvaged is a distinct outcome, never folded into failures
        e.salvaged = 3;
        assert_eq!(e.total(), 5);
    }
}
