//! The serve wire protocol: typed request/response messages and their
//! binary encoding inside [`super::framing`] frames.
//!
//! Every payload is little-endian and self-describing enough to validate
//! before any work happens: image dimensions are checked against the
//! codec's [`crate::codec::MAX_PIXELS`] cap and against the actual byte
//! count in the frame, so a hostile header cannot make the server
//! allocate beyond the frame it already read.
//!
//! ```text
//! requests                         responses
//! 1 CompressGray                   0x81 Compressed
//! 2 CompressColor                  0x82 Image (decode / histeq result)
//! 3 Decode                         0x83 Pong
//! 4 Histeq                         0x84 StatsJson
//! 5 Ping                           0x85 Degraded (load-shed compress)
//! 6 Stats                          0x86 Salvaged (salvage decode result)
//! 7 DecodeSalvage                  0xE0 Error { code, message }
//!                                  0xE1 Overloaded
//! ```
//!
//! Error codes 10..=14 mirror [`DecodeErrorKind`] one-to-one, so a
//! client can tell a truncated upload from a corrupt entropy stream
//! without parsing message text.

use anyhow::{bail, ensure, Result};

use crate::codec::{self, DecodeErrorKind};
use crate::coordinator::Lane;
use crate::dct::Variant;
use crate::image::color::ColorImage;
use crate::image::ycbcr::Subsampling;
use crate::image::GrayImage;

// -- frame kinds -----------------------------------------------------------

pub const REQ_COMPRESS_GRAY: u8 = 1;
pub const REQ_COMPRESS_COLOR: u8 = 2;
pub const REQ_DECODE: u8 = 3;
pub const REQ_HISTEQ: u8 = 4;
pub const REQ_PING: u8 = 5;
pub const REQ_STATS: u8 = 6;
pub const REQ_DECODE_SALVAGE: u8 = 7;

// v2 (multiplexed) wrapper kinds: the payload carries a client-assigned
// u64 request id followed by a whole v1 message (kind byte + payload).
// Negotiation is per-frame via the kind byte, so v1 and v2 traffic can
// share one connection and a pure-v1 client never sees a v2 byte.

/// A v2 request: `u64 request_id LE | u8 inner kind | inner payload`.
pub const REQ_V2: u8 = 0x20;
/// A v2 response echoing the request id, same layout as [`REQ_V2`].
pub const RESP_V2: u8 = 0x90;
/// Structured per-connection admission refusal for a v2 request:
/// `u64 request_id LE | u32 max_inflight LE`. The request was not
/// admitted; the connection (and every other in-flight request on it)
/// stays healthy.
pub const RESP_V2_BUSY: u8 = 0x91;

/// Bytes of the v2 wrapper prefix (request id + inner kind).
pub const V2_PREFIX_LEN: usize = 9;

pub const RESP_COMPRESSED: u8 = 0x81;
pub const RESP_IMAGE: u8 = 0x82;
pub const RESP_PONG: u8 = 0x83;
pub const RESP_STATS: u8 = 0x84;
pub const RESP_DEGRADED: u8 = 0x85;
pub const RESP_SALVAGED: u8 = 0x86;
pub const RESP_ERROR: u8 = 0xE0;
pub const RESP_OVERLOADED: u8 = 0xE1;

// -- error codes -----------------------------------------------------------

/// The request frame itself did not parse.
pub const ERR_BAD_FRAME: u16 = 1;
/// Unknown request kind byte.
pub const ERR_UNSUPPORTED: u16 = 2;
/// A v2 request reused a request id that is still in flight on the
/// same connection. The original request is unaffected.
pub const ERR_DUPLICATE_ID: u16 = 3;
pub const ERR_DECODE_TRUNCATED: u16 = 10;
pub const ERR_DECODE_BAD_MAGIC: u16 = 11;
pub const ERR_DECODE_BAD_HEADER: u16 = 12;
pub const ERR_DECODE_TOO_LARGE: u16 = 13;
pub const ERR_DECODE_CORRUPT: u16 = 14;
/// The job ran and failed for a non-decode reason.
pub const ERR_JOB_FAILED: u16 = 20;
/// The job did not complete within the server's job timeout.
pub const ERR_JOB_TIMEOUT: u16 = 21;
/// The job panicked inside a worker. The pool already recovered (the
/// supervisor respawned the worker loop), so the request may simply be
/// retried — but clients should treat it as non-retryable by default
/// since the same input may deterministically re-panic.
pub const ERR_WORKER_PANIC: u16 = 22;

/// Map a classified decode failure to its wire code.
pub fn decode_error_code(kind: DecodeErrorKind) -> u16 {
    match kind {
        DecodeErrorKind::Truncated => ERR_DECODE_TRUNCATED,
        DecodeErrorKind::BadMagic => ERR_DECODE_BAD_MAGIC,
        DecodeErrorKind::BadHeader => ERR_DECODE_BAD_HEADER,
        DecodeErrorKind::TooLarge => ERR_DECODE_TOO_LARGE,
        DecodeErrorKind::Corrupt => ERR_DECODE_CORRUPT,
    }
}

// -- enum tags -------------------------------------------------------------

pub fn lane_tag(lane: Lane) -> u8 {
    match lane {
        Lane::Cpu => 0,
        Lane::CpuParallel => 1,
        Lane::Gpu => 2,
        Lane::Auto => 3,
    }
}

pub fn tag_lane(t: u8) -> Result<Lane> {
    Ok(match t {
        0 => Lane::Cpu,
        1 => Lane::CpuParallel,
        2 => Lane::Gpu,
        3 => Lane::Auto,
        _ => bail!("unknown lane tag {t}"),
    })
}

// -- messages --------------------------------------------------------------

/// A request frame, decoded.
#[derive(Debug, Clone, PartialEq)]
pub enum RequestMsg {
    CompressGray {
        image: GrayImage,
        variant: Variant,
        lane: Lane,
        want_psnr: bool,
    },
    CompressColor {
        image: ColorImage,
        variant: Variant,
        lane: Lane,
        subsampling: Subsampling,
        want_psnr: bool,
    },
    /// Decode an (untrusted) CDC1/CDC2/CDC3 container back to pixels.
    Decode { container: Vec<u8>, lane: Lane },
    /// Like `Decode`, but damaged CDC2 segments are concealed instead of
    /// failing the request; the reply is a `Salvaged` frame carrying an
    /// honest damage report.
    DecodeSalvage { container: Vec<u8>, lane: Lane },
    Histeq { image: GrayImage, lane: Lane },
    Ping,
    Stats,
}

/// Pixels coming back from a decode or histeq job.
#[derive(Debug, Clone, PartialEq)]
pub enum ImagePayload {
    Gray(GrayImage),
    Color(ColorImage),
}

/// A response frame, decoded.
#[derive(Debug, Clone, PartialEq)]
pub enum ResponseMsg {
    Compressed {
        lane: Lane,
        psnr_db: Option<f64>,
        container: Vec<u8>,
    },
    Image { lane: Lane, image: ImagePayload },
    /// A salvage-decode result: pixels plus the damage report. All-zero
    /// damage fields mean the container was intact and the pixels are
    /// bit-identical to a strict decode.
    Salvaged {
        lane: Lane,
        segments_total: u32,
        segments_damaged: u32,
        segments_concealed: u32,
        bytes_skipped: u64,
        image: ImagePayload,
    },
    Pong,
    StatsJson(String),
    /// A reduced-quality compress result from the load-shedding path
    /// (`serve --degrade`): same payload layout as `Compressed`, but a
    /// distinct kind so clients can tell a shed reply from a
    /// full-quality one.
    Degraded {
        lane: Lane,
        psnr_db: Option<f64>,
        container: Vec<u8>,
    },
    Error { code: u16, message: String },
    /// Structured backpressure: the admission gate or the request queue
    /// is full. Retry later; the connection stays usable.
    Overloaded,
}

// -- byte cursor -----------------------------------------------------------

struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.i + n <= self.b.len(),
            "payload truncated: need {n} bytes at offset {}, have {}",
            self.i,
            self.b.len() - self.i
        );
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn rest(self) -> &'a [u8] {
        &self.b[self.i..]
    }
}

/// Validate wire dimensions: nonzero and under the codec pixel cap
/// (which bounds the later `w * h * channels` allocation).
fn checked_dims(w: u32, h: u32, channels: usize) -> Result<(usize, usize)> {
    ensure!(w > 0 && h > 0, "image dimensions {w}x{h} must be nonzero");
    let px = (w as u64).saturating_mul(h as u64);
    ensure!(
        px <= codec::MAX_PIXELS,
        "image {w}x{h} exceeds the {}-pixel cap",
        codec::MAX_PIXELS
    );
    let _ = channels;
    Ok((w as usize, h as usize))
}

impl RequestMsg {
    /// Encode to `(frame kind, payload)`.
    pub fn encode(&self) -> (u8, Vec<u8>) {
        match self {
            RequestMsg::CompressGray {
                image,
                variant,
                lane,
                want_psnr,
            } => {
                let mut p = Vec::with_capacity(11 + image.data.len());
                p.push(codec::variant_tag(*variant));
                p.push(lane_tag(*lane));
                p.push(u8::from(*want_psnr));
                p.extend_from_slice(&(image.width as u32).to_le_bytes());
                p.extend_from_slice(&(image.height as u32).to_le_bytes());
                p.extend_from_slice(&image.data);
                (REQ_COMPRESS_GRAY, p)
            }
            RequestMsg::CompressColor {
                image,
                variant,
                lane,
                subsampling,
                want_psnr,
            } => {
                let mut p = Vec::with_capacity(12 + image.data.len());
                p.push(codec::variant_tag(*variant));
                p.push(lane_tag(*lane));
                p.push(u8::from(*want_psnr));
                p.push(codec::color::subsampling_tag(*subsampling));
                p.extend_from_slice(&(image.width as u32).to_le_bytes());
                p.extend_from_slice(&(image.height as u32).to_le_bytes());
                p.extend_from_slice(&image.data);
                (REQ_COMPRESS_COLOR, p)
            }
            RequestMsg::Decode { container, lane } => {
                let mut p = Vec::with_capacity(1 + container.len());
                p.push(lane_tag(*lane));
                p.extend_from_slice(container);
                (REQ_DECODE, p)
            }
            RequestMsg::DecodeSalvage { container, lane } => {
                let mut p = Vec::with_capacity(1 + container.len());
                p.push(lane_tag(*lane));
                p.extend_from_slice(container);
                (REQ_DECODE_SALVAGE, p)
            }
            RequestMsg::Histeq { image, lane } => {
                let mut p = Vec::with_capacity(9 + image.data.len());
                p.push(lane_tag(*lane));
                p.extend_from_slice(&(image.width as u32).to_le_bytes());
                p.extend_from_slice(&(image.height as u32).to_le_bytes());
                p.extend_from_slice(&image.data);
                (REQ_HISTEQ, p)
            }
            RequestMsg::Ping => (REQ_PING, Vec::new()),
            RequestMsg::Stats => (REQ_STATS, Vec::new()),
        }
    }

    /// Decode a request frame. Every length/dimension claim is checked
    /// against the bytes actually present.
    pub fn decode(kind: u8, payload: &[u8]) -> Result<RequestMsg> {
        let mut c = Cur::new(payload);
        match kind {
            REQ_COMPRESS_GRAY => {
                let variant = codec::tag_variant(c.u8()?)?;
                let lane = tag_lane(c.u8()?)?;
                let want_psnr = c.u8()? != 0;
                let (w, h) =
                    checked_dims(c.u32()?, c.u32()?, 1)?;
                let px = c.rest();
                ensure!(
                    px.len() == w * h,
                    "gray payload {} bytes != {w}x{h}",
                    px.len()
                );
                Ok(RequestMsg::CompressGray {
                    image: GrayImage::from_vec(w, h, px.to_vec())?,
                    variant,
                    lane,
                    want_psnr,
                })
            }
            REQ_COMPRESS_COLOR => {
                let variant = codec::tag_variant(c.u8()?)?;
                let lane = tag_lane(c.u8()?)?;
                let want_psnr = c.u8()? != 0;
                let subsampling = codec::color::tag_subsampling(c.u8()?)?;
                let (w, h) =
                    checked_dims(c.u32()?, c.u32()?, 3)?;
                let px = c.rest();
                ensure!(
                    px.len() == w * h * 3,
                    "rgb payload {} bytes != {w}x{h}x3",
                    px.len()
                );
                Ok(RequestMsg::CompressColor {
                    image: ColorImage::from_vec(w, h, px.to_vec())?,
                    variant,
                    lane,
                    subsampling,
                    want_psnr,
                })
            }
            REQ_DECODE => {
                let lane = tag_lane(c.u8()?)?;
                // no container validation here: the codec's hardened
                // header reader is the single point of truth, and its
                // structured error comes back as an error frame
                Ok(RequestMsg::Decode {
                    container: c.rest().to_vec(),
                    lane,
                })
            }
            REQ_DECODE_SALVAGE => {
                let lane = tag_lane(c.u8()?)?;
                Ok(RequestMsg::DecodeSalvage {
                    container: c.rest().to_vec(),
                    lane,
                })
            }
            REQ_HISTEQ => {
                let lane = tag_lane(c.u8()?)?;
                let (w, h) =
                    checked_dims(c.u32()?, c.u32()?, 1)?;
                let px = c.rest();
                ensure!(
                    px.len() == w * h,
                    "gray payload {} bytes != {w}x{h}",
                    px.len()
                );
                Ok(RequestMsg::Histeq {
                    image: GrayImage::from_vec(w, h, px.to_vec())?,
                    lane,
                })
            }
            REQ_PING => Ok(RequestMsg::Ping),
            REQ_STATS => Ok(RequestMsg::Stats),
            other => bail!("unsupported request kind {other:#04x}"),
        }
    }
}

impl ResponseMsg {
    /// Encode to `(frame kind, payload)`.
    pub fn encode(&self) -> (u8, Vec<u8>) {
        match self {
            ResponseMsg::Compressed {
                lane,
                psnr_db,
                container,
            } => {
                let mut p = Vec::with_capacity(10 + container.len());
                p.push(lane_tag(*lane));
                p.push(u8::from(psnr_db.is_some()));
                p.extend_from_slice(
                    &psnr_db.unwrap_or(0.0).to_le_bytes(),
                );
                p.extend_from_slice(container);
                (RESP_COMPRESSED, p)
            }
            ResponseMsg::Image { lane, image } => {
                let (color, w, h, data): (u8, usize, usize, &[u8]) =
                    match image {
                        ImagePayload::Gray(g) => {
                            (0, g.width, g.height, &g.data)
                        }
                        ImagePayload::Color(c) => {
                            (1, c.width, c.height, &c.data)
                        }
                    };
                let mut p = Vec::with_capacity(10 + data.len());
                p.push(lane_tag(*lane));
                p.push(color);
                p.extend_from_slice(&(w as u32).to_le_bytes());
                p.extend_from_slice(&(h as u32).to_le_bytes());
                p.extend_from_slice(data);
                (RESP_IMAGE, p)
            }
            ResponseMsg::Salvaged {
                lane,
                segments_total,
                segments_damaged,
                segments_concealed,
                bytes_skipped,
                image,
            } => {
                let (color, w, h, data): (u8, usize, usize, &[u8]) =
                    match image {
                        ImagePayload::Gray(g) => {
                            (0, g.width, g.height, &g.data)
                        }
                        ImagePayload::Color(c) => {
                            (1, c.width, c.height, &c.data)
                        }
                    };
                let mut p = Vec::with_capacity(30 + data.len());
                p.push(lane_tag(*lane));
                p.extend_from_slice(&segments_total.to_le_bytes());
                p.extend_from_slice(&segments_damaged.to_le_bytes());
                p.extend_from_slice(&segments_concealed.to_le_bytes());
                p.extend_from_slice(&bytes_skipped.to_le_bytes());
                p.push(color);
                p.extend_from_slice(&(w as u32).to_le_bytes());
                p.extend_from_slice(&(h as u32).to_le_bytes());
                p.extend_from_slice(data);
                (RESP_SALVAGED, p)
            }
            ResponseMsg::Pong => (RESP_PONG, Vec::new()),
            ResponseMsg::StatsJson(s) => {
                (RESP_STATS, s.as_bytes().to_vec())
            }
            ResponseMsg::Degraded {
                lane,
                psnr_db,
                container,
            } => {
                let mut p = Vec::with_capacity(10 + container.len());
                p.push(lane_tag(*lane));
                p.push(u8::from(psnr_db.is_some()));
                p.extend_from_slice(
                    &psnr_db.unwrap_or(0.0).to_le_bytes(),
                );
                p.extend_from_slice(container);
                (RESP_DEGRADED, p)
            }
            ResponseMsg::Error { code, message } => {
                let mut p = Vec::with_capacity(2 + message.len());
                p.extend_from_slice(&code.to_le_bytes());
                p.extend_from_slice(message.as_bytes());
                (RESP_ERROR, p)
            }
            ResponseMsg::Overloaded => (RESP_OVERLOADED, Vec::new()),
        }
    }

    /// Decode a response frame.
    pub fn decode(kind: u8, payload: &[u8]) -> Result<ResponseMsg> {
        let mut c = Cur::new(payload);
        match kind {
            RESP_COMPRESSED => {
                let lane = tag_lane(c.u8()?)?;
                let has_psnr = c.u8()? != 0;
                let psnr = c.f64()?;
                Ok(ResponseMsg::Compressed {
                    lane,
                    psnr_db: has_psnr.then_some(psnr),
                    container: c.rest().to_vec(),
                })
            }
            RESP_IMAGE => {
                let lane = tag_lane(c.u8()?)?;
                let color = c.u8()?;
                ensure!(color <= 1, "bad color flag {color}");
                let (w, h) = checked_dims(
                    c.u32()?,
                    c.u32()?,
                    if color == 1 { 3 } else { 1 },
                )?;
                let px = c.rest();
                let image = if color == 1 {
                    ensure!(
                        px.len() == w * h * 3,
                        "rgb payload {} bytes != {w}x{h}x3",
                        px.len()
                    );
                    ImagePayload::Color(ColorImage::from_vec(
                        w,
                        h,
                        px.to_vec(),
                    )?)
                } else {
                    ensure!(
                        px.len() == w * h,
                        "gray payload {} bytes != {w}x{h}",
                        px.len()
                    );
                    ImagePayload::Gray(GrayImage::from_vec(
                        w,
                        h,
                        px.to_vec(),
                    )?)
                };
                Ok(ResponseMsg::Image { lane, image })
            }
            RESP_SALVAGED => {
                let lane = tag_lane(c.u8()?)?;
                let segments_total = c.u32()?;
                let segments_damaged = c.u32()?;
                let segments_concealed = c.u32()?;
                let bytes_skipped = c.u64()?;
                let color = c.u8()?;
                ensure!(color <= 1, "bad color flag {color}");
                let (w, h) = checked_dims(
                    c.u32()?,
                    c.u32()?,
                    if color == 1 { 3 } else { 1 },
                )?;
                let px = c.rest();
                let image = if color == 1 {
                    ensure!(
                        px.len() == w * h * 3,
                        "rgb payload {} bytes != {w}x{h}x3",
                        px.len()
                    );
                    ImagePayload::Color(ColorImage::from_vec(
                        w,
                        h,
                        px.to_vec(),
                    )?)
                } else {
                    ensure!(
                        px.len() == w * h,
                        "gray payload {} bytes != {w}x{h}",
                        px.len()
                    );
                    ImagePayload::Gray(GrayImage::from_vec(
                        w,
                        h,
                        px.to_vec(),
                    )?)
                };
                Ok(ResponseMsg::Salvaged {
                    lane,
                    segments_total,
                    segments_damaged,
                    segments_concealed,
                    bytes_skipped,
                    image,
                })
            }
            RESP_DEGRADED => {
                let lane = tag_lane(c.u8()?)?;
                let has_psnr = c.u8()? != 0;
                let psnr = c.f64()?;
                Ok(ResponseMsg::Degraded {
                    lane,
                    psnr_db: has_psnr.then_some(psnr),
                    container: c.rest().to_vec(),
                })
            }
            RESP_PONG => Ok(ResponseMsg::Pong),
            RESP_STATS => Ok(ResponseMsg::StatsJson(
                String::from_utf8(payload.to_vec())
                    .map_err(|_| anyhow::anyhow!("stats not UTF-8"))?,
            )),
            RESP_ERROR => {
                let code = c.u16()?;
                let message =
                    String::from_utf8_lossy(c.rest()).into_owned();
                Ok(ResponseMsg::Error { code, message })
            }
            RESP_OVERLOADED => Ok(ResponseMsg::Overloaded),
            other => bail!("unsupported response kind {other:#04x}"),
        }
    }
}

// -- v2 (multiplexed) wrappers ---------------------------------------------

/// Encode a v2 request frame: the inner v1 encoding prefixed with the
/// client-assigned request id and the inner kind byte.
pub fn encode_v2_request(
    request_id: u64,
    msg: &RequestMsg,
) -> (u8, Vec<u8>) {
    let (inner_kind, inner) = msg.encode();
    let mut p = Vec::with_capacity(V2_PREFIX_LEN + inner.len());
    p.extend_from_slice(&request_id.to_le_bytes());
    p.push(inner_kind);
    p.extend_from_slice(&inner);
    (REQ_V2, p)
}

/// Split a v2 payload into `(request_id, inner kind, inner payload)`
/// without decoding the inner message — the server uses this to learn
/// the id to echo even when the inner decode later fails.
pub fn v2_prefix(payload: &[u8]) -> Result<(u64, u8, &[u8])> {
    let mut c = Cur::new(payload);
    let request_id = c.u64()?;
    let inner_kind = c.u8()?;
    Ok((request_id, inner_kind, c.rest()))
}

/// Decode a v2 request frame to `(request_id, inner message)`.
pub fn decode_v2_request(payload: &[u8]) -> Result<(u64, RequestMsg)> {
    let (request_id, inner_kind, inner) = v2_prefix(payload)?;
    Ok((request_id, RequestMsg::decode(inner_kind, inner)?))
}

/// Encode a v2 response frame echoing `request_id`.
pub fn encode_v2_response(
    request_id: u64,
    msg: &ResponseMsg,
) -> (u8, Vec<u8>) {
    let (inner_kind, inner) = msg.encode();
    let mut p = Vec::with_capacity(V2_PREFIX_LEN + inner.len());
    p.extend_from_slice(&request_id.to_le_bytes());
    p.push(inner_kind);
    p.extend_from_slice(&inner);
    (RESP_V2, p)
}

/// Decode a v2 response frame to `(request_id, inner message)`.
pub fn decode_v2_response(payload: &[u8]) -> Result<(u64, ResponseMsg)> {
    let (request_id, inner_kind, inner) = v2_prefix(payload)?;
    Ok((request_id, ResponseMsg::decode(inner_kind, inner)?))
}

/// Encode a [`RESP_V2_BUSY`] frame: the refused request id plus the
/// connection's `max_inflight` cap so the client can right-size its
/// window.
pub fn encode_v2_busy(request_id: u64, max_inflight: u32) -> (u8, Vec<u8>) {
    let mut p = Vec::with_capacity(12);
    p.extend_from_slice(&request_id.to_le_bytes());
    p.extend_from_slice(&max_inflight.to_le_bytes());
    (RESP_V2_BUSY, p)
}

/// Decode a [`RESP_V2_BUSY`] payload to `(request_id, max_inflight)`.
pub fn decode_v2_busy(payload: &[u8]) -> Result<(u64, u32)> {
    let mut c = Cur::new(payload);
    let request_id = c.u64()?;
    let max_inflight = c.u32()?;
    ensure!(c.rest().is_empty(), "trailing bytes after Busy payload");
    Ok((request_id, max_inflight))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synthetic;

    fn roundtrip_req(msg: RequestMsg) {
        let (k, p) = msg.encode();
        let back = RequestMsg::decode(k, &p).unwrap();
        assert_eq!(back, msg);
    }

    fn roundtrip_resp(msg: ResponseMsg) {
        let (k, p) = msg.encode();
        let back = ResponseMsg::decode(k, &p).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn request_roundtrips() {
        let gray = synthetic::lena_like(24, 16, 1);
        let rgb = synthetic::lena_like_rgb(24, 16, 2);
        roundtrip_req(RequestMsg::CompressGray {
            image: gray.clone(),
            variant: Variant::Cordic,
            lane: Lane::Auto,
            want_psnr: true,
        });
        roundtrip_req(RequestMsg::CompressColor {
            image: rgb,
            variant: Variant::Dct,
            lane: Lane::CpuParallel,
            subsampling: Subsampling::S422,
            want_psnr: false,
        });
        roundtrip_req(RequestMsg::Decode {
            container: vec![1, 2, 3, 4, 5],
            lane: Lane::Cpu,
        });
        roundtrip_req(RequestMsg::DecodeSalvage {
            container: vec![6, 7, 8],
            lane: Lane::Auto,
        });
        roundtrip_req(RequestMsg::Histeq {
            image: gray,
            lane: Lane::Gpu,
        });
        roundtrip_req(RequestMsg::Ping);
        roundtrip_req(RequestMsg::Stats);
    }

    #[test]
    fn response_roundtrips() {
        roundtrip_resp(ResponseMsg::Compressed {
            lane: Lane::Cpu,
            psnr_db: Some(31.25),
            container: vec![9; 40],
        });
        roundtrip_resp(ResponseMsg::Compressed {
            lane: Lane::Gpu,
            psnr_db: None,
            container: vec![],
        });
        roundtrip_resp(ResponseMsg::Image {
            lane: Lane::CpuParallel,
            image: ImagePayload::Gray(synthetic::lena_like(8, 8, 3)),
        });
        roundtrip_resp(ResponseMsg::Image {
            lane: Lane::Cpu,
            image: ImagePayload::Color(synthetic::lena_like_rgb(
                8, 8, 4,
            )),
        });
        roundtrip_resp(ResponseMsg::Salvaged {
            lane: Lane::Cpu,
            segments_total: 12,
            segments_damaged: 2,
            segments_concealed: 2,
            bytes_skipped: 310,
            image: ImagePayload::Gray(synthetic::lena_like(8, 8, 5)),
        });
        roundtrip_resp(ResponseMsg::Salvaged {
            lane: Lane::CpuParallel,
            segments_total: 3,
            segments_damaged: 0,
            segments_concealed: 0,
            bytes_skipped: 0,
            image: ImagePayload::Color(synthetic::lena_like_rgb(
                8, 8, 6,
            )),
        });
        roundtrip_resp(ResponseMsg::Degraded {
            lane: Lane::Cpu,
            psnr_db: Some(27.5),
            container: vec![3; 17],
        });
        roundtrip_resp(ResponseMsg::Degraded {
            lane: Lane::Cpu,
            psnr_db: None,
            container: vec![],
        });
        roundtrip_resp(ResponseMsg::Pong);
        roundtrip_resp(ResponseMsg::StatsJson("{\"a\":1}".into()));
        roundtrip_resp(ResponseMsg::Error {
            code: ERR_DECODE_CORRUPT,
            message: "entropy stream died".into(),
        });
        roundtrip_resp(ResponseMsg::Overloaded);
    }

    #[test]
    fn truncated_payloads_error() {
        let gray = synthetic::lena_like(16, 16, 5);
        let (k, p) = RequestMsg::CompressGray {
            image: gray,
            variant: Variant::Dct,
            lane: Lane::Cpu,
            want_psnr: true,
        }
        .encode();
        // every strict prefix must fail to parse, never panic
        for cut in 0..p.len() {
            assert!(
                RequestMsg::decode(k, &p[..cut]).is_err(),
                "prefix {cut}/{} parsed",
                p.len()
            );
        }
    }

    #[test]
    fn hostile_dims_rejected_without_allocation() {
        // claims a 65535x65535 gray image with a 1-byte body; the parser
        // must reject on the pixel cap / length check, not allocate 4 GiB
        let mut p = vec![0, 0, 1];
        p.extend_from_slice(&0xFFFF_FFFFu32.to_le_bytes());
        p.extend_from_slice(&0xFFFF_FFFFu32.to_le_bytes());
        p.push(7);
        assert!(RequestMsg::decode(REQ_COMPRESS_GRAY, &p).is_err());
        // zero dims too
        let mut p = vec![0, 0, 1];
        p.extend_from_slice(&0u32.to_le_bytes());
        p.extend_from_slice(&0u32.to_le_bytes());
        assert!(RequestMsg::decode(REQ_COMPRESS_GRAY, &p).is_err());
    }

    #[test]
    fn bad_tags_rejected() {
        // variant 250
        let p = vec![250, 0, 1, 8, 0, 0, 0, 8, 0, 0, 0];
        assert!(RequestMsg::decode(REQ_COMPRESS_GRAY, &p).is_err());
        // lane 9
        let p = vec![0, 9, 1, 8, 0, 0, 0, 8, 0, 0, 0];
        assert!(RequestMsg::decode(REQ_COMPRESS_GRAY, &p).is_err());
        // unknown request kind
        assert!(RequestMsg::decode(0x77, &[]).is_err());
        // unknown response kind
        assert!(ResponseMsg::decode(0x13, &[]).is_err());
        // a Degraded frame shorter than its 10-byte prelude
        assert!(ResponseMsg::decode(RESP_DEGRADED, &[0, 1]).is_err());
        // a Salvaged frame shorter than its 30-byte prelude
        assert!(ResponseMsg::decode(RESP_SALVAGED, &[0; 12]).is_err());
    }

    #[test]
    fn length_mismatch_rejected() {
        // valid header claiming 8x8 but carrying 63 bytes
        let mut p = vec![0, 0, 1];
        p.extend_from_slice(&8u32.to_le_bytes());
        p.extend_from_slice(&8u32.to_le_bytes());
        p.extend_from_slice(&[0u8; 63]);
        assert!(RequestMsg::decode(REQ_COMPRESS_GRAY, &p).is_err());
    }

    #[test]
    fn v2_wrappers_roundtrip() {
        let req = RequestMsg::CompressGray {
            image: synthetic::lena_like(16, 12, 1),
            variant: Variant::Cordic,
            lane: Lane::Auto,
            want_psnr: false,
        };
        for id in [0u64, 1, 7, u64::MAX] {
            let (k, p) = encode_v2_request(id, &req);
            assert_eq!(k, REQ_V2);
            let (back_id, back) = decode_v2_request(&p).unwrap();
            assert_eq!((back_id, back), (id, req.clone()));
        }
        let resp = ResponseMsg::Compressed {
            lane: Lane::Cpu,
            psnr_db: None,
            container: vec![5; 20],
        };
        let (k, p) = encode_v2_response(u64::MAX, &resp);
        assert_eq!(k, RESP_V2);
        let (id, back) = decode_v2_response(&p).unwrap();
        assert_eq!((id, back), (u64::MAX, resp));
        let (k, p) = encode_v2_busy(42, 8);
        assert_eq!(k, RESP_V2_BUSY);
        assert_eq!(decode_v2_busy(&p).unwrap(), (42, 8));
        // a short prefix must fail cleanly, never panic
        for cut in 0..V2_PREFIX_LEN {
            assert!(v2_prefix(&vec![0u8; cut]).is_err());
        }
        assert!(decode_v2_busy(&[1, 2, 3]).is_err());
    }

    #[test]
    fn decode_error_codes_cover_all_kinds() {
        let mut seen = std::collections::BTreeSet::new();
        for k in DecodeErrorKind::ALL {
            assert!(
                seen.insert(decode_error_code(k)),
                "duplicate wire code for {k:?}"
            );
        }
    }
}
