//! The TCP front-end over the [`crate::coordinator`]: a length-prefixed
//! binary protocol, connection handling on the crate's own thread pool,
//! admission control with structured overload replies, per-connection
//! read/write timeouts, and graceful shutdown that drains in-flight
//! jobs.
//!
//! ```text
//!  client ──frame──► TcpServer accept thread
//!                       │  admission gate (max_connections)
//!                       ▼
//!                    ThreadPool ── conn frame loop
//!                       │  RequestMsg::decode  (validates dims/lengths)
//!                       ▼
//!                    Service queue (Backpressure::Reject)
//!                       │  full ──► Overloaded frame
//!                       ▼
//!                    worker lanes ──► JobOutput ──► ResponseMsg frame
//! ```
//!
//! Every failure mode a client can trigger — garbage bytes, truncated
//! frames, hostile container headers, oversized length prefixes, queue
//! overload — answers with a structured frame (or a clean close when the
//! byte stream itself desynchronizes); the hardened codec header
//! validation ([`crate::codec::DecodeErrorKind`]) maps one-to-one onto
//! wire error codes. A panicked worker job answers a structured
//! `ERR_WORKER_PANIC` frame while the pool respawns the worker, and
//! with `--degrade` a queue-rejected compress request is served a
//! reduced-quality `Degraded` reply instead of a bare refusal.
//!
//! The client side matches the failure model: [`Client`] is the plain
//! one-connection client, [`RetryClient`] adds reconnects, exponential
//! backoff with deterministic jitter, and a [`CircuitBreaker`] —
//! retrying only transient failures ([`RequestError::retryable`]).
//! The [`loadgen`] module is the measurement half: concurrent
//! closed-loop clients with exact latency percentiles driving the
//! `ablation_serve_load` bench, and — with [`LoadSpec::faults`] — the
//! chaos-soak harness behind `ablation_chaos`. Seeded fault injection
//! itself (slow/short socket I/O, disconnects, bit-flips) lives in
//! [`crate::faults`] and is wired in through
//! [`server::ServeConfig::faults`].

pub mod client;
mod conn;
pub mod framing;
pub mod loadgen;
pub mod protocol;
pub mod server;

pub use client::{
    CircuitBreaker, Client, Compressed, RequestError, RetryClient,
    RetryPolicy, SalvageSummary,
};
pub use loadgen::{run_load, ErrorCounts, LoadReport, LoadSpec};
pub use protocol::{ImagePayload, RequestMsg, ResponseMsg};
pub use server::{ServeConfig, TcpServer};
