//! The TCP front-end over the [`crate::coordinator`]: a length-prefixed
//! binary protocol, connection handling on the crate's own thread pool,
//! admission control with structured overload replies, per-connection
//! read/write timeouts, and graceful shutdown that drains in-flight
//! jobs.
//!
//! ```text
//!  client ──frame──► TcpServer accept thread
//!                       │  admission gate (max_connections)
//!                       ▼
//!                    ThreadPool ── conn frame loop
//!                       │  RequestMsg::decode  (validates dims/lengths)
//!                       ▼
//!                    Service queue (Backpressure::Reject)
//!                       │  full ──► Overloaded frame
//!                       ▼
//!                    worker lanes ──► JobOutput ──► ResponseMsg frame
//! ```
//!
//! Every failure mode a client can trigger — garbage bytes, truncated
//! frames, hostile container headers, oversized length prefixes, queue
//! overload — answers with a structured frame (or a clean close when the
//! byte stream itself desynchronizes); the hardened codec header
//! validation ([`crate::codec::DecodeErrorKind`]) maps one-to-one onto
//! wire error codes. A panicked worker job answers a structured
//! `ERR_WORKER_PANIC` frame while the pool respawns the worker, and
//! with `--degrade` a queue-rejected compress request is served a
//! reduced-quality `Degraded` reply instead of a bare refusal.
//!
//! Since protocol v2 the same socket can also pipeline: a v2 frame
//! wraps any v1 request with a client-assigned `request_id`, the server
//! fans admitted jobs out to the coordinator, and a per-connection
//! drainer writes responses back in *completion order*, each echoing
//! its id ([`client::MuxClient`] is the matching window-keeping
//! client). Admission past [`ServeConfig::max_inflight`] answers a
//! structured Busy frame; v1 clients keep working bit-for-bit because
//! negotiation is per frame via the kind byte. Two scaling layers ride
//! on top: a content-addressed response [`cache`] (sharded LRU over the
//! exact encoded container bytes, keyed on pixels digest + every encode
//! knob) and [`server::ShardGroup`] — `--shards N` shared-nothing
//! listeners on consecutive ports, spread over by
//! [`client::ShardedClient`].
//!
//! The client side matches the failure model: [`Client`] is the plain
//! one-connection client, [`RetryClient`] adds reconnects, exponential
//! backoff with deterministic jitter, and a [`CircuitBreaker`] —
//! retrying only transient failures ([`RequestError::retryable`]).
//! The [`loadgen`] module is the measurement half: concurrent
//! closed-loop or pipelined ([`LoadSpec::pipeline`]) clients with exact
//! latency percentiles driving the `ablation_serve_load` bench, and —
//! with [`LoadSpec::faults`] — the chaos-soak harness behind
//! `ablation_chaos`. Seeded fault injection itself (slow/short socket
//! I/O, disconnects, bit-flips) lives in [`crate::faults`] and is wired
//! in through [`server::ServeConfig::faults`].

pub mod cache;
pub mod client;
mod conn;
pub mod framing;
pub mod loadgen;
pub mod protocol;
pub mod server;

pub use cache::{CacheKey, CacheStats, ResponseCache};
pub use client::{
    CircuitBreaker, Client, Compressed, MuxClient, MuxEvent,
    RequestError, RetryClient, RetryPolicy, SalvageSummary,
    ShardedClient,
};
pub use loadgen::{run_load, ErrorCounts, ImageMix, LoadReport, LoadSpec};
pub use protocol::{ImagePayload, RequestMsg, ResponseMsg};
pub use server::{ServeConfig, ShardGroup, TcpServer};
