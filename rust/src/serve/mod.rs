//! The TCP front-end over the [`crate::coordinator`]: a length-prefixed
//! binary protocol, connection handling on the crate's own thread pool,
//! admission control with structured overload replies, per-connection
//! read/write timeouts, and graceful shutdown that drains in-flight
//! jobs.
//!
//! ```text
//!  client ──frame──► TcpServer accept thread
//!                       │  admission gate (max_connections)
//!                       ▼
//!                    ThreadPool ── conn frame loop
//!                       │  RequestMsg::decode  (validates dims/lengths)
//!                       ▼
//!                    Service queue (Backpressure::Reject)
//!                       │  full ──► Overloaded frame
//!                       ▼
//!                    worker lanes ──► JobOutput ──► ResponseMsg frame
//! ```
//!
//! Every failure mode a client can trigger — garbage bytes, truncated
//! frames, hostile container headers, oversized length prefixes, queue
//! overload — answers with a structured frame (or a clean close when the
//! byte stream itself desynchronizes); the hardened codec header
//! validation ([`crate::codec::DecodeErrorKind`]) maps one-to-one onto
//! wire error codes. The [`loadgen`] module is the measurement half:
//! concurrent closed-loop clients with exact latency percentiles,
//! driving the `ablation_serve_load` bench.

pub mod client;
mod conn;
pub mod framing;
pub mod loadgen;
pub mod protocol;
pub mod server;

pub use client::{Client, Compressed};
pub use loadgen::{run_load, LoadReport, LoadSpec};
pub use protocol::{ImagePayload, RequestMsg, ResponseMsg};
pub use server::{ServeConfig, TcpServer};
