//! A small blocking client for the serve protocol — used by the load
//! generator, the integration tests, and the `loadgen` CLI subcommand.

use std::io::{BufReader, BufWriter};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::Lane;
use crate::dct::Variant;
use crate::image::color::ColorImage;
use crate::image::ycbcr::Subsampling;
use crate::image::GrayImage;

use super::framing::{self, FrameEvent, MAX_FRAME_LEN_DEFAULT};
use super::protocol::{ImagePayload, RequestMsg, ResponseMsg};

/// A successful compression reply.
#[derive(Debug, Clone)]
pub struct Compressed {
    pub lane: Lane,
    pub psnr_db: Option<f64>,
    /// The CDC1/CDC3 container bytes.
    pub container: Vec<u8>,
}

/// Blocking protocol client over one TCP connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    max_frame_len: usize,
    /// Overall per-request response deadline (the socket read timeout is
    /// just a poll tick under it).
    response_deadline: Duration,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).context("connecting to server")?;
        stream.set_read_timeout(Some(Duration::from_millis(200)))?;
        stream.set_write_timeout(Some(Duration::from_secs(10)))?;
        let _ = stream.set_nodelay(true);
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            max_frame_len: MAX_FRAME_LEN_DEFAULT,
            response_deadline: Duration::from_secs(60),
        })
    }

    /// Override the per-request response deadline.
    pub fn with_deadline(mut self, d: Duration) -> Client {
        self.response_deadline = d;
        self
    }

    /// Raw access to the underlying stream (test hook for simulating
    /// abrupt client behavior).
    pub fn stream(&self) -> &TcpStream {
        self.reader.get_ref()
    }

    /// Send one request frame and wait for its response frame.
    pub fn request(&mut self, msg: &RequestMsg) -> Result<ResponseMsg> {
        let (kind, payload) = msg.encode();
        framing::write_frame(&mut self.writer, kind, &payload)?;
        let t0 = Instant::now();
        loop {
            match framing::read_frame(&mut self.reader, self.max_frame_len)?
            {
                FrameEvent::Frame { kind, payload } => {
                    return ResponseMsg::decode(kind, &payload)
                }
                FrameEvent::Eof => {
                    bail!("server closed the connection mid-request")
                }
                FrameEvent::Idle => {
                    if t0.elapsed() > self.response_deadline {
                        bail!(
                            "no response within {:?}",
                            self.response_deadline
                        );
                    }
                }
            }
        }
    }

    fn expect_ok(resp: ResponseMsg) -> Result<ResponseMsg> {
        match resp {
            ResponseMsg::Error { code, message } => {
                bail!("server error {code}: {message}")
            }
            ResponseMsg::Overloaded => bail!("server overloaded"),
            other => Ok(other),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        match Self::expect_ok(self.request(&RequestMsg::Ping)?)? {
            ResponseMsg::Pong => Ok(()),
            other => bail!("expected Pong, got {other:?}"),
        }
    }

    /// Server-side stats snapshot as a JSON string.
    pub fn stats_json(&mut self) -> Result<String> {
        match Self::expect_ok(self.request(&RequestMsg::Stats)?)? {
            ResponseMsg::StatsJson(s) => Ok(s),
            other => bail!("expected StatsJson, got {other:?}"),
        }
    }

    pub fn compress_gray(
        &mut self,
        image: &GrayImage,
        variant: Variant,
        lane: Lane,
        want_psnr: bool,
    ) -> Result<Compressed> {
        let msg = RequestMsg::CompressGray {
            image: image.clone(),
            variant,
            lane,
            want_psnr,
        };
        match Self::expect_ok(self.request(&msg)?)? {
            ResponseMsg::Compressed {
                lane,
                psnr_db,
                container,
            } => Ok(Compressed {
                lane,
                psnr_db,
                container,
            }),
            other => bail!("expected Compressed, got {other:?}"),
        }
    }

    pub fn compress_color(
        &mut self,
        image: &ColorImage,
        variant: Variant,
        lane: Lane,
        subsampling: Subsampling,
        want_psnr: bool,
    ) -> Result<Compressed> {
        let msg = RequestMsg::CompressColor {
            image: image.clone(),
            variant,
            lane,
            subsampling,
            want_psnr,
        };
        match Self::expect_ok(self.request(&msg)?)? {
            ResponseMsg::Compressed {
                lane,
                psnr_db,
                container,
            } => Ok(Compressed {
                lane,
                psnr_db,
                container,
            }),
            other => bail!("expected Compressed, got {other:?}"),
        }
    }

    /// Decode a container server-side; returns the reconstructed pixels.
    pub fn decode(
        &mut self,
        container: Vec<u8>,
        lane: Lane,
    ) -> Result<ImagePayload> {
        let msg = RequestMsg::Decode { container, lane };
        match Self::expect_ok(self.request(&msg)?)? {
            ResponseMsg::Image { image, .. } => Ok(image),
            other => bail!("expected Image, got {other:?}"),
        }
    }

    pub fn histeq(
        &mut self,
        image: &GrayImage,
        lane: Lane,
    ) -> Result<GrayImage> {
        let msg = RequestMsg::Histeq {
            image: image.clone(),
            lane,
        };
        match Self::expect_ok(self.request(&msg)?)? {
            ResponseMsg::Image {
                image: ImagePayload::Gray(g),
                ..
            } => Ok(g),
            other => bail!("expected gray Image, got {other:?}"),
        }
    }
}
