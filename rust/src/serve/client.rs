//! Blocking clients for the serve protocol — used by the load
//! generator, the integration tests, and the `loadgen` CLI subcommand.
//!
//! Four tiers:
//!
//! - [`Client`]: one TCP connection, one request frame in, one response
//!   frame out. Transport failures come back as a typed
//!   [`RequestError`] through [`Client::try_request`] (the `anyhow`
//!   surface of [`Client::request`] wraps the same value).
//! - [`RetryClient`]: reconnecting wrapper with exponential backoff +
//!   deterministic jitter, a per-attempt deadline, and a
//!   [`CircuitBreaker`]. It retries **only** transient failures —
//!   connect errors, Overloaded frames, response timeouts — and never
//!   a decode/server error, which would fail identically on every
//!   attempt.
//! - [`MuxClient`]: the pipelined (protocol v2) client — `send` assigns
//!   a request id and returns immediately, `recv` yields the next
//!   completed response (any order); the caller keeps the window.
//! - [`ShardedClient`]: round-robin over a [`ShardGroup`]'s addresses
//!   with one lazily-connected [`Client`] per shard.
//!
//! [`ShardGroup`]: super::server::ShardGroup

use std::fmt;
use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::Lane;
use crate::dct::Variant;
use crate::image::color::ColorImage;
use crate::image::ycbcr::Subsampling;
use crate::image::GrayImage;
use crate::util::prng::Rng;

use super::framing::{self, FrameEvent, MAX_FRAME_LEN_DEFAULT};
use super::protocol::{
    self, ImagePayload, RequestMsg, ResponseMsg, ERR_DECODE_CORRUPT,
    RESP_V2, RESP_V2_BUSY,
};

/// A request failure, classified for retry decisions.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RequestError {
    /// Could not connect, or the connection died mid-request; the
    /// string carries the transport detail.
    Connect(String),
    /// The server answered an Overloaded frame (queue or admission
    /// backpressure) — the request never ran.
    Overloaded,
    /// No response within the per-request deadline.
    Timeout(String),
    /// The response frame failed to decode; the connection is suspect.
    Malformed(String),
    /// A structured server error frame, typed for callers that convert
    /// frames into errors. Deterministic — never retried.
    Server { code: u16, message: String },
    /// The circuit breaker is open; the request was not attempted.
    CircuitOpen,
}

impl RequestError {
    /// Transient failures worth another attempt. Everything else would
    /// fail the same way again.
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            RequestError::Connect(_)
                | RequestError::Overloaded
                | RequestError::Timeout(_)
        )
    }
}

impl fmt::Display for RequestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            // Connect/Timeout print their detail verbatim so the
            // long-standing message contracts ("server closed the
            // connection mid-request", "no response within ...") hold
            RequestError::Connect(s) => f.write_str(s),
            RequestError::Timeout(s) => f.write_str(s),
            RequestError::Malformed(s) => {
                write!(f, "malformed response frame: {s}")
            }
            RequestError::Overloaded => f.write_str("server overloaded"),
            RequestError::Server { code, message } => {
                write!(f, "server error {code}: {message}")
            }
            RequestError::CircuitOpen => {
                f.write_str("circuit breaker open: request not attempted")
            }
        }
    }
}

impl std::error::Error for RequestError {}

/// A successful compression reply.
#[derive(Debug, Clone)]
pub struct Compressed {
    pub lane: Lane,
    pub psnr_db: Option<f64>,
    /// The CDC1/CDC3 container bytes.
    pub container: Vec<u8>,
    /// True when the server shed load and answered a reduced-quality
    /// `Degraded` frame instead of a normal result.
    pub degraded: bool,
}

/// The damage report carried by a `Salvaged` frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SalvageSummary {
    pub segments_total: u32,
    pub segments_damaged: u32,
    pub segments_concealed: u32,
    pub bytes_skipped: u64,
}

impl SalvageSummary {
    /// No damage: the pixels are bit-identical to a strict decode.
    pub fn is_clean(&self) -> bool {
        self.segments_damaged == 0 && self.bytes_skipped == 0
    }
}

/// Blocking protocol client over one TCP connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    max_frame_len: usize,
    /// Overall per-request response deadline (the socket read timeout is
    /// just a poll tick under it).
    response_deadline: Duration,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).context("connecting to server")?;
        Self::from_stream(stream)
    }

    /// Like [`Client::connect`] but bounded: a dead or blackholed
    /// address fails within `timeout` instead of the OS default.
    pub fn connect_timeout(
        addr: &SocketAddr,
        timeout: Duration,
    ) -> Result<Client> {
        let stream = TcpStream::connect_timeout(addr, timeout)
            .context("connecting to server")?;
        Self::from_stream(stream)
    }

    fn from_stream(stream: TcpStream) -> Result<Client> {
        stream.set_read_timeout(Some(Duration::from_millis(200)))?;
        stream.set_write_timeout(Some(Duration::from_secs(10)))?;
        let _ = stream.set_nodelay(true);
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            max_frame_len: MAX_FRAME_LEN_DEFAULT,
            response_deadline: Duration::from_secs(60),
        })
    }

    /// Override the per-request response deadline.
    pub fn with_deadline(mut self, d: Duration) -> Client {
        self.response_deadline = d;
        self
    }

    /// Raw access to the underlying stream (test hook for simulating
    /// abrupt client behavior).
    pub fn stream(&self) -> &TcpStream {
        self.reader.get_ref()
    }

    /// Send one request frame and wait for its response frame.
    pub fn request(&mut self, msg: &RequestMsg) -> Result<ResponseMsg> {
        self.try_request(msg).map_err(anyhow::Error::from)
    }

    /// [`Client::request`] with the failure classified for retry logic.
    pub fn try_request(
        &mut self,
        msg: &RequestMsg,
    ) -> Result<ResponseMsg, RequestError> {
        let (kind, payload) = msg.encode();
        framing::write_frame(&mut self.writer, kind, &payload)
            .map_err(|e| RequestError::Connect(format!("{e:#}")))?;
        let t0 = Instant::now();
        loop {
            match framing::read_frame(&mut self.reader, self.max_frame_len)
            {
                Ok(FrameEvent::Frame { kind, payload }) => {
                    return ResponseMsg::decode(kind, &payload).map_err(
                        |e| RequestError::Malformed(format!("{e:#}")),
                    )
                }
                Ok(FrameEvent::Eof) => {
                    return Err(RequestError::Connect(
                        "server closed the connection mid-request".into(),
                    ))
                }
                Ok(FrameEvent::Idle) => {
                    if t0.elapsed() > self.response_deadline {
                        return Err(RequestError::Timeout(format!(
                            "no response within {:?}",
                            self.response_deadline
                        )));
                    }
                }
                // a mid-frame stall or desync: the connection cannot be
                // reused, which is exactly what Connect signals
                Err(e) => {
                    return Err(RequestError::Connect(format!("{e:#}")))
                }
            }
        }
    }

    fn expect_ok(resp: ResponseMsg) -> Result<ResponseMsg> {
        match resp {
            ResponseMsg::Error { code, message } => {
                bail!("server error {code}: {message}")
            }
            ResponseMsg::Overloaded => bail!("server overloaded"),
            other => Ok(other),
        }
    }

    pub fn ping(&mut self) -> Result<()> {
        match Self::expect_ok(self.request(&RequestMsg::Ping)?)? {
            ResponseMsg::Pong => Ok(()),
            other => bail!("expected Pong, got {other:?}"),
        }
    }

    /// Server-side stats snapshot as a JSON string.
    pub fn stats_json(&mut self) -> Result<String> {
        match Self::expect_ok(self.request(&RequestMsg::Stats)?)? {
            ResponseMsg::StatsJson(s) => Ok(s),
            other => bail!("expected StatsJson, got {other:?}"),
        }
    }

    pub fn compress_gray(
        &mut self,
        image: &GrayImage,
        variant: Variant,
        lane: Lane,
        want_psnr: bool,
    ) -> Result<Compressed> {
        let msg = RequestMsg::CompressGray {
            image: image.clone(),
            variant,
            lane,
            want_psnr,
        };
        compressed_reply(Self::expect_ok(self.request(&msg)?)?)
    }

    pub fn compress_color(
        &mut self,
        image: &ColorImage,
        variant: Variant,
        lane: Lane,
        subsampling: Subsampling,
        want_psnr: bool,
    ) -> Result<Compressed> {
        let msg = RequestMsg::CompressColor {
            image: image.clone(),
            variant,
            lane,
            subsampling,
            want_psnr,
        };
        compressed_reply(Self::expect_ok(self.request(&msg)?)?)
    }

    /// Decode a container server-side; returns the reconstructed pixels.
    pub fn decode(
        &mut self,
        container: Vec<u8>,
        lane: Lane,
    ) -> Result<ImagePayload> {
        let msg = RequestMsg::Decode { container, lane };
        match Self::expect_ok(self.request(&msg)?)? {
            ResponseMsg::Image { image, .. } => Ok(image),
            other => bail!("expected Image, got {other:?}"),
        }
    }

    /// Salvage-decode a (possibly damaged) container server-side;
    /// returns the reconstructed pixels plus the damage report.
    pub fn decode_salvage(
        &mut self,
        container: Vec<u8>,
        lane: Lane,
    ) -> Result<(ImagePayload, SalvageSummary)> {
        let msg = RequestMsg::DecodeSalvage { container, lane };
        match Self::expect_ok(self.request(&msg)?)? {
            ResponseMsg::Salvaged {
                segments_total,
                segments_damaged,
                segments_concealed,
                bytes_skipped,
                image,
                ..
            } => Ok((
                image,
                SalvageSummary {
                    segments_total,
                    segments_damaged,
                    segments_concealed,
                    bytes_skipped,
                },
            )),
            other => bail!("expected Salvaged, got {other:?}"),
        }
    }

    pub fn histeq(
        &mut self,
        image: &GrayImage,
        lane: Lane,
    ) -> Result<GrayImage> {
        let msg = RequestMsg::Histeq {
            image: image.clone(),
            lane,
        };
        match Self::expect_ok(self.request(&msg)?)? {
            ResponseMsg::Image {
                image: ImagePayload::Gray(g),
                ..
            } => Ok(g),
            other => bail!("expected gray Image, got {other:?}"),
        }
    }
}

/// Accept either a normal `Compressed` frame or a load-shed `Degraded`
/// one — both carry a valid container.
fn compressed_reply(resp: ResponseMsg) -> Result<Compressed> {
    match resp {
        ResponseMsg::Compressed {
            lane,
            psnr_db,
            container,
        } => Ok(Compressed {
            lane,
            psnr_db,
            container,
            degraded: false,
        }),
        ResponseMsg::Degraded {
            lane,
            psnr_db,
            container,
        } => Ok(Compressed {
            lane,
            psnr_db,
            container,
            degraded: true,
        }),
        other => bail!("expected Compressed, got {other:?}"),
    }
}

/// Retry/backoff knobs for [`RetryClient`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts (first try + retries).
    pub max_attempts: u32,
    /// Backoff before retry `n` is `base_backoff * 2^n`, capped at
    /// `max_backoff`, then jittered down to at least half.
    pub base_backoff: Duration,
    pub max_backoff: Duration,
    /// Bound on each attempt's TCP connect.
    pub connect_timeout: Duration,
    /// Per-attempt response deadline (passed to the underlying
    /// [`Client::with_deadline`]).
    pub attempt_deadline: Duration,
    /// Seed for the deterministic jitter stream — same seed, same
    /// backoff schedule.
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(500),
            connect_timeout: Duration::from_secs(2),
            attempt_deadline: Duration::from_secs(10),
            jitter_seed: 1,
        }
    }
}

impl RetryPolicy {
    /// Backoff before the retry following attempt `attempt` (0-based):
    /// exponential, capped, jittered into `[cap/2, cap]` so synchronized
    /// clients spread out instead of stampeding in lockstep.
    pub fn backoff(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let cap = self
            .base_backoff
            .saturating_mul(1u32 << attempt.min(16))
            .min(self.max_backoff);
        let nanos = cap.as_nanos() as u64;
        if nanos == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(nanos / 2 + rng.below(nanos / 2 + 1))
    }

    /// Worst-case wall clock one [`RetryClient::request`] can consume:
    /// every attempt burns its connect timeout, its full deadline, and a
    /// maximal backoff. The chaos harness asserts no request exceeds it.
    pub fn total_budget(&self) -> Duration {
        let per = self.connect_timeout + self.attempt_deadline
            + self.max_backoff;
        per * self.max_attempts.max(1)
    }
}

/// Consecutive-failure circuit breaker.
///
/// Closed → (threshold consecutive failures) → Open for `cooldown` →
/// Half-open: the next request is allowed through as a probe; its
/// success closes the breaker, its failure re-opens it. Time is passed
/// in explicitly so the state machine is testable without sleeping.
#[derive(Debug)]
pub struct CircuitBreaker {
    threshold: u32,
    cooldown: Duration,
    consecutive: u32,
    open_until: Option<Instant>,
    half_open: bool,
}

impl CircuitBreaker {
    pub fn new(threshold: u32, cooldown: Duration) -> CircuitBreaker {
        CircuitBreaker {
            threshold: threshold.max(1),
            cooldown,
            consecutive: 0,
            open_until: None,
            half_open: false,
        }
    }

    /// May a request be attempted at `now`? Transitions Open →
    /// Half-open once the cooldown has elapsed.
    pub fn allow(&mut self, now: Instant) -> bool {
        if let Some(until) = self.open_until {
            if now < until {
                return false;
            }
            self.open_until = None;
            self.half_open = true;
        }
        true
    }

    /// Currently refusing requests (cooldown not yet elapsed)?
    pub fn is_open(&self, now: Instant) -> bool {
        matches!(self.open_until, Some(until) if now < until)
    }

    pub fn record_success(&mut self) {
        self.consecutive = 0;
        self.half_open = false;
    }

    pub fn record_failure(&mut self, now: Instant) {
        if self.half_open {
            // the probe failed: straight back to Open
            self.trip(now);
            return;
        }
        self.consecutive += 1;
        if self.consecutive >= self.threshold {
            self.trip(now);
        }
    }

    fn trip(&mut self, now: Instant) {
        self.open_until = Some(now + self.cooldown);
        self.half_open = false;
        self.consecutive = 0;
    }
}

impl Default for CircuitBreaker {
    fn default() -> Self {
        CircuitBreaker::new(5, Duration::from_millis(250))
    }
}

/// Reconnecting client with retries, backoff, and a circuit breaker.
///
/// Retries only [`RequestError::retryable`] failures (connect,
/// Overloaded, timeout); decode and server errors surface immediately.
/// `Degraded` and `Error` frames pass through as `Ok` responses — they
/// are answers, not transport failures.
pub struct RetryClient {
    addr: SocketAddr,
    policy: RetryPolicy,
    breaker: CircuitBreaker,
    rng: Rng,
    conn: Option<Client>,
    retries: u64,
    salvage_fallback: bool,
    salvage_fallbacks: u64,
    /// Wire time of the attempt that produced the last returned
    /// response — excludes connects, backoff sleeps, and failed
    /// attempts, unlike the caller's total elapsed time.
    last_service: Option<Duration>,
}

impl RetryClient {
    pub fn new(addr: SocketAddr, policy: RetryPolicy) -> RetryClient {
        let rng = Rng::new(policy.jitter_seed);
        RetryClient {
            addr,
            policy,
            breaker: CircuitBreaker::default(),
            rng,
            conn: None,
            retries: 0,
            salvage_fallback: false,
            salvage_fallbacks: 0,
            last_service: None,
        }
    }

    /// Replace the default breaker (5 failures, 250 ms cooldown).
    pub fn with_breaker(mut self, breaker: CircuitBreaker) -> RetryClient {
        self.breaker = breaker;
        self
    }

    /// Opt in to the salvage fallback: a `Decode` request answered with
    /// a corrupt-container error frame is re-sent once as
    /// `DecodeSalvage`, trading bit-exactness for availability. Off by
    /// default — strict callers see the error unchanged.
    pub fn with_salvage_fallback(mut self) -> RetryClient {
        self.salvage_fallback = true;
        self
    }

    /// Retries performed so far (attempts beyond each first try).
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Times the salvage fallback fired (corrupt strict decode re-sent
    /// as a salvage decode).
    pub fn salvage_fallbacks(&self) -> u64 {
        self.salvage_fallbacks
    }

    pub fn policy(&self) -> &RetryPolicy {
        &self.policy
    }

    /// Service time of the attempt behind the last successful
    /// [`RetryClient::request`]: one request frame out, its response
    /// frame in. Connect time, backoff sleeps, and earlier failed
    /// attempts are excluded — this is the honest latency sample for
    /// percentile reporting, where the total elapsed time (which the
    /// retry budget check uses) conflates server latency with the
    /// client's own recovery behavior. `None` until a request succeeds.
    pub fn last_service_time(&self) -> Option<Duration> {
        self.last_service
    }

    /// Send one request with retries. Connections are lazy: the first
    /// request (and the first after any transport failure) reconnects.
    /// With [`RetryClient::with_salvage_fallback`], a `Decode` answered
    /// by a corrupt-container error frame is re-sent once as a
    /// `DecodeSalvage`.
    pub fn request(
        &mut self,
        msg: &RequestMsg,
    ) -> Result<ResponseMsg, RequestError> {
        let resp = self.request_raw(msg)?;
        if self.salvage_fallback {
            if let (
                RequestMsg::Decode { container, lane },
                ResponseMsg::Error { code, .. },
            ) = (msg, &resp)
            {
                if *code == ERR_DECODE_CORRUPT {
                    self.salvage_fallbacks += 1;
                    return self.request_raw(&RequestMsg::DecodeSalvage {
                        container: container.clone(),
                        lane: *lane,
                    });
                }
            }
        }
        Ok(resp)
    }

    fn request_raw(
        &mut self,
        msg: &RequestMsg,
    ) -> Result<ResponseMsg, RequestError> {
        let mut last: Option<RequestError> = None;
        for attempt in 0..self.policy.max_attempts {
            if attempt > 0 {
                let pause = self.policy.backoff(attempt - 1, &mut self.rng);
                std::thread::sleep(pause);
                self.retries += 1;
            }
            if !self.breaker.allow(Instant::now()) {
                return Err(RequestError::CircuitOpen);
            }
            let outcome = match self.ensure_conn() {
                Ok(c) => {
                    // time only the wire round-trip, after the
                    // connection exists — the satellite fix for
                    // percentiles that used to absorb connect+backoff
                    let t = Instant::now();
                    let r = c.try_request(msg);
                    if r.is_ok() {
                        self.last_service = Some(t.elapsed());
                    }
                    r
                }
                Err(e) => Err(e),
            };
            match outcome {
                Ok(ResponseMsg::Overloaded) => {
                    // the connection is healthy, the queue is not:
                    // count it toward the breaker and back off
                    self.breaker.record_failure(Instant::now());
                    last = Some(RequestError::Overloaded);
                }
                Ok(resp) => {
                    self.breaker.record_success();
                    return Ok(resp);
                }
                Err(e) if e.retryable() => {
                    self.breaker.record_failure(Instant::now());
                    self.conn = None;
                    last = Some(e);
                }
                // deterministic failures (decode errors, server errors,
                // malformed frames) never improve with retries
                Err(e) => return Err(e),
            }
        }
        Err(last.unwrap_or(RequestError::CircuitOpen))
    }

    fn ensure_conn(&mut self) -> Result<&mut Client, RequestError> {
        if self.conn.is_none() {
            let c = Client::connect_timeout(
                &self.addr,
                self.policy.connect_timeout,
            )
            .map_err(|e| RequestError::Connect(format!("{e:#}")))?
            .with_deadline(self.policy.attempt_deadline);
            self.conn = Some(c);
        }
        Ok(self.conn.as_mut().expect("connection just ensured"))
    }
}

/// One completed event from a pipelined connection.
#[derive(Debug, Clone)]
pub enum MuxEvent {
    /// A response wrapped with the request id it answers.
    Response { request_id: u64, msg: ResponseMsg },
    /// The server refused to admit the request — the window was full at
    /// `max_inflight`. Nothing ran; resend after a completion frees a
    /// slot.
    Busy { request_id: u64, max_inflight: u32 },
}

/// Pipelined (protocol v2) client: fire-and-forget sends, completion-
/// order receives. The caller owns the windowing policy — typically
/// `send` until `pipeline` requests are outstanding, then one `recv`
/// per further `send`.
pub struct MuxClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    max_frame_len: usize,
    /// Deadline for one [`MuxClient::recv`] call.
    recv_deadline: Duration,
    next_id: u64,
}

impl MuxClient {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<MuxClient> {
        let stream =
            TcpStream::connect(addr).context("connecting to server")?;
        Self::from_stream(stream)
    }

    /// Like [`MuxClient::connect`] but bounded by `timeout`.
    pub fn connect_timeout(
        addr: &SocketAddr,
        timeout: Duration,
    ) -> Result<MuxClient> {
        let stream = TcpStream::connect_timeout(addr, timeout)
            .context("connecting to server")?;
        Self::from_stream(stream)
    }

    fn from_stream(stream: TcpStream) -> Result<MuxClient> {
        stream.set_read_timeout(Some(Duration::from_millis(200)))?;
        stream.set_write_timeout(Some(Duration::from_secs(10)))?;
        let _ = stream.set_nodelay(true);
        Ok(MuxClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            max_frame_len: MAX_FRAME_LEN_DEFAULT,
            recv_deadline: Duration::from_secs(60),
            next_id: 1,
        })
    }

    /// Override the per-`recv` deadline.
    pub fn with_deadline(mut self, d: Duration) -> MuxClient {
        self.recv_deadline = d;
        self
    }

    /// Raw access to the underlying stream (test hook).
    pub fn stream(&self) -> &TcpStream {
        self.reader.get_ref()
    }

    /// Send one request, auto-assigning the next request id; returns
    /// the id to match against [`MuxEvent::Response`].
    pub fn send(
        &mut self,
        msg: &RequestMsg,
    ) -> Result<u64, RequestError> {
        let id = self.next_id;
        self.next_id += 1;
        self.send_with_id(id, msg)?;
        Ok(id)
    }

    /// Send under an explicit request id (test hook: duplicate-id and
    /// id-space probes need ids the auto-assign would never produce).
    pub fn send_with_id(
        &mut self,
        request_id: u64,
        msg: &RequestMsg,
    ) -> Result<(), RequestError> {
        let (kind, payload) = protocol::encode_v2_request(request_id, msg);
        framing::write_frame(&mut self.writer, kind, &payload)
            .map_err(|e| RequestError::Connect(format!("{e:#}")))
    }

    /// Receive the next completed event, whatever request it answers.
    /// Responses arrive in server completion order, not send order.
    pub fn recv(&mut self) -> Result<MuxEvent, RequestError> {
        let t0 = Instant::now();
        loop {
            match framing::read_frame(&mut self.reader, self.max_frame_len)
            {
                Ok(FrameEvent::Frame { kind, payload })
                    if kind == RESP_V2 =>
                {
                    let (request_id, msg) =
                        protocol::decode_v2_response(&payload).map_err(
                            |e| RequestError::Malformed(format!("{e:#}")),
                        )?;
                    return Ok(MuxEvent::Response { request_id, msg });
                }
                Ok(FrameEvent::Frame { kind, payload })
                    if kind == RESP_V2_BUSY =>
                {
                    let (request_id, max_inflight) =
                        protocol::decode_v2_busy(&payload).map_err(
                            |e| RequestError::Malformed(format!("{e:#}")),
                        )?;
                    return Ok(MuxEvent::Busy {
                        request_id,
                        max_inflight,
                    });
                }
                Ok(FrameEvent::Frame { kind, .. }) => {
                    // a v1 frame on a pipelined stream has no id to
                    // correlate — the connection is unusable
                    return Err(RequestError::Malformed(format!(
                        "unwrapped v1 frame (kind {kind:#04x}) on a \
                         pipelined connection"
                    )));
                }
                Ok(FrameEvent::Eof) => {
                    return Err(RequestError::Connect(
                        "server closed the connection mid-request".into(),
                    ))
                }
                Ok(FrameEvent::Idle) => {
                    if t0.elapsed() > self.recv_deadline {
                        return Err(RequestError::Timeout(format!(
                            "no response within {:?}",
                            self.recv_deadline
                        )));
                    }
                }
                Err(e) => {
                    return Err(RequestError::Connect(format!("{e:#}")))
                }
            }
        }
    }
}

/// Round-robin front-tier over a shard group: one lazily-connected
/// [`Client`] per shard address, requests dealt to shards in turn. A
/// transport failure drops that shard's connection (reconnected on its
/// next turn) and surfaces the error — retry policy stays the caller's
/// concern.
pub struct ShardedClient {
    addrs: Vec<SocketAddr>,
    conns: Vec<Option<Client>>,
    next: usize,
    connect_timeout: Duration,
    deadline: Duration,
}

impl ShardedClient {
    /// `addrs` must be non-empty (one entry degenerates to a plain
    /// reconnecting client).
    pub fn new(addrs: Vec<SocketAddr>) -> ShardedClient {
        assert!(!addrs.is_empty(), "ShardedClient needs >= 1 address");
        let conns = addrs.iter().map(|_| None).collect();
        ShardedClient {
            addrs,
            conns,
            next: 0,
            connect_timeout: Duration::from_secs(2),
            deadline: Duration::from_secs(60),
        }
    }

    /// Override the per-request response deadline.
    pub fn with_deadline(mut self, d: Duration) -> ShardedClient {
        self.deadline = d;
        self
    }

    pub fn shard_count(&self) -> usize {
        self.addrs.len()
    }

    /// Send one request to the next shard in rotation.
    pub fn request(
        &mut self,
        msg: &RequestMsg,
    ) -> Result<ResponseMsg, RequestError> {
        let i = self.next % self.addrs.len();
        self.next = self.next.wrapping_add(1);
        if self.conns[i].is_none() {
            let c = Client::connect_timeout(
                &self.addrs[i],
                self.connect_timeout,
            )
            .map_err(|e| RequestError::Connect(format!("{e:#}")))?
            .with_deadline(self.deadline);
            self.conns[i] = Some(c);
        }
        let out = self.conns[i]
            .as_mut()
            .expect("connection just ensured")
            .try_request(msg);
        if matches!(
            out,
            Err(RequestError::Connect(_)) | Err(RequestError::Timeout(_))
        ) {
            // the stream may hold a half-read frame; never reuse it
            self.conns[i] = None;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_deterministic_capped_and_jittered() {
        let policy = RetryPolicy::default();
        let mut a = Rng::new(policy.jitter_seed);
        let mut b = Rng::new(policy.jitter_seed);
        for attempt in 0..10 {
            let cap = policy
                .base_backoff
                .saturating_mul(1u32 << attempt.min(16))
                .min(policy.max_backoff);
            let d = policy.backoff(attempt, &mut a);
            assert_eq!(d, policy.backoff(attempt, &mut b));
            assert!(d <= cap, "attempt {attempt}: {d:?} > {cap:?}");
            assert!(
                d >= cap / 2,
                "attempt {attempt}: {d:?} < half of {cap:?}"
            );
        }
    }

    #[test]
    fn breaker_trips_half_opens_and_recovers() {
        let cooldown = Duration::from_millis(100);
        let mut br = CircuitBreaker::new(3, cooldown);
        let t0 = Instant::now();
        assert!(br.allow(t0));
        br.record_failure(t0);
        br.record_failure(t0);
        assert!(br.allow(t0), "below threshold stays closed");
        br.record_failure(t0);
        assert!(br.is_open(t0));
        assert!(!br.allow(t0), "tripped breaker refuses requests");
        // cooldown elapses: the next request goes through as a probe
        let t1 = t0 + cooldown;
        assert!(br.allow(t1));
        // a failed probe re-opens immediately, not after 3 failures
        br.record_failure(t1);
        assert!(!br.allow(t1));
        let t2 = t1 + cooldown;
        assert!(br.allow(t2));
        br.record_success();
        assert!(!br.is_open(t2));
        // closed again: failures below the threshold are tolerated
        br.record_failure(t2);
        br.record_failure(t2);
        assert!(br.allow(t2));
    }

    #[test]
    fn retryable_classification() {
        assert!(RequestError::Connect("x".into()).retryable());
        assert!(RequestError::Overloaded.retryable());
        assert!(RequestError::Timeout("x".into()).retryable());
        assert!(!RequestError::Malformed("x".into()).retryable());
        assert!(!RequestError::CircuitOpen.retryable());
        let server = RequestError::Server {
            code: 20,
            message: "boom".into(),
        };
        assert!(!server.retryable());
    }

    #[test]
    fn display_preserves_message_contracts() {
        let e = RequestError::Connect(
            "server closed the connection mid-request".into(),
        );
        assert_eq!(
            e.to_string(),
            "server closed the connection mid-request"
        );
        assert_eq!(
            RequestError::Overloaded.to_string(),
            "server overloaded"
        );
        let e = RequestError::Server {
            code: 22,
            message: "worker panicked".into(),
        };
        assert_eq!(e.to_string(), "server error 22: worker panicked");
    }
}
