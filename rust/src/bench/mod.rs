//! Benchmark harness: the measurement protocol behind every paper table
//! (warmup + repeated wall-clock samples + median), plus the table
//! formatters the `cargo bench` targets print.

pub mod tables;

use crate::util::timer::{Bench, Stats};

/// A single (label, stats) measurement row across the three lanes.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    /// Serial CPU lane.
    pub cpu: Option<Stats>,
    /// Block-parallel CPU lane.
    pub cpu_par: Option<Stats>,
    /// PJRT lane.
    pub gpu: Option<Stats>,
    pub extra: Vec<(String, String)>,
}

impl Row {
    /// Serial-CPU / GPU speedup (the paper's headline column).
    pub fn speedup(&self) -> Option<f64> {
        match (&self.cpu, &self.gpu) {
            (Some(c), Some(g)) if g.median_ms > 0.0 => {
                Some(c.median_ms / g.median_ms)
            }
            _ => None,
        }
    }

    /// Serial-CPU / parallel-CPU speedup (the multi-core column).
    pub fn speedup_parallel(&self) -> Option<f64> {
        match (&self.cpu, &self.cpu_par) {
            (Some(c), Some(p)) if p.median_ms > 0.0 => {
                Some(c.median_ms / p.median_ms)
            }
            _ => None,
        }
    }
}

fn fmt_ms(stats: &Option<Stats>) -> String {
    stats
        .as_ref()
        .map(|st| format!("{:.2}", st.median_ms))
        .unwrap_or_else(|| "-".into())
}

fn fmt_speedup(v: Option<f64>) -> String {
    v.map(|v| format!("{v:.1}x")).unwrap_or_else(|| "-".into())
}

/// Render rows in the paper's table style, extended with the parallel-CPU
/// lane columns.
pub fn render_table(title: &str, rows: &[Row]) -> String {
    let mut s = format!("\n=== {title} ===\n");
    s += &format!(
        "{:<16} {:>12} {:>12} {:>9} {:>12} {:>9}\n",
        "Input image", "CPU(ms)", "CPUpar(ms)", "ParSp", "GPU(ms)",
        "Speedup"
    );
    for r in rows {
        s += &format!(
            "{:<16} {:>12} {:>12} {:>9} {:>12} {:>9}",
            r.label,
            fmt_ms(&r.cpu),
            fmt_ms(&r.cpu_par),
            fmt_speedup(r.speedup_parallel()),
            fmt_ms(&r.gpu),
            fmt_speedup(r.speedup()),
        );
        for (k, v) in &r.extra {
            s += &format!("  {k}={v}");
        }
        s.push('\n');
    }
    s
}

/// Emit a machine-readable JSON line per row (collected into
/// bench_results/*.json by the bench targets).
///
/// Schema: `label` plus per-lane medians/means (`cpu_ms`, `cpu_par_ms`,
/// `gpu_ms`, `*_mean_ms`) and derived `speedup` / `speedup_parallel`.
/// `extra` pairs pass through (numeric strings as numbers) — the
/// microbench stage rows use this for the throughput columns
/// `blocks_per_s` and `mb_per_s` and for `speedup_vs_scalar` on the
/// batched transform stages; the chroma-ablation workload rows use it
/// for `gpu_backend` (`"stub"` or `"pjrt"` — which backend filled
/// `gpu_ms`) and `gpu_psnr_weighted` (the GPU lane's 6:1:1 luma-weighted
/// color PSNR).
pub fn rows_to_json(table: &str, rows: &[Row]) -> String {
    use crate::util::json::Json;
    let arr: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut pairs: Vec<(&str, Json)> = vec![
                ("label", Json::str(r.label.clone())),
            ];
            if let Some(c) = &r.cpu {
                pairs.push(("cpu_ms", Json::num(c.median_ms)));
                pairs.push(("cpu_mean_ms", Json::num(c.mean_ms)));
            }
            if let Some(p) = &r.cpu_par {
                pairs.push(("cpu_par_ms", Json::num(p.median_ms)));
                pairs.push(("cpu_par_mean_ms", Json::num(p.mean_ms)));
            }
            if let Some(g) = &r.gpu {
                pairs.push(("gpu_ms", Json::num(g.median_ms)));
                pairs.push(("gpu_mean_ms", Json::num(g.mean_ms)));
            }
            if let Some(s) = r.speedup() {
                pairs.push(("speedup", Json::num(s)));
            }
            if let Some(s) = r.speedup_parallel() {
                pairs.push(("speedup_parallel", Json::num(s)));
            }
            for (k, v) in &r.extra {
                // numbers pass through as numbers when they parse
                if let Ok(n) = v.parse::<f64>() {
                    pairs.push((Box::leak(k.clone().into_boxed_str()),
                                Json::num(n)));
                } else {
                    pairs.push((Box::leak(k.clone().into_boxed_str()),
                                Json::str(v.clone())));
                }
            }
            Json::obj(pairs)
        })
        .collect();
    Json::obj(vec![
        ("table", Json::str(table)),
        ("rows", Json::Arr(arr)),
    ])
    .to_string()
}

/// Persist bench output under `bench_results/` — or the directory named
/// by `CORDIC_DCT_BENCH_OUT` (the CI bench-smoke job points this at
/// `bench-out/` and uploads it as a workflow artifact).
pub fn save_results(name: &str, text: &str, json: &str) {
    let dir = std::env::var("CORDIC_DCT_BENCH_OUT")
        .unwrap_or_else(|_| "bench_results".to_string());
    let dir = std::path::Path::new(&dir);
    let _ = std::fs::create_dir_all(dir);
    let _ = std::fs::write(dir.join(format!("{name}.txt")), text);
    let _ = std::fs::write(dir.join(format!("{name}.json")), json);
}

/// Bench config from env: CORDIC_DCT_BENCH_QUICK=1 trims iterations (CI).
pub fn bench_config() -> Bench {
    if std::env::var("CORDIC_DCT_BENCH_QUICK").is_ok() {
        Bench {
            warmup: 1,
            iters: 3,
            budget_ms: 2_000.0,
        }
    } else {
        Bench {
            warmup: 2,
            iters: 7,
            budget_ms: 20_000.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::timer::Stats;

    fn stats(ms: f64) -> Stats {
        Stats::from_samples_ms(&[ms, ms, ms])
    }

    #[test]
    fn speedup_computed() {
        let r = Row {
            label: "512x512".into(),
            cpu: Some(stats(100.0)),
            cpu_par: Some(stats(25.0)),
            gpu: Some(stats(4.0)),
            extra: vec![],
        };
        assert_eq!(r.speedup(), Some(25.0));
        assert_eq!(r.speedup_parallel(), Some(4.0));
    }

    #[test]
    fn speedups_absent_without_lanes() {
        let r = Row {
            label: "x".into(),
            cpu: Some(stats(10.0)),
            cpu_par: None,
            gpu: None,
            extra: vec![],
        };
        assert_eq!(r.speedup(), None);
        assert_eq!(r.speedup_parallel(), None);
    }

    #[test]
    fn render_contains_rows() {
        let rows = vec![Row {
            label: "200x200".into(),
            cpu: Some(stats(6.88)),
            cpu_par: Some(stats(1.72)),
            gpu: Some(stats(0.24)),
            extra: vec![("psnr".into(), "31.61".into())],
        }];
        let t = render_table("Table 1", &rows);
        assert!(t.contains("200x200"));
        assert!(t.contains("6.88"));
        assert!(t.contains("1.72"));
        assert!(t.contains("4.0x"), "parallel speedup column: {t}");
        assert!(t.contains("psnr=31.61"));
    }

    #[test]
    fn json_parses_back() {
        let rows = vec![Row {
            label: "a".into(),
            cpu: Some(stats(2.0)),
            cpu_par: Some(stats(1.0)),
            gpu: None,
            extra: vec![("k".into(), "3.5".into())],
        }];
        let j = rows_to_json("t", &rows);
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(
            parsed.get("table").unwrap().as_str().unwrap(),
            "t"
        );
        let row = &parsed.get("rows").unwrap().as_arr().unwrap()[0];
        assert_eq!(row.get("cpu_ms").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(row.get("cpu_par_ms").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(
            row.get("speedup_parallel").unwrap().as_f64().unwrap(),
            2.0
        );
        assert_eq!(row.get("k").unwrap().as_f64().unwrap(), 3.5);
    }
}
