//! Paper-experiment drivers: one function per table/figure, shared by the
//! `cargo bench` targets and the `paper-tables` CLI subcommand.
//!
//! Experiment map (DESIGN.md §5):
//!   E1 Table 1      — Lena sweep, CPU vs GPU wall ms
//!   E2 Table 2      — Cable-car sweep
//!   E3 Fig. 5/6     — speedup series from E1
//!   E4 Fig. 10/11   — speedup series from E2
//!   E5 Table 3      — Lena PSNR, DCT vs Cordic-Loeffler
//!   E6 Table 4      — Cable-car PSNR

use std::sync::Arc;

use anyhow::Result;

use crate::dct::parallel::ParallelCpuPipeline;
use crate::dct::pipeline::CpuPipeline;
use crate::dct::Variant;
use crate::image::{synthetic, GrayImage};
use crate::metrics;
use crate::runtime::{Executor, Runtime};
use crate::util::timer::Bench;

use super::{render_table, rows_to_json, save_results, Row};

/// The paper's size sweeps, (height, width) — matching the artifact
/// naming (`compress_*_{H}x{W}`) and the labels printed in the tables.
pub const LENA_SIZES: &[(usize, usize)] = &[
    (3072, 3072),
    (2048, 2048),
    (1600, 1400),
    (1024, 814),
    (576, 720),
    (512, 512),
    (200, 200),
];

pub const CABLECAR_SIZES: &[(usize, usize)] = &[
    (544, 512),
    (512, 480),
    (448, 416),
    (384, 352),
    (320, 288),
];

/// PSNR-table subsets (paper Tables 3-4 column sets).
pub const LENA_PSNR_SIZES: &[(usize, usize)] =
    &[(200, 200), (512, 512), (2048, 2048), (3072, 3072)];
pub const CABLECAR_PSNR_SIZES: &[(usize, usize)] = CABLECAR_SIZES;

/// Paper reference numbers for side-by-side printing (CPU ms, GPU ms).
pub const PAPER_TABLE1: &[(&str, f64, f64)] = &[
    ("3072x3072", 1020.32, 8.92),
    ("2048x2048", 266.23, 5.61),
    ("1600x1400", 116.12, 2.20),
    ("1024x814", 88.23, 1.24),
    ("576x720", 48.52, 0.82),
    ("512x512", 16.42, 0.62),
    ("200x200", 6.88, 0.24),
];

pub const PAPER_TABLE2: &[(&str, f64, f64)] = &[
    ("544x512", 30.32, 0.58),
    ("512x480", 26.84, 0.41),
    ("448x416", 21.22, 0.34),
    ("384x352", 17.28, 0.26),
    ("320x288", 10.86, 0.19),
];

/// Build the scene image at a sweep size ((h, w) tuples; GrayImage takes
/// width first).
pub fn scene_image(scene: &str, h: usize, w: usize) -> GrayImage {
    synthetic::by_name(scene, w, h, 0xD_C7)
        .unwrap_or_else(|| panic!("unknown scene {scene}"))
}

/// Load the runtime if artifacts are present.
pub fn try_runtime() -> Option<Arc<Runtime>> {
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        Runtime::new(dir).ok().map(Arc::new)
    } else {
        None
    }
}

/// Cap a size sweep for quick mode (drop > 1 MPixel entries).
pub fn maybe_trim(sizes: &[(usize, usize)]) -> Vec<(usize, usize)> {
    if std::env::var("CORDIC_DCT_BENCH_QUICK").is_ok() {
        sizes
            .iter()
            .copied()
            .filter(|&(h, w)| h * w <= 1024 * 1024)
            .collect()
    } else {
        sizes.to_vec()
    }
}

/// E1/E2: timing sweep over one scene. `variant` is the transform all
/// three lanes run (the paper's tables time the full DCT pipeline); the
/// parallel-CPU column is this reproduction's multi-core extension.
pub fn timing_table(
    scene: &str,
    sizes: &[(usize, usize)],
    variant: Variant,
    bench: Bench,
) -> Result<Vec<Row>> {
    let runtime = try_runtime();
    let executor = runtime.map(Executor::new);
    let cpu_pipe = CpuPipeline::new(variant, 50);
    let par_pipe = ParallelCpuPipeline::new(variant, 50);
    let mut rows = Vec::new();
    for &(h, w) in sizes {
        let img = scene_image(scene, h, w);
        let cpu = bench.run(|| cpu_pipe.compress(&img));
        let cpu_par = bench.run(|| par_pipe.compress(&img));
        let gpu = executor.as_ref().map(|ex| {
            bench.run(|| {
                ex.compress(&img, variant.as_str())
                    .expect("gpu lane compress")
            })
        });
        let mut extra = Vec::new();
        if let Some(ex) = &executor {
            // per-row PSNR sanity tag
            let out = ex.compress(&img, variant.as_str())?;
            extra.push((
                "psnr".into(),
                format!("{:.2}", metrics::psnr(&img, &out.recon)),
            ));
        }
        rows.push(Row {
            label: format!("{h}x{w}"),
            cpu: Some(cpu),
            cpu_par: Some(cpu_par),
            gpu,
            extra,
        });
    }
    Ok(rows)
}

/// E5/E6: PSNR table — exact DCT vs Cordic-based Loeffler per size.
pub fn psnr_table(scene: &str, sizes: &[(usize, usize)])
                  -> Result<Vec<Row>> {
    let dct = CpuPipeline::new(Variant::Dct, 50);
    let cordic = CpuPipeline::new(Variant::Cordic, 50);
    let mut rows = Vec::new();
    for &(h, w) in sizes {
        let img = scene_image(scene, h, w);
        let p_dct = metrics::psnr(&img, &dct.compress(&img).recon);
        let p_cor = metrics::psnr(&img, &cordic.compress(&img).recon);
        rows.push(Row {
            label: format!("{h}x{w}"),
            cpu: None,
            cpu_par: None,
            gpu: None,
            extra: vec![
                ("dct_psnr".into(), format!("{p_dct:.6}")),
                ("cordic_psnr".into(), format!("{p_cor:.6}")),
                ("gap_db".into(), format!("{:.3}", p_dct - p_cor)),
            ],
        });
    }
    Ok(rows)
}

/// Speedup series (Figures 5/6/10/11): derived from a timing table.
pub fn speedup_series(rows: &[Row]) -> Vec<(String, f64)> {
    rows.iter()
        .filter_map(|r| r.speedup().map(|s| (r.label.clone(), s)))
        .collect()
}

/// Render a PSNR table in the paper's layout (Tables 3-4).
pub fn render_psnr_table(title: &str, rows: &[Row]) -> String {
    let mut s = format!("\n=== {title} ===\n");
    s += &format!("{:<14}", "");
    for r in rows {
        s += &format!("{:>14}", r.label);
    }
    s.push('\n');
    for (key, name) in [
        ("dct_psnr", "DCT"),
        ("cordic_psnr", "Cordic-Loeffler"),
        ("gap_db", "gap (dB)"),
    ] {
        s += &format!("{name:<14}");
        for r in rows {
            let v = r
                .extra
                .iter()
                .find(|(k, _)| k == key)
                .map(|(_, v)| v.as_str())
                .unwrap_or("-");
            s += &format!("{v:>14}");
        }
        s.push('\n');
    }
    s
}

/// Render an ASCII speedup figure (the paper's Figures 5/6/10/11 as a
/// terminal bar chart).
pub fn render_speedup_figure(title: &str, series: &[(String, f64)])
                             -> String {
    let mut s = format!("\n=== {title} ===\n");
    let max = series
        .iter()
        .map(|(_, v)| *v)
        .fold(1.0f64, f64::max);
    for (label, v) in series {
        let bar_len = ((v / max) * 50.0).round() as usize;
        s += &format!(
            "{label:<12} {:>7.1}x |{}\n",
            v,
            "#".repeat(bar_len.max(1))
        );
    }
    s
}

/// Print paper-reference vs measured side by side (shape check).
pub fn render_paper_comparison(
    title: &str,
    rows: &[Row],
    paper: &[(&str, f64, f64)],
) -> String {
    let mut s = format!("\n=== {title}: paper vs measured ===\n");
    s += &format!(
        "{:<12} {:>10} {:>10} {:>9} | {:>10} {:>10} {:>9}\n",
        "size", "paperCPU", "paperGPU", "paperSp", "ourCPU", "ourGPU",
        "ourSp"
    );
    for r in rows {
        let p = paper.iter().find(|(l, _, _)| *l == r.label);
        let (pc, pg, ps) = match p {
            Some((_, c, g)) => {
                (format!("{c:.2}"), format!("{g:.2}"),
                 format!("{:.0}x", c / g))
            }
            None => ("-".into(), "-".into(), "-".into()),
        };
        let oc = r
            .cpu
            .as_ref()
            .map(|v| format!("{:.2}", v.median_ms))
            .unwrap_or("-".into());
        let og = r
            .gpu
            .as_ref()
            .map(|v| format!("{:.2}", v.median_ms))
            .unwrap_or("-".into());
        let os = r
            .speedup()
            .map(|v| format!("{v:.0}x"))
            .unwrap_or("-".into());
        s += &format!(
            "{:<12} {pc:>10} {pg:>10} {ps:>9} | {oc:>10} {og:>10} {os:>9}\n",
            r.label
        );
    }
    s
}

/// Run + persist one timing experiment end to end (used by bench mains).
pub fn run_timing_experiment(
    name: &str,
    title: &str,
    scene: &str,
    sizes: &[(usize, usize)],
    paper: &[(&str, f64, f64)],
) -> Result<()> {
    let bench = super::bench_config();
    let sizes = maybe_trim(sizes);
    let rows = timing_table(scene, &sizes, Variant::Cordic, bench)?;
    let mut text = render_table(title, &rows);
    text += &render_paper_comparison(title, &rows, paper);
    text += &render_speedup_figure(
        &format!("{title} speedup (figure)"),
        &speedup_series(&rows),
    );
    println!("{text}");
    save_results(name, &text, &rows_to_json(name, &rows));
    Ok(())
}

/// Run + persist one PSNR experiment.
pub fn run_psnr_experiment(
    name: &str,
    title: &str,
    scene: &str,
    sizes: &[(usize, usize)],
) -> Result<()> {
    let sizes = maybe_trim(sizes);
    let rows = psnr_table(scene, &sizes)?;
    let text = render_psnr_table(title, &rows);
    println!("{text}");
    save_results(name, &text, &rows_to_json(name, &rows));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::timer::Stats;

    #[test]
    fn sweeps_match_paper_row_counts() {
        assert_eq!(LENA_SIZES.len(), 7); // Table 1 has 7 rows
        assert_eq!(CABLECAR_SIZES.len(), 5); // Table 2 has 5 rows
        assert_eq!(LENA_PSNR_SIZES.len(), 4); // Table 3 columns
        assert_eq!(CABLECAR_PSNR_SIZES.len(), 5); // Table 4 columns
    }

    #[test]
    fn psnr_table_small() {
        let rows =
            psnr_table("lena", &[(64, 64), (128, 128)]).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            let gap: f64 = r
                .extra
                .iter()
                .find(|(k, _)| k == "gap_db")
                .unwrap()
                .1
                .parse()
                .unwrap();
            assert!(gap > 0.0, "cordic must trail dct: {gap}");
        }
        let rendered = render_psnr_table("t", &rows);
        assert!(rendered.contains("Cordic-Loeffler"));
    }

    #[test]
    fn speedup_series_extracts() {
        let rows = vec![Row {
            label: "x".into(),
            cpu: Some(Stats::from_samples_ms(&[10.0])),
            cpu_par: None,
            gpu: Some(Stats::from_samples_ms(&[2.0])),
            extra: vec![],
        }];
        let s = speedup_series(&rows);
        assert_eq!(s, vec![("x".to_string(), 5.0)]);
        let fig = render_speedup_figure("f", &s);
        assert!(fig.contains("5.0x"));
    }

    #[test]
    fn paper_comparison_renders() {
        let rows = vec![Row {
            label: "200x200".into(),
            cpu: Some(Stats::from_samples_ms(&[5.0])),
            cpu_par: Some(Stats::from_samples_ms(&[1.0])),
            gpu: Some(Stats::from_samples_ms(&[0.5])),
            extra: vec![],
        }];
        let s = render_paper_comparison("T1", &rows, PAPER_TABLE1);
        assert!(s.contains("6.88"), "paper value shown");
        assert!(s.contains("10x"), "our speedup shown");
    }
}
