//! Container decoder: header + Huffman tables + entropy-coded blocks back
//! to planar quantized coefficients. Strictly validating — corrupt input
//! must produce an `Err`, never a panic or OOM.
//!
//! Two entry points:
//!
//! * [`decode`] — fail-fast over either container version (`CDC1` or
//!   `CDC2`): any checksum, marker, or entropy failure is an `Err`.
//! * [`decode_salvage`] — damage-tolerant over `CDC2`: verifies each
//!   restart segment's crc32, re-syncs at the next segment marker after
//!   a failure, conceals damaged segments (DC-midpoint fill plus
//!   replication of the nearest intact block row), and reports what it
//!   did in a [`SalvageReport`]. Hard-fails only when the head (header,
//!   Huffman tables, segment index) is unusable.

use anyhow::{Context, Result};

use crate::dct::blocks::{grid_dims, store_coef_planar, BLOCK};
use crate::util::bitio::BitReader;

use super::encoder::{rows_per_segment, segment_count};
use super::huffman::{HuffmanCode, HuffmanDecoder};
use super::rle::read_block;
use super::zigzag::unscan;
use super::{
    decode_bail, DecodeErrorKind, Header, PlaneSalvage, SalvageReport,
    MAX_PIXELS, SEG_MARKER, SEG_MARKER_BASE,
};

/// Decoded container: header + planar coefficients (padded layout).
pub struct Decoded {
    pub header: Header,
    pub qcoef_planar: Vec<f32>,
}

/// Bytes of a v2 segment header: marker pair + u32 length + u32 crc32.
const SEG_HEAD_BYTES: usize = 2 + 4 + 4;

pub fn decode(bytes: &[u8]) -> Result<Decoded> {
    if super::is_v2_container(bytes) {
        decode_v2(bytes)
    } else {
        decode_v1(bytes)
    }
}

fn decode_v1(bytes: &[u8]) -> Result<Decoded> {
    let (header, mut off) = Header::read(bytes)?;
    let pw = header.padded_width as u64;
    let ph = header.padded_height as u64;
    // Defense in depth: Header::read already caps this, but the
    // allocation below must never trust anything it did not check.
    if pw * ph > MAX_PIXELS {
        decode_bail!(
            DecodeErrorKind::TooLarge,
            "image too large: {pw}x{ph}"
        );
    }
    let (dc_code, used) = HuffmanCode::read_table(&bytes[off..])
        .context("DC Huffman table")?;
    off += used;
    let (ac_code, used) = HuffmanCode::read_table(&bytes[off..])
        .context("AC Huffman table")?;
    off += used;
    if bytes.len() < off + 4 {
        decode_bail!(DecodeErrorKind::Truncated, "truncated payload length");
    }
    let payload_len = u32::from_le_bytes([
        bytes[off],
        bytes[off + 1],
        bytes[off + 2],
        bytes[off + 3],
    ]) as usize;
    off += 4;
    if bytes.len() < off + payload_len {
        decode_bail!(
            DecodeErrorKind::Truncated,
            "payload truncated: header says {payload_len}, {} available",
            bytes.len() - off
        );
    }
    let payload = &bytes[off..off + payload_len];

    let dc_dec = HuffmanDecoder::new(&dc_code);
    let ac_dec = HuffmanDecoder::new(&ac_code);
    let (gw, gh) = grid_dims(pw as usize, ph as usize);
    let mut qcoef = vec![0.0f32; (pw * ph) as usize];
    decode_rows(
        payload,
        0..gh,
        gw,
        pw as usize,
        &dc_dec,
        &ac_dec,
        &mut qcoef,
    )?;
    Ok(Decoded {
        header,
        qcoef_planar: qcoef,
    })
}

/// Entropy-decode one byte-aligned bitstream covering block rows
/// `rows` (DC predictor starts at 0) into the planar buffer.
fn decode_rows(
    payload: &[u8],
    rows: std::ops::Range<usize>,
    gw: usize,
    pw: usize,
    dc_dec: &HuffmanDecoder,
    ac_dec: &HuffmanDecoder,
    qcoef: &mut [f32],
) -> Result<()> {
    let mut r = BitReader::new(payload);
    let mut prev_dc: i16 = 0;
    for by in rows {
        for bx in 0..gw {
            let z = read_block(
                &mut r,
                prev_dc,
                |r| dc_dec.get(r),
                |r| ac_dec.get(r),
            )
            .with_context(|| {
                format!("[decode:corrupt] entropy block ({bx},{by})")
            })?;
            prev_dc = z[0];
            let block = unscan(&z);
            store_coef_planar(qcoef, pw, bx, by, &block);
        }
    }
    Ok(())
}

/// Parsed, crc-verified head of a v2 container: everything before the
/// first segment. A salvage decode can trust all of it — the head crc32
/// covers the header fields, both Huffman tables, and the length index.
struct V2Head {
    header: Header,
    rows_per_seg: usize,
    seg_count: usize,
    dc: HuffmanCode,
    ac: HuffmanCode,
    seg_lens: Vec<u32>,
    /// Offset of the first segment marker.
    head_len: usize,
}

fn read_v2_head(bytes: &[u8]) -> Result<V2Head> {
    let (header, mut off) = Header::read_v2(bytes)?;
    let pw = header.padded_width as u64;
    let ph = header.padded_height as u64;
    if pw * ph > MAX_PIXELS {
        decode_bail!(
            DecodeErrorKind::TooLarge,
            "image too large: {pw}x{ph}"
        );
    }
    if bytes.len() < off + 6 {
        decode_bail!(
            DecodeErrorKind::Truncated,
            "truncated v2 segment fields"
        );
    }
    let restart_interval =
        u16::from_le_bytes([bytes[off], bytes[off + 1]]);
    let seg_count = u32::from_le_bytes([
        bytes[off + 2],
        bytes[off + 3],
        bytes[off + 4],
        bytes[off + 5],
    ]) as usize;
    off += 6;
    let (_gw, gh) = grid_dims(pw as usize, ph as usize);
    let rows_per_seg = rows_per_segment(restart_interval, gh);
    // the DoS guard for the index allocation below: the count must
    // agree with the grid geometry, which MAX_PIXELS already bounds
    if seg_count != segment_count(restart_interval, gh) {
        decode_bail!(
            DecodeErrorKind::BadHeader,
            "segment count {seg_count} disagrees with {gh} block rows \
             at interval {restart_interval}"
        );
    }
    let (dc, used) = HuffmanCode::read_table(&bytes[off..])
        .context("DC Huffman table")?;
    off += used;
    let (ac, used) = HuffmanCode::read_table(&bytes[off..])
        .context("AC Huffman table")?;
    off += used;
    if bytes.len() < off + seg_count * 4 + 4 {
        decode_bail!(
            DecodeErrorKind::Truncated,
            "truncated v2 segment index ({seg_count} segments)"
        );
    }
    let mut seg_lens = Vec::with_capacity(seg_count);
    for i in 0..seg_count {
        let o = off + i * 4;
        seg_lens.push(u32::from_le_bytes([
            bytes[o],
            bytes[o + 1],
            bytes[o + 2],
            bytes[o + 3],
        ]));
    }
    off += seg_count * 4;
    let stored = u32::from_le_bytes([
        bytes[off],
        bytes[off + 1],
        bytes[off + 2],
        bytes[off + 3],
    ]);
    if crc32fast::hash(&bytes[..off]) != stored {
        decode_bail!(
            DecodeErrorKind::Corrupt,
            "v2 head checksum mismatch"
        );
    }
    off += 4;
    Ok(V2Head {
        header,
        rows_per_seg,
        seg_count,
        dc,
        ac,
        seg_lens,
        head_len: off,
    })
}

/// Is a well-formed segment header for segment `s` (marker pair, inline
/// length matching the index, crc32 matching the payload) at `pos`?
fn segment_valid_at(
    bytes: &[u8],
    pos: usize,
    s: usize,
    len: usize,
) -> bool {
    if bytes.len() < pos + SEG_HEAD_BYTES + len {
        return false;
    }
    if bytes[pos] != SEG_MARKER
        || bytes[pos + 1] != SEG_MARKER_BASE + (s as u8 & 7)
    {
        return false;
    }
    let inline_len = u32::from_le_bytes([
        bytes[pos + 2],
        bytes[pos + 3],
        bytes[pos + 4],
        bytes[pos + 5],
    ]) as usize;
    if inline_len != len {
        return false;
    }
    let crc = u32::from_le_bytes([
        bytes[pos + 6],
        bytes[pos + 7],
        bytes[pos + 8],
        bytes[pos + 9],
    ]);
    crc32fast::hash(&bytes[pos + SEG_HEAD_BYTES..pos + SEG_HEAD_BYTES + len])
        == crc
}

fn decode_v2(bytes: &[u8]) -> Result<Decoded> {
    let head = read_v2_head(bytes)?;
    let pw = head.header.padded_width as usize;
    let ph = head.header.padded_height as usize;
    let (gw, gh) = grid_dims(pw, ph);
    let dc_dec = HuffmanDecoder::new(&head.dc);
    let ac_dec = HuffmanDecoder::new(&head.ac);
    let mut qcoef = vec![0.0f32; pw * ph];
    let mut off = head.head_len;
    for s in 0..head.seg_count {
        let len = head.seg_lens[s] as usize;
        if bytes.len() < off + SEG_HEAD_BYTES + len {
            decode_bail!(
                DecodeErrorKind::Truncated,
                "segment {s} truncated: {} bytes needed, {} available",
                SEG_HEAD_BYTES + len,
                bytes.len() - off
            );
        }
        if !segment_valid_at(bytes, off, s, len) {
            decode_bail!(
                DecodeErrorKind::Corrupt,
                "segment {s} marker or checksum mismatch"
            );
        }
        let payload = &bytes[off + SEG_HEAD_BYTES..off + SEG_HEAD_BYTES + len];
        let r0 = s * head.rows_per_seg;
        let r1 = (r0 + head.rows_per_seg).min(gh);
        decode_rows(payload, r0..r1, gw, pw, &dc_dec, &ac_dec, &mut qcoef)
            .with_context(|| format!("segment {s}"))?;
        off += SEG_HEAD_BYTES + len;
    }
    Ok(Decoded {
        header: head.header,
        qcoef_planar: qcoef,
    })
}

/// Scan forward from `from` for a valid header of segment `s` — the
/// re-sync step after damage. The triple check (marker pair, index
/// length, payload crc32) makes a false anchor on entropy bytes
/// vanishingly unlikely.
fn scan_segment(
    bytes: &[u8],
    from: usize,
    s: usize,
    len: usize,
) -> Option<usize> {
    let mut pos = from;
    while pos + SEG_HEAD_BYTES + len <= bytes.len() {
        if bytes[pos] == SEG_MARKER
            && bytes[pos + 1] == SEG_MARKER_BASE + (s as u8 & 7)
            && segment_valid_at(bytes, pos, s, len)
        {
            return Some(pos);
        }
        pos += 1;
    }
    None
}

/// Salvage-decode one grayscale stream (either version), reporting
/// per-plane damage. v1 streams have no segments to salvage: they decode
/// strictly and report a single clean segment, or propagate the error.
pub(crate) fn decode_salvage_plane(
    bytes: &[u8],
) -> Result<(Decoded, PlaneSalvage)> {
    if !super::is_v2_container(bytes) {
        let dec = decode(bytes)?;
        return Ok((
            dec,
            PlaneSalvage {
                segments_total: 1,
                ..PlaneSalvage::default()
            },
        ));
    }
    let head = read_v2_head(bytes)?;
    let pw = head.header.padded_width as usize;
    let ph = head.header.padded_height as usize;
    let (gw, gh) = grid_dims(pw, ph);
    let dc_dec = HuffmanDecoder::new(&head.dc);
    let ac_dec = HuffmanDecoder::new(&head.ac);
    let mut qcoef = vec![0.0f32; pw * ph];
    let mut ps = PlaneSalvage {
        segments_total: head.seg_count as u32,
        ..PlaneSalvage::default()
    };
    let mut row_ok = vec![false; gh];
    let mut damaged: Vec<usize> = Vec::new();
    // `cursor` is where the next segment should start; `resync_from` is
    // the end of the last intact segment (never past real data, so a
    // splice that removed bytes is still covered by the scan)
    let mut cursor = head.head_len;
    let mut resync_from = head.head_len;
    for s in 0..head.seg_count {
        let len = head.seg_lens[s] as usize;
        let r0 = s * head.rows_per_seg;
        let r1 = (r0 + head.rows_per_seg).min(gh);
        let found = if segment_valid_at(bytes, cursor, s, len) {
            Some(cursor)
        } else {
            scan_segment(bytes, resync_from, s, len)
        };
        let decoded = found.is_some_and(|pos| {
            let payload =
                &bytes[pos + SEG_HEAD_BYTES..pos + SEG_HEAD_BYTES + len];
            let ok = decode_rows(
                payload,
                r0..r1,
                gw,
                pw,
                &dc_dec,
                &ac_dec,
                &mut qcoef,
            )
            .is_ok();
            if ok {
                if pos > cursor {
                    ps.bytes_skipped += (pos - cursor) as u64;
                }
                cursor = pos + SEG_HEAD_BYTES + len;
                resync_from = cursor;
            }
            ok
        });
        if decoded {
            for by in r0..r1 {
                row_ok[by] = true;
            }
        } else {
            ps.segments_damaged += 1;
            damaged.push(s);
            ps.bytes_skipped += (SEG_HEAD_BYTES + len) as u64;
            // nominal advance: a pure bit-flip leaves later segments at
            // their indexed offsets; a splice is caught by the scan
            cursor += SEG_HEAD_BYTES + len;
        }
    }
    // concealment: damaged bands reset to zero coefficients (DC
    // midpoint — mid-gray after the level shift), then patched with the
    // nearest intact block row when one exists
    let any_ok = row_ok.iter().any(|&b| b);
    for &s in &damaged {
        let r0 = s * head.rows_per_seg;
        let r1 = (r0 + head.rows_per_seg).min(gh);
        for by in r0..r1 {
            let band = by * BLOCK * pw;
            qcoef[band..band + BLOCK * pw].fill(0.0);
            if let Some(src) = nearest_ok_row(&row_ok, by) {
                let sband = src * BLOCK * pw;
                qcoef.copy_within(sband..sband + BLOCK * pw, band);
            }
        }
        if any_ok {
            ps.segments_concealed += 1;
        }
    }
    Ok((
        Decoded {
            header: head.header,
            qcoef_planar: qcoef,
        },
        ps,
    ))
}

/// Nearest block row flagged intact, searching outward from `by`.
fn nearest_ok_row(row_ok: &[bool], by: usize) -> Option<usize> {
    for d in 1..row_ok.len() {
        if by >= d && row_ok[by - d] {
            return Some(by - d);
        }
        if by + d < row_ok.len() && row_ok[by + d] {
            return Some(by + d);
        }
    }
    None
}

/// Damage-tolerant decode of a grayscale container. Strict semantics
/// for v1 input; for v2, per-segment crc verification, marker re-sync,
/// and concealment as described in the module docs. Errors only when
/// the head (header, tables, index) is unusable.
pub fn decode_salvage(bytes: &[u8]) -> Result<(Decoded, SalvageReport)> {
    let (dec, ps) = decode_salvage_plane(bytes)?;
    Ok((dec, SalvageReport::from_planes(vec![ps])))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{encoder, variant_tag};
    use crate::dct::pipeline::CpuPipeline;
    use crate::dct::Variant;
    use crate::image::synthetic;
    use crate::metrics::psnr;
    use crate::util::prng::Rng;

    fn encode_image(
        w: usize,
        h: usize,
        variant: Variant,
        quality: u8,
    ) -> (Vec<u8>, Vec<f32>, usize, usize) {
        let img = synthetic::lena_like(w, h, 7);
        let pipe = CpuPipeline::new(variant, quality);
        let (qcoef, pw, ph) = pipe.analyze(&img);
        let header = Header {
            width: w as u32,
            height: h as u32,
            padded_width: pw as u32,
            padded_height: ph as u32,
            quality,
            variant: variant_tag(variant),
        };
        (encoder::encode(&header, &qcoef).unwrap(), qcoef, pw, ph)
    }

    #[test]
    fn roundtrip_exact_coefficients() {
        let (bytes, qcoef, _pw, _ph) =
            encode_image(64, 48, Variant::Dct, 50);
        let dec = decode(&bytes).unwrap();
        assert_eq!(dec.qcoef_planar, qcoef);
        assert_eq!(dec.header.width, 64);
        assert_eq!(dec.header.quality, 50);
    }

    #[test]
    fn roundtrip_unaligned_size() {
        let (bytes, qcoef, pw, ph) =
            encode_image(30, 21, Variant::Cordic, 75);
        let dec = decode(&bytes).unwrap();
        assert_eq!((pw, ph), (32, 24));
        assert_eq!(dec.qcoef_planar, qcoef);
    }

    #[test]
    fn full_file_to_image_pipeline() {
        let img = synthetic::cablecar_like(96, 80, 3);
        let pipe = CpuPipeline::new(Variant::Dct, 50);
        let (qcoef, pw, ph) = pipe.analyze(&img);
        let header = Header {
            width: 96,
            height: 80,
            padded_width: pw as u32,
            padded_height: ph as u32,
            quality: 50,
            variant: variant_tag(Variant::Dct),
        };
        let bytes = encoder::encode(&header, &qcoef).unwrap();
        let dec = decode(&bytes).unwrap();
        let recon = pipe.decode_coefficients(
            &dec.qcoef_planar,
            pw,
            ph,
            96,
            80,
        );
        let p = psnr(&img, &recon);
        assert!(p > 30.0, "file->image PSNR {p}");
    }

    #[test]
    fn truncated_file_errors() {
        let (bytes, ..) = encode_image(32, 32, Variant::Dct, 50);
        for cut in [3, Header::BYTES - 1, Header::BYTES + 4,
                    bytes.len() - 5] {
            assert!(
                decode(&bytes[..cut]).is_err(),
                "cut at {cut} should fail"
            );
        }
    }

    #[test]
    fn bitflip_fuzz_no_panics() {
        let (bytes, ..) = encode_image(32, 32, Variant::Dct, 50);
        let mut rng = Rng::new(33);
        for _ in 0..300 {
            let mut corrupt = bytes.clone();
            let n_flips = rng.range_i64(1, 8) as usize;
            for _ in 0..n_flips {
                let i = rng.below(corrupt.len() as u64) as usize;
                corrupt[i] ^= 1 << rng.below(8);
            }
            // must not panic; Ok (flip in padding) or Err both fine
            let _ = decode(&corrupt);
        }
    }

    #[test]
    fn oversized_header_rejected() {
        let mut buf = Vec::new();
        Header {
            width: 60_000,
            height: 60_000,
            padded_width: 60_000,
            padded_height: 60_000,
            quality: 50,
            variant: 0,
        }
        .write(&mut buf);
        buf.extend_from_slice(&[0u8; 64]);
        // rejected either for size or for non-8-aligned padding
        match decode(&buf) {
            Ok(_) => panic!("oversized header must be rejected"),
            Err(err) => assert!(!err.to_string().is_empty()),
        }
    }

    fn encode_image_v2(
        w: usize,
        h: usize,
        interval: u16,
    ) -> (Vec<u8>, Vec<f32>) {
        let img = synthetic::lena_like(w, h, 7);
        let pipe = CpuPipeline::new(Variant::Cordic, 50);
        let (qcoef, pw, ph) = pipe.analyze(&img);
        let header = Header {
            width: w as u32,
            height: h as u32,
            padded_width: pw as u32,
            padded_height: ph as u32,
            quality: 50,
            variant: variant_tag(Variant::Cordic),
        };
        let bytes =
            encoder::encode_v2(&header, &qcoef, interval).unwrap();
        (bytes, qcoef)
    }

    #[test]
    fn v2_strict_roundtrip_across_intervals() {
        for interval in [0u16, 1, 2, 4, 7, 100] {
            let (bytes, qcoef) = encode_image_v2(64, 48, interval);
            let dec = decode(&bytes).unwrap();
            assert_eq!(dec.qcoef_planar, qcoef, "interval {interval}");
            let (dec2, report) = decode_salvage(&bytes).unwrap();
            assert_eq!(dec2.qcoef_planar, qcoef);
            assert!(report.is_clean(), "{report:?}");
        }
    }

    #[test]
    fn v2_interval_zero_single_segment() {
        let (bytes, _) = encode_image_v2(64, 64, 0);
        let (_, report) = decode_salvage(&bytes).unwrap();
        assert_eq!(report.segments_total, 1);
    }

    #[test]
    fn v2_strict_rejects_payload_flip_salvage_conceals() {
        let (bytes, qcoef) = encode_image_v2(64, 64, 1);
        // flip one bit well inside the last quarter (segment region)
        let mut corrupt = bytes.clone();
        let pos = corrupt.len() - corrupt.len() / 4;
        corrupt[pos] ^= 0x10;
        assert!(decode(&corrupt).is_err(), "strict must reject the flip");
        let (dec, report) = decode_salvage(&corrupt).unwrap();
        assert_eq!(report.segments_damaged, 1, "{report:?}");
        assert_eq!(report.segments_concealed, 1);
        assert!(report.bytes_skipped > 0);
        // intact rows decode bit-identically
        assert_eq!(dec.qcoef_planar.len(), qcoef.len());
        let pw = 64;
        let damaged_rows: Vec<usize> = (0..8)
            .filter(|&by| {
                dec.qcoef_planar[by * 8 * pw..(by + 1) * 8 * pw]
                    != qcoef[by * 8 * pw..(by + 1) * 8 * pw]
            })
            .collect();
        assert!(
            damaged_rows.len() <= 1,
            "one damaged segment must cost at most one band: \
             {damaged_rows:?}"
        );
    }

    #[test]
    fn v1_salvage_reports_single_clean_segment() {
        let (bytes, qcoef, ..) = encode_image(48, 48, Variant::Dct, 50);
        let (dec, report) = decode_salvage(&bytes).unwrap();
        assert_eq!(dec.qcoef_planar, qcoef);
        assert_eq!(report.segments_total, 1);
        assert!(report.is_clean());
        assert_eq!(report.per_plane.len(), 1);
    }

    #[test]
    fn v2_salvage_survives_any_single_payload_flip() {
        let (bytes, _) = encode_image_v2(48, 48, 1);
        // parse the head structure to find where the segments begin
        let seg_count = u32::from_le_bytes(
            bytes[Header::BYTES + 2..Header::BYTES + 6]
                .try_into()
                .unwrap(),
        ) as usize;
        let mut head_end = Header::BYTES + 6;
        for _ in 0..2 {
            let (_, used) =
                HuffmanCode::read_table(&bytes[head_end..]).unwrap();
            head_end += used;
        }
        head_end += seg_count * 4 + 4;
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            let mut corrupt = bytes.clone();
            let i = head_end
                + rng.below((corrupt.len() - head_end) as u64) as usize;
            corrupt[i] ^= 1 << rng.below(8);
            let (_, report) = decode_salvage(&corrupt)
                .expect("payload flip must salvage");
            assert!(
                report.segments_damaged >= 1,
                "flip at {i} reported clean"
            );
            assert_eq!(
                report.segments_concealed, report.segments_damaged,
                "with intact neighbours every damaged segment conceals"
            );
        }
    }
}
