//! Container decoder: header + Huffman tables + entropy-coded blocks back
//! to planar quantized coefficients. Strictly validating — corrupt input
//! must produce an `Err`, never a panic or OOM.

use anyhow::{Context, Result};

use crate::dct::blocks::{grid_dims, store_coef_planar};
use crate::util::bitio::BitReader;

use super::huffman::{HuffmanCode, HuffmanDecoder};
use super::rle::read_block;
use super::zigzag::unscan;
use super::{decode_bail, DecodeErrorKind, Header, MAX_PIXELS};

/// Decoded container: header + planar coefficients (padded layout).
pub struct Decoded {
    pub header: Header,
    pub qcoef_planar: Vec<f32>,
}

pub fn decode(bytes: &[u8]) -> Result<Decoded> {
    let (header, mut off) = Header::read(bytes)?;
    let pw = header.padded_width as u64;
    let ph = header.padded_height as u64;
    // Defense in depth: Header::read already caps this, but the
    // allocation below must never trust anything it did not check.
    if pw * ph > MAX_PIXELS {
        decode_bail!(
            DecodeErrorKind::TooLarge,
            "image too large: {pw}x{ph}"
        );
    }
    let (dc_code, used) = HuffmanCode::read_table(&bytes[off..])
        .context("[decode:corrupt] DC Huffman table")?;
    off += used;
    let (ac_code, used) = HuffmanCode::read_table(&bytes[off..])
        .context("[decode:corrupt] AC Huffman table")?;
    off += used;
    if bytes.len() < off + 4 {
        decode_bail!(DecodeErrorKind::Truncated, "truncated payload length");
    }
    let payload_len = u32::from_le_bytes([
        bytes[off],
        bytes[off + 1],
        bytes[off + 2],
        bytes[off + 3],
    ]) as usize;
    off += 4;
    if bytes.len() < off + payload_len {
        decode_bail!(
            DecodeErrorKind::Truncated,
            "payload truncated: header says {payload_len}, {} available",
            bytes.len() - off
        );
    }
    let payload = &bytes[off..off + payload_len];

    let dc_dec = HuffmanDecoder::new(&dc_code);
    let ac_dec = HuffmanDecoder::new(&ac_code);
    let (gw, gh) = grid_dims(pw as usize, ph as usize);
    let mut qcoef = vec![0.0f32; (pw * ph) as usize];
    let mut r = BitReader::new(payload);
    let mut prev_dc: i16 = 0;
    for by in 0..gh {
        for bx in 0..gw {
            let z = read_block(
                &mut r,
                prev_dc,
                |r| dc_dec.get(r),
                |r| ac_dec.get(r),
            )
            .with_context(|| {
                format!("[decode:corrupt] entropy block ({bx},{by})")
            })?;
            prev_dc = z[0];
            let block = unscan(&z);
            store_coef_planar(&mut qcoef, pw as usize, bx, by, &block);
        }
    }
    Ok(Decoded {
        header,
        qcoef_planar: qcoef,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{encoder, variant_tag};
    use crate::dct::pipeline::CpuPipeline;
    use crate::dct::Variant;
    use crate::image::synthetic;
    use crate::metrics::psnr;
    use crate::util::prng::Rng;

    fn encode_image(
        w: usize,
        h: usize,
        variant: Variant,
        quality: u8,
    ) -> (Vec<u8>, Vec<f32>, usize, usize) {
        let img = synthetic::lena_like(w, h, 7);
        let pipe = CpuPipeline::new(variant, quality);
        let (qcoef, pw, ph) = pipe.analyze(&img);
        let header = Header {
            width: w as u32,
            height: h as u32,
            padded_width: pw as u32,
            padded_height: ph as u32,
            quality,
            variant: variant_tag(variant),
        };
        (encoder::encode(&header, &qcoef).unwrap(), qcoef, pw, ph)
    }

    #[test]
    fn roundtrip_exact_coefficients() {
        let (bytes, qcoef, _pw, _ph) =
            encode_image(64, 48, Variant::Dct, 50);
        let dec = decode(&bytes).unwrap();
        assert_eq!(dec.qcoef_planar, qcoef);
        assert_eq!(dec.header.width, 64);
        assert_eq!(dec.header.quality, 50);
    }

    #[test]
    fn roundtrip_unaligned_size() {
        let (bytes, qcoef, pw, ph) =
            encode_image(30, 21, Variant::Cordic, 75);
        let dec = decode(&bytes).unwrap();
        assert_eq!((pw, ph), (32, 24));
        assert_eq!(dec.qcoef_planar, qcoef);
    }

    #[test]
    fn full_file_to_image_pipeline() {
        let img = synthetic::cablecar_like(96, 80, 3);
        let pipe = CpuPipeline::new(Variant::Dct, 50);
        let (qcoef, pw, ph) = pipe.analyze(&img);
        let header = Header {
            width: 96,
            height: 80,
            padded_width: pw as u32,
            padded_height: ph as u32,
            quality: 50,
            variant: variant_tag(Variant::Dct),
        };
        let bytes = encoder::encode(&header, &qcoef).unwrap();
        let dec = decode(&bytes).unwrap();
        let recon = pipe.decode_coefficients(
            &dec.qcoef_planar,
            pw,
            ph,
            96,
            80,
        );
        let p = psnr(&img, &recon);
        assert!(p > 30.0, "file->image PSNR {p}");
    }

    #[test]
    fn truncated_file_errors() {
        let (bytes, ..) = encode_image(32, 32, Variant::Dct, 50);
        for cut in [3, Header::BYTES - 1, Header::BYTES + 4,
                    bytes.len() - 5] {
            assert!(
                decode(&bytes[..cut]).is_err(),
                "cut at {cut} should fail"
            );
        }
    }

    #[test]
    fn bitflip_fuzz_no_panics() {
        let (bytes, ..) = encode_image(32, 32, Variant::Dct, 50);
        let mut rng = Rng::new(33);
        for _ in 0..300 {
            let mut corrupt = bytes.clone();
            let n_flips = rng.range_i64(1, 8) as usize;
            for _ in 0..n_flips {
                let i = rng.below(corrupt.len() as u64) as usize;
                corrupt[i] ^= 1 << rng.below(8);
            }
            // must not panic; Ok (flip in padding) or Err both fine
            let _ = decode(&corrupt);
        }
    }

    #[test]
    fn oversized_header_rejected() {
        let mut buf = Vec::new();
        Header {
            width: 60_000,
            height: 60_000,
            padded_width: 60_000,
            padded_height: 60_000,
            quality: 50,
            variant: 0,
        }
        .write(&mut buf);
        buf.extend_from_slice(&[0u8; 64]);
        // rejected either for size or for non-8-aligned padding
        match decode(&buf) {
            Ok(_) => panic!("oversized header must be rejected"),
            Err(err) => assert!(!err.to_string().is_empty()),
        }
    }
}
