//! Zigzag scan order: maps the 8x8 block to a 1-D sequence ordered by
//! ascending spatial frequency, so quantized ACs end in long zero runs.

/// zigzag[i] = row-major index of the i-th coefficient in scan order.
pub const ZIGZAG: [usize; 64] = build_zigzag();

/// inverse: INV_ZIGZAG[row_major] = scan position.
pub const INV_ZIGZAG: [usize; 64] = invert(&ZIGZAG);

const fn build_zigzag() -> [usize; 64] {
    let mut out = [0usize; 64];
    let mut i = 0usize;
    let mut d = 0usize; // anti-diagonal index r+c
    while d < 15 {
        // even diagonals run bottom-left -> top-right, odd the reverse
        if d % 2 == 0 {
            let mut r = if d < 8 { d } else { 7 };
            loop {
                let c = d - r;
                if c < 8 {
                    out[i] = r * 8 + c;
                    i += 1;
                }
                if r == 0 {
                    break;
                }
                r -= 1;
            }
        } else {
            let mut c = if d < 8 { d } else { 7 };
            loop {
                let r = d - c;
                if r < 8 {
                    out[i] = r * 8 + c;
                    i += 1;
                }
                if c == 0 {
                    break;
                }
                c -= 1;
            }
        }
        d += 1;
    }
    out
}

const fn invert(z: &[usize; 64]) -> [usize; 64] {
    let mut inv = [0usize; 64];
    let mut i = 0;
    while i < 64 {
        inv[z[i]] = i;
        i += 1;
    }
    inv
}

/// Scatter a row-major block into scan order.
pub fn scan(block: &[i16; 64]) -> [i16; 64] {
    std::array::from_fn(|i| block[ZIGZAG[i]])
}

/// Gather a scan-ordered sequence back to row-major.
pub fn unscan(seq: &[i16; 64]) -> [i16; 64] {
    let mut out = [0i16; 64];
    for (i, &v) in seq.iter().enumerate() {
        out[ZIGZAG[i]] = v;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn is_permutation() {
        let mut seen = [false; 64];
        for &z in &ZIGZAG {
            assert!(!seen[z], "duplicate {z}");
            seen[z] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn matches_jpeg_prefix() {
        // the canonical JPEG zigzag head: 0, 1, 8, 16, 9, 2, 3, 10 ...
        assert_eq!(
            &ZIGZAG[..10],
            &[0, 1, 8, 16, 9, 2, 3, 10, 17, 24]
        );
        // and tail ends at the bottom-right corner
        assert_eq!(ZIGZAG[63], 63);
    }

    #[test]
    fn inverse_consistent() {
        for i in 0..64 {
            assert_eq!(INV_ZIGZAG[ZIGZAG[i]], i);
        }
    }

    #[test]
    fn scan_unscan_roundtrip() {
        let block: [i16; 64] = std::array::from_fn(|i| (i as i16) * 3 - 50);
        assert_eq!(unscan(&scan(&block)), block);
    }

    #[test]
    fn frequency_ordering_property() {
        // scan position should (weakly) order by r+c: position of any
        // coefficient on diagonal d is before all on diagonal d+2
        for i in 0..64 {
            for j in 0..64 {
                let (ri, ci) = (ZIGZAG[i] / 8, ZIGZAG[i] % 8);
                let (rj, cj) = (ZIGZAG[j] / 8, ZIGZAG[j] % 8);
                if ri + ci + 2 <= rj + cj {
                    assert!(i < j, "diag order violated: {i} vs {j}");
                }
            }
        }
    }
}
