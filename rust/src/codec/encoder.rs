//! Two-pass entropy encoder: statistics pass builds per-image DC/AC
//! Huffman tables, coding pass emits the container.
//!
//! Two front doors feed the same coding core:
//!
//! * [`encode`] — the planar-f32 interchange path (what the PJRT
//!   artifacts emit): gathers each block out of the image-layout buffer
//!   and zigzag-scans it.
//! * [`encode_scanned`] — the fused path: consumes [`ScanCoefs`], the
//!   already-zigzag-ordered `i16` output of
//!   `dct::batch::quantize_zigzag_batch`, skipping the f32 planar
//!   round-trip entirely. Byte-identical output to [`encode`] on the
//!   same coefficients.

use anyhow::Result;

use crate::dct::blocks::{grid_dims, load_coef_planar, BLOCK};
use crate::util::bitio::BitWriter;

use super::huffman::HuffmanCode;
use super::rle::{encode_block, write_block, BlockSymbols};
use super::zigzag::scan;
use super::{Header, SEG_MARKER, SEG_MARKER_BASE};

/// Quantized coefficients in entropy-coding order: one 64-entry zigzag
/// scan per 8x8 block, blocks in raster order over the padded grid —
/// exactly what `dct::batch::quantize_zigzag_batch` emits, so the encoder
/// can consume the quantizer output without the f32 planar interchange
/// round-trip.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScanCoefs {
    /// Pre-padding image (or plane) size.
    pub width: usize,
    pub height: usize,
    /// Padded (8-aligned) size the block grid uses.
    pub padded_width: usize,
    pub padded_height: usize,
    /// `grid_w * grid_h * 64` coefficients, zigzag order within a block.
    pub data: Vec<i16>,
}

impl ScanCoefs {
    /// Empty buffer for a plane of the given pre-padding size.
    pub fn zeroed(width: usize, height: usize, pw: usize, ph: usize)
                  -> ScanCoefs {
        debug_assert!(pw % BLOCK == 0 && ph % BLOCK == 0);
        ScanCoefs {
            width,
            height,
            padded_width: pw,
            padded_height: ph,
            data: vec![0i16; pw * ph],
        }
    }

    /// Re-shape an existing buffer for a new plane, reusing the
    /// allocation whenever capacity allows — the zero-alloc steady-state
    /// path [`crate::dct::pipeline::CpuPipeline::analyze_scanned_into`]
    /// runs on.
    pub fn reset(&mut self, width: usize, height: usize,
                 pw: usize, ph: usize) {
        debug_assert!(pw % BLOCK == 0 && ph % BLOCK == 0);
        self.width = width;
        self.height = height;
        self.padded_width = pw;
        self.padded_height = ph;
        self.data.clear();
        self.data.resize(pw * ph, 0);
    }

    /// Number of 8x8 blocks.
    pub fn blocks(&self) -> usize {
        self.data.len() / 64
    }

    /// The zigzag scan of block index `b` (raster order).
    #[inline]
    pub fn block(&self, b: usize) -> &[i16] {
        &self.data[b * 64..(b + 1) * 64]
    }

    /// Convert from the planar-f32 interchange layout (the PJRT artifact
    /// output) — the compatibility shim for backends that do not emit
    /// fused zigzag coefficients.
    pub fn from_planar(
        qcoef_planar: &[f32],
        pw: usize,
        ph: usize,
        width: usize,
        height: usize,
    ) -> ScanCoefs {
        assert_eq!(qcoef_planar.len(), pw * ph, "coefficient buffer size");
        let (gw, gh) = grid_dims(pw, ph);
        let mut out = ScanCoefs::zeroed(width, height, pw, ph);
        let mut qc = [0i16; 64];
        for by in 0..gh {
            for bx in 0..gw {
                load_coef_planar(qcoef_planar, pw, bx, by, &mut qc);
                let z = scan(&qc);
                let base = (by * gw + bx) * 64;
                out.data[base..base + 64].copy_from_slice(&z);
            }
        }
        out
    }
}

/// Encode planar quantized coefficients (padded size) into a `.cdc` file.
pub fn encode(
    header: &Header,
    qcoef_planar: &[f32],
) -> Result<Vec<u8>> {
    let (pw, ph) = (
        header.padded_width as usize,
        header.padded_height as usize,
    );
    assert_eq!(qcoef_planar.len(), pw * ph, "coefficient buffer size");
    let (gw, gh) = grid_dims(pw, ph);
    let mut qc = [0i16; 64];
    encode_scans(
        header,
        gw * gh,
        (0..gh).flat_map(|by| (0..gw).map(move |bx| (bx, by))),
        |(bx, by)| {
            load_coef_planar(qcoef_planar, pw, bx, by, &mut qc);
            scan(&qc)
        },
    )
}

/// Encode already-zigzag-ordered coefficients (the fused
/// `quantize_zigzag_batch` output) into a `.cdc` file. Byte-identical to
/// [`encode`] over the equivalent planar buffer — same symbols, same
/// per-image Huffman tables, same bitstream.
pub fn encode_scanned(header: &Header, scans: &ScanCoefs) -> Result<Vec<u8>> {
    let (pw, ph) = (
        header.padded_width as usize,
        header.padded_height as usize,
    );
    assert_eq!(
        (scans.padded_width, scans.padded_height),
        (pw, ph),
        "scanned buffer padded size disagrees with header"
    );
    assert_eq!(scans.data.len(), pw * ph, "scanned buffer size");
    encode_scans(header, scans.blocks(), 0..scans.blocks(), |b| {
        scans.block(b).try_into().expect("64-coefficient block")
    })
}

/// The shared coding core: statistics pass over block scans, per-image
/// Huffman tables, then the container emit pass.
fn encode_scans<T>(
    header: &Header,
    nblocks: usize,
    order: impl Iterator<Item = T>,
    mut scan_of: impl FnMut(T) -> [i16; 64],
) -> Result<Vec<u8>> {
    // pass 1: symbols + statistics
    let mut dc_freq = [0u64; 256];
    let mut ac_freq = [0u64; 256];
    let mut blocks: Vec<BlockSymbols> = Vec::with_capacity(nblocks);
    let mut prev_dc: i16 = 0;
    for item in order {
        let z = scan_of(item);
        let sym = encode_block(&z, prev_dc);
        prev_dc = z[0];
        dc_freq[sym.dc.0 as usize] += 1;
        for &(s, _) in &sym.ac {
            ac_freq[s as usize] += 1;
        }
        blocks.push(sym);
    }
    // Blocks with no AC symbols at all are possible (all-zero AC with the
    // final block fully coded): ensure the AC alphabet is non-empty so the
    // table builds.
    if ac_freq.iter().all(|&f| f == 0) {
        ac_freq[super::rle::EOB as usize] = 1;
    }

    let dc_code = HuffmanCode::build(&dc_freq)?;
    let ac_code = HuffmanCode::build(&ac_freq)?;

    // pass 2: emit container
    let mut out = Vec::new();
    header.write(&mut out);
    dc_code.write_table(&mut out);
    ac_code.write_table(&mut out);
    let mut w = BitWriter::new();
    for sym in &blocks {
        write_block(
            &mut w,
            sym,
            |w, s| dc_code.put(w, s),
            |w, s| ac_code.put(w, s),
        );
    }
    let payload = w.finish();
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Block rows per segment for a grid of `gh` block rows: interval 0
/// degenerates to one segment covering the whole image.
pub(super) fn rows_per_segment(interval: u16, gh: usize) -> usize {
    if interval == 0 {
        gh.max(1)
    } else {
        interval as usize
    }
}

/// Segment count for a grid of `gh` block rows at `interval`.
pub(super) fn segment_count(interval: u16, gh: usize) -> usize {
    gh.max(1).div_ceil(rows_per_segment(interval, gh))
}

/// Encode planar quantized coefficients into a v2 (`CDC2`) container
/// with restart segments of `restart_interval` block rows (0 = one
/// segment for the whole image).
pub fn encode_v2(
    header: &Header,
    qcoef_planar: &[f32],
    restart_interval: u16,
) -> Result<Vec<u8>> {
    let (pw, ph) = (
        header.padded_width as usize,
        header.padded_height as usize,
    );
    assert_eq!(qcoef_planar.len(), pw * ph, "coefficient buffer size");
    let (gw, gh) = grid_dims(pw, ph);
    let mut qc = [0i16; 64];
    encode_scans_v2(
        header,
        (gw, gh),
        restart_interval,
        (0..gh).flat_map(|by| (0..gw).map(move |bx| (bx, by))),
        |(bx, by)| {
            load_coef_planar(qcoef_planar, pw, bx, by, &mut qc);
            scan(&qc)
        },
    )
}

/// Encode already-zigzag-ordered coefficients into a v2 (`CDC2`)
/// container. Byte-identical to [`encode_v2`] over the equivalent
/// planar buffer.
pub fn encode_scanned_v2(
    header: &Header,
    scans: &ScanCoefs,
    restart_interval: u16,
) -> Result<Vec<u8>> {
    let (pw, ph) = (
        header.padded_width as usize,
        header.padded_height as usize,
    );
    assert_eq!(
        (scans.padded_width, scans.padded_height),
        (pw, ph),
        "scanned buffer padded size disagrees with header"
    );
    assert_eq!(scans.data.len(), pw * ph, "scanned buffer size");
    let (gw, gh) = grid_dims(pw, ph);
    encode_scans_v2(
        header,
        (gw, gh),
        restart_interval,
        0..scans.blocks(),
        |b| scans.block(b).try_into().expect("64-coefficient block"),
    )
}

/// The v2 coding core: global statistics (DC predictor reset at every
/// segment start, so the symbol stream matches what each segment's
/// independent decode will see), shared per-image Huffman tables in a
/// crc32-protected head with a segment-length index, then one
/// byte-aligned, individually checksummed bitstream per segment.
fn encode_scans_v2<T>(
    header: &Header,
    (gw, gh): (usize, usize),
    restart_interval: u16,
    order: impl Iterator<Item = T>,
    mut scan_of: impl FnMut(T) -> [i16; 64],
) -> Result<Vec<u8>> {
    let rows_per_seg = rows_per_segment(restart_interval, gh);
    let seg_count = segment_count(restart_interval, gh);
    // pass 1: symbols + statistics, DC DPCM restarting per segment
    let mut dc_freq = [0u64; 256];
    let mut ac_freq = [0u64; 256];
    let mut blocks: Vec<BlockSymbols> = Vec::with_capacity(gw * gh);
    let mut prev_dc: i16 = 0;
    for (idx, item) in order.enumerate() {
        if idx % gw == 0 && (idx / gw) % rows_per_seg == 0 {
            prev_dc = 0;
        }
        let z = scan_of(item);
        let sym = encode_block(&z, prev_dc);
        prev_dc = z[0];
        dc_freq[sym.dc.0 as usize] += 1;
        for &(s, _) in &sym.ac {
            ac_freq[s as usize] += 1;
        }
        blocks.push(sym);
    }
    if ac_freq.iter().all(|&f| f == 0) {
        ac_freq[super::rle::EOB as usize] = 1;
    }
    let dc_code = HuffmanCode::build(&dc_freq)?;
    let ac_code = HuffmanCode::build(&ac_freq)?;

    // pass 2: one independent, byte-aligned bitstream per segment
    let mut payloads: Vec<Vec<u8>> = Vec::with_capacity(seg_count);
    for s in 0..seg_count {
        let r0 = s * rows_per_seg;
        let r1 = (r0 + rows_per_seg).min(gh);
        let mut w = BitWriter::new();
        for sym in &blocks[r0 * gw..r1 * gw] {
            write_block(
                &mut w,
                sym,
                |w, s| dc_code.put(w, s),
                |w, s| ac_code.put(w, s),
            );
        }
        payloads.push(w.finish());
    }

    // head: header fields + interval + count + tables + length index,
    // sealed by a crc32 so salvage can trust the index
    let mut out = Vec::new();
    header.write_v2(&mut out);
    out.extend_from_slice(&restart_interval.to_le_bytes());
    out.extend_from_slice(&(seg_count as u32).to_le_bytes());
    dc_code.write_table(&mut out);
    ac_code.write_table(&mut out);
    for p in &payloads {
        out.extend_from_slice(&(p.len() as u32).to_le_bytes());
    }
    let head_crc = crc32fast::hash(&out);
    out.extend_from_slice(&head_crc.to_le_bytes());
    // segments: marker | coded length | payload crc | payload
    for (i, p) in payloads.iter().enumerate() {
        out.push(SEG_MARKER);
        out.push(SEG_MARKER_BASE + (i as u8 & 7));
        out.extend_from_slice(&(p.len() as u32).to_le_bytes());
        out.extend_from_slice(&crc32fast::hash(p).to_le_bytes());
        out.extend_from_slice(p);
    }
    Ok(out)
}

/// Entropy-coded size estimate (bits) without building the container —
/// used by the bitrate ablation.
pub fn estimate_bits(qcoef_planar: &[f32], pw: usize, ph: usize)
                     -> Result<u64> {
    let (gw, gh) = grid_dims(pw, ph);
    let mut dc_freq = [0u64; 256];
    let mut ac_freq = [0u64; 256];
    let mut extra_bits = 0u64;
    let mut prev_dc: i16 = 0;
    let mut qc = [0i16; 64];
    for by in 0..gh {
        for bx in 0..gw {
            load_coef_planar(qcoef_planar, pw, bx, by, &mut qc);
            let z = scan(&qc);
            let sym = encode_block(&z, prev_dc);
            prev_dc = z[0];
            dc_freq[sym.dc.0 as usize] += 1;
            extra_bits += sym.dc.0 as u64;
            for &(s, _) in &sym.ac {
                ac_freq[s as usize] += 1;
                extra_bits += (s & 0x0F) as u64;
            }
        }
    }
    if ac_freq.iter().all(|&f| f == 0) {
        ac_freq[super::rle::EOB as usize] = 1;
    }
    let dc_code = HuffmanCode::build(&dc_freq)?;
    let ac_code = HuffmanCode::build(&ac_freq)?;
    Ok(dc_code.total_bits(&dc_freq)
        + ac_code.total_bits(&ac_freq)
        + extra_bits)
}

/// Convenience: blocks count of a planar buffer.
pub fn block_count(pw: usize, ph: usize) -> usize {
    (pw / BLOCK) * (ph / BLOCK)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::variant_tag;
    use crate::dct::pipeline::CpuPipeline;
    use crate::dct::Variant;
    use crate::image::synthetic;

    fn make_header(w: usize, h: usize, pw: usize, ph: usize) -> Header {
        Header {
            width: w as u32,
            height: h as u32,
            padded_width: pw as u32,
            padded_height: ph as u32,
            quality: 50,
            variant: variant_tag(Variant::Dct),
        }
    }

    #[test]
    fn encodes_real_image() {
        let img = synthetic::lena_like(64, 64, 1);
        let pipe = CpuPipeline::new(Variant::Dct, 50);
        let (qcoef, pw, ph) = pipe.analyze(&img);
        let bytes =
            encode(&make_header(64, 64, pw, ph), &qcoef).unwrap();
        // compressed should be much smaller than raw
        assert!(bytes.len() < 64 * 64 / 2, "{} bytes", bytes.len());
        assert_eq!(&bytes[..4], super::super::MAGIC);
    }

    #[test]
    fn all_zero_coefficients_encode() {
        let qcoef = vec![0.0f32; 16 * 16];
        let bytes = encode(&make_header(16, 16, 16, 16), &qcoef).unwrap();
        assert!(bytes.len() < 120);
    }

    #[test]
    fn estimate_close_to_actual() {
        let img = synthetic::cablecar_like(96, 96, 2);
        let pipe = CpuPipeline::new(Variant::Dct, 50);
        let (qcoef, pw, ph) = pipe.analyze(&img);
        let bits = estimate_bits(&qcoef, pw, ph).unwrap();
        let actual =
            encode(&make_header(96, 96, pw, ph), &qcoef).unwrap();
        // actual = header + tables + payload; payload ~ bits/8
        let payload_bytes = bits as usize / 8;
        assert!(
            actual.len() >= payload_bytes,
            "{} vs {payload_bytes}",
            actual.len()
        );
        assert!(actual.len() < payload_bytes + 700);
    }

    #[test]
    fn scanned_path_byte_identical_to_planar_path() {
        // the fused-output front door must emit the exact same container
        for (w, h) in [(64, 64), (40, 21), (72, 8)] {
            let img = synthetic::lena_like(w, h, 9);
            let pipe = CpuPipeline::new(Variant::Cordic, 50);
            let (qcoef, pw, ph) = pipe.analyze(&img);
            let header = make_header(w, h, pw, ph);
            let via_planar = encode(&header, &qcoef).unwrap();
            let scans = ScanCoefs::from_planar(&qcoef, pw, ph, w, h);
            let via_scanned = encode_scanned(&header, &scans).unwrap();
            assert_eq!(via_planar, via_scanned, "{w}x{h}");
        }
    }

    #[test]
    fn scan_coefs_shape_helpers() {
        let s = ScanCoefs::zeroed(30, 21, 32, 24);
        assert_eq!(s.blocks(), 4 * 3);
        assert_eq!(s.block(11).len(), 64);
        assert_eq!(s.data.len(), 32 * 24);
    }

    #[test]
    fn lower_quality_fewer_bits() {
        let img = synthetic::lena_like(96, 96, 3);
        let hi = CpuPipeline::new(Variant::Dct, 90).analyze(&img);
        let lo = CpuPipeline::new(Variant::Dct, 10).analyze(&img);
        let bits_hi = estimate_bits(&hi.0, hi.1, hi.2).unwrap();
        let bits_lo = estimate_bits(&lo.0, lo.1, lo.2).unwrap();
        assert!(bits_lo < bits_hi, "{bits_lo} vs {bits_hi}");
    }
}
