//! Two-pass entropy encoder: statistics pass builds per-image DC/AC
//! Huffman tables, coding pass emits the container.

use anyhow::Result;

use crate::dct::blocks::{grid_dims, load_coef_planar, BLOCK};
use crate::util::bitio::BitWriter;

use super::huffman::HuffmanCode;
use super::rle::{encode_block, write_block, BlockSymbols};
use super::zigzag::scan;
use super::Header;

/// Encode planar quantized coefficients (padded size) into a `.cdc` file.
pub fn encode(
    header: &Header,
    qcoef_planar: &[f32],
) -> Result<Vec<u8>> {
    let (pw, ph) = (
        header.padded_width as usize,
        header.padded_height as usize,
    );
    assert_eq!(qcoef_planar.len(), pw * ph, "coefficient buffer size");
    let (gw, gh) = grid_dims(pw, ph);

    // pass 1: symbols + statistics
    let mut dc_freq = [0u64; 256];
    let mut ac_freq = [0u64; 256];
    let mut blocks: Vec<BlockSymbols> = Vec::with_capacity(gw * gh);
    let mut prev_dc: i16 = 0;
    let mut qc = [0i16; 64];
    for by in 0..gh {
        for bx in 0..gw {
            load_coef_planar(qcoef_planar, pw, bx, by, &mut qc);
            let z = scan(&qc);
            let sym = encode_block(&z, prev_dc);
            prev_dc = z[0];
            dc_freq[sym.dc.0 as usize] += 1;
            for &(s, _) in &sym.ac {
                ac_freq[s as usize] += 1;
            }
            blocks.push(sym);
        }
    }
    // Blocks with no AC symbols at all are possible (all-zero AC with the
    // final block fully coded): ensure the AC alphabet is non-empty so the
    // table builds.
    if ac_freq.iter().all(|&f| f == 0) {
        ac_freq[super::rle::EOB as usize] = 1;
    }

    let dc_code = HuffmanCode::build(&dc_freq)?;
    let ac_code = HuffmanCode::build(&ac_freq)?;

    // pass 2: emit container
    let mut out = Vec::new();
    header.write(&mut out);
    dc_code.write_table(&mut out);
    ac_code.write_table(&mut out);
    let mut w = BitWriter::new();
    for sym in &blocks {
        write_block(
            &mut w,
            sym,
            |w, s| dc_code.put(w, s),
            |w, s| ac_code.put(w, s),
        );
    }
    let payload = w.finish();
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Entropy-coded size estimate (bits) without building the container —
/// used by the bitrate ablation.
pub fn estimate_bits(qcoef_planar: &[f32], pw: usize, ph: usize)
                     -> Result<u64> {
    let (gw, gh) = grid_dims(pw, ph);
    let mut dc_freq = [0u64; 256];
    let mut ac_freq = [0u64; 256];
    let mut extra_bits = 0u64;
    let mut prev_dc: i16 = 0;
    let mut qc = [0i16; 64];
    for by in 0..gh {
        for bx in 0..gw {
            load_coef_planar(qcoef_planar, pw, bx, by, &mut qc);
            let z = scan(&qc);
            let sym = encode_block(&z, prev_dc);
            prev_dc = z[0];
            dc_freq[sym.dc.0 as usize] += 1;
            extra_bits += sym.dc.0 as u64;
            for &(s, _) in &sym.ac {
                ac_freq[s as usize] += 1;
                extra_bits += (s & 0x0F) as u64;
            }
        }
    }
    if ac_freq.iter().all(|&f| f == 0) {
        ac_freq[super::rle::EOB as usize] = 1;
    }
    let dc_code = HuffmanCode::build(&dc_freq)?;
    let ac_code = HuffmanCode::build(&ac_freq)?;
    Ok(dc_code.total_bits(&dc_freq)
        + ac_code.total_bits(&ac_freq)
        + extra_bits)
}

/// Convenience: blocks count of a planar buffer.
pub fn block_count(pw: usize, ph: usize) -> usize {
    (pw / BLOCK) * (ph / BLOCK)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::variant_tag;
    use crate::dct::pipeline::CpuPipeline;
    use crate::dct::Variant;
    use crate::image::synthetic;

    fn make_header(w: usize, h: usize, pw: usize, ph: usize) -> Header {
        Header {
            width: w as u32,
            height: h as u32,
            padded_width: pw as u32,
            padded_height: ph as u32,
            quality: 50,
            variant: variant_tag(Variant::Dct),
        }
    }

    #[test]
    fn encodes_real_image() {
        let img = synthetic::lena_like(64, 64, 1);
        let pipe = CpuPipeline::new(Variant::Dct, 50);
        let (qcoef, pw, ph) = pipe.analyze(&img);
        let bytes =
            encode(&make_header(64, 64, pw, ph), &qcoef).unwrap();
        // compressed should be much smaller than raw
        assert!(bytes.len() < 64 * 64 / 2, "{} bytes", bytes.len());
        assert_eq!(&bytes[..4], super::super::MAGIC);
    }

    #[test]
    fn all_zero_coefficients_encode() {
        let qcoef = vec![0.0f32; 16 * 16];
        let bytes = encode(&make_header(16, 16, 16, 16), &qcoef).unwrap();
        assert!(bytes.len() < 120);
    }

    #[test]
    fn estimate_close_to_actual() {
        let img = synthetic::cablecar_like(96, 96, 2);
        let pipe = CpuPipeline::new(Variant::Dct, 50);
        let (qcoef, pw, ph) = pipe.analyze(&img);
        let bits = estimate_bits(&qcoef, pw, ph).unwrap();
        let actual =
            encode(&make_header(96, 96, pw, ph), &qcoef).unwrap();
        // actual = header + tables + payload; payload ~ bits/8
        let payload_bytes = bits as usize / 8;
        assert!(
            actual.len() >= payload_bytes,
            "{} vs {payload_bytes}",
            actual.len()
        );
        assert!(actual.len() < payload_bytes + 700);
    }

    #[test]
    fn lower_quality_fewer_bits() {
        let img = synthetic::lena_like(96, 96, 3);
        let hi = CpuPipeline::new(Variant::Dct, 90).analyze(&img);
        let lo = CpuPipeline::new(Variant::Dct, 10).analyze(&img);
        let bits_hi = estimate_bits(&hi.0, hi.1, hi.2).unwrap();
        let bits_lo = estimate_bits(&lo.0, lo.1, lo.2).unwrap();
        assert!(bits_lo < bits_hi, "{bits_lo} vs {bits_hi}");
    }
}
