//! Canonical Huffman coding over byte symbols (0..=255).
//!
//! Built per image from symbol frequencies (package-merge-free: standard
//! heap construction with a JPEG-style 16-bit length cap via length
//! rebalancing), serialized as canonical descriptors (length counts +
//! symbols in canonical order) so the decoder reconstructs codes exactly.

use anyhow::{bail, Result};

use super::{decode_bail, DecodeErrorKind};
use crate::util::bitio::{BitReader, BitWriter};

pub const MAX_LEN: usize = 16;

/// A built Huffman code: per-symbol (code, length).
#[derive(Clone, Debug)]
pub struct HuffmanCode {
    code: [u32; 256],
    len: [u8; 256],
    /// canonical descriptor: count of codes of each length 1..=16
    pub counts: [u8; MAX_LEN],
    /// symbols in canonical order
    pub symbols: Vec<u8>,
}

impl HuffmanCode {
    /// Build from frequencies. Symbols with zero frequency get no code.
    /// At least one symbol must be present; a single-symbol alphabet gets
    /// a 1-bit code (JPEG convention).
    pub fn build(freq: &[u64; 256]) -> Result<HuffmanCode> {
        let mut lens = assign_lengths(freq)?;
        cap_lengths(&mut lens, freq);
        Self::from_lengths(&lens)
    }

    /// Construct the canonical code from per-symbol lengths.
    pub fn from_lengths(lens: &[u8; 256]) -> Result<HuffmanCode> {
        let mut counts = [0u8; MAX_LEN];
        let mut symbols: Vec<u8> = (0u16..256)
            .filter(|&s| lens[s as usize] > 0)
            .map(|s| s as u8)
            .collect();
        if symbols.is_empty() {
            bail!("empty Huffman alphabet");
        }
        // canonical order: by length then symbol value
        symbols.sort_by_key(|&s| (lens[s as usize], s));
        for &s in &symbols {
            let l = lens[s as usize] as usize;
            if l > MAX_LEN {
                bail!("code length {l} exceeds cap");
            }
            counts[l - 1] += 1;
        }
        // assign canonical codes
        let mut code = [0u32; 256];
        let mut len = [0u8; 256];
        let mut next: u32 = 0;
        let mut prev_len = 0usize;
        for &s in &symbols {
            let l = lens[s as usize] as usize;
            next <<= l - prev_len;
            code[s as usize] = next;
            len[s as usize] = l as u8;
            next += 1;
            prev_len = l;
        }
        // Kraft check
        let kraft: u64 = symbols
            .iter()
            .map(|&s| 1u64 << (MAX_LEN - lens[s as usize] as usize))
            .sum();
        if kraft > 1 << MAX_LEN {
            bail!("invalid code: Kraft sum exceeded");
        }
        Ok(HuffmanCode {
            code,
            len,
            counts,
            symbols,
        })
    }

    /// Encode one symbol.
    #[inline]
    pub fn put(&self, w: &mut BitWriter, sym: u8) {
        let l = self.len[sym as usize];
        debug_assert!(l > 0, "symbol {sym} has no code");
        w.put(self.code[sym as usize] as u64, l as u32);
    }

    pub fn code_len(&self, sym: u8) -> u8 {
        self.len[sym as usize]
    }

    /// Total encoded bits for a frequency table (cost model for tests).
    pub fn total_bits(&self, freq: &[u64; 256]) -> u64 {
        freq.iter()
            .enumerate()
            .map(|(s, &f)| f * self.len[s] as u64)
            .sum()
    }

    /// Serialize the canonical descriptor (17..273 bytes).
    pub fn write_table(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.counts);
        out.extend_from_slice(&self.symbols);
    }

    /// Parse a canonical descriptor; returns (code, bytes consumed).
    /// Failures carry a `[decode:*]` tag: a table cut short classifies
    /// as `Truncated`, an internally invalid one as `Corrupt` — the
    /// distinction the serve layer's error frames rely on.
    pub fn read_table(bytes: &[u8]) -> Result<(HuffmanCode, usize)> {
        if bytes.len() < MAX_LEN {
            decode_bail!(
                DecodeErrorKind::Truncated,
                "truncated Huffman table"
            );
        }
        let mut counts = [0u8; MAX_LEN];
        counts.copy_from_slice(&bytes[..MAX_LEN]);
        let nsym: usize = counts.iter().map(|&c| c as usize).sum();
        if nsym == 0 {
            decode_bail!(
                DecodeErrorKind::Corrupt,
                "empty Huffman table"
            );
        }
        if bytes.len() < MAX_LEN + nsym {
            decode_bail!(
                DecodeErrorKind::Truncated,
                "truncated Huffman symbol list ({nsym} symbols)"
            );
        }
        let symbols = bytes[MAX_LEN..MAX_LEN + nsym].to_vec();
        let mut lens = [0u8; 256];
        let mut idx = 0usize;
        for (li, &c) in counts.iter().enumerate() {
            for _ in 0..c {
                let s = symbols[idx] as usize;
                if lens[s] != 0 {
                    decode_bail!(
                        DecodeErrorKind::Corrupt,
                        "duplicate symbol {s} in Huffman table"
                    );
                }
                lens[s] = (li + 1) as u8;
                idx += 1;
            }
        }
        let code = Self::from_lengths(&lens).map_err(|e| {
            super::DecodeError::new(
                DecodeErrorKind::Corrupt,
                format!("invalid Huffman table: {e}"),
            )
        })?;
        Ok((code, MAX_LEN + nsym))
    }
}

/// Bit width of the decoder's first-level lookup table. Real DC/AC
/// symbol distributions put the overwhelming majority of decoded symbols
/// at <= 8 bits, so almost every symbol resolves in one table probe.
const LUT_BITS: u32 = 8;

/// Canonical decoder: a first-level `2^LUT_BITS`-entry lookup table
/// resolves all codes of <= `LUT_BITS` bits in a single peek+consume;
/// longer codes fall back to the length-indexed first-code walk (JPEG's
/// MINCODE / MAXCODE scheme). The 256-entry table is 512 bytes — built
/// once per table, no per-symbol bit loop on the hot path.
#[derive(Clone, Debug)]
pub struct HuffmanDecoder {
    /// `lut[prefix] = (symbol, code_len)`; `code_len == 0` marks a prefix
    /// whose code is longer than `LUT_BITS` (take the slow path).
    lut: [(u8, u8); 1 << LUT_BITS],
    min_code: [u32; MAX_LEN + 1],
    max_code: [i64; MAX_LEN + 1], // -1 when no codes of that length
    val_ptr: [usize; MAX_LEN + 1],
    symbols: Vec<u8>,
}

impl HuffmanDecoder {
    pub fn new(code: &HuffmanCode) -> HuffmanDecoder {
        let mut lut = [(0u8, 0u8); 1 << LUT_BITS];
        let mut min_code = [0u32; MAX_LEN + 1];
        let mut max_code = [-1i64; MAX_LEN + 1];
        let mut val_ptr = [0usize; MAX_LEN + 1];
        let mut next: u32 = 0;
        let mut idx = 0usize;
        for l in 1..=MAX_LEN {
            let c = code.counts[l - 1] as usize;
            if c > 0 {
                val_ptr[l] = idx;
                min_code[l] = next;
                // canonical codes of length l are consecutive: fill every
                // LUT entry whose top l bits equal one of them
                if l as u32 <= LUT_BITS {
                    let fill = 1usize << (LUT_BITS - l as u32);
                    for k in 0..c {
                        let sym = code.symbols[idx + k];
                        let base =
                            ((next + k as u32) as usize) << (LUT_BITS - l as u32);
                        for e in lut[base..base + fill].iter_mut() {
                            *e = (sym, l as u8);
                        }
                    }
                }
                next += c as u32;
                max_code[l] = (next - 1) as i64;
                idx += c;
            }
            next <<= 1;
        }
        HuffmanDecoder {
            lut,
            min_code,
            max_code,
            val_ptr,
            symbols: code.symbols.clone(),
        }
    }

    /// Decode one symbol from the reader.
    #[inline]
    pub fn get(&self, r: &mut BitReader<'_>) -> Result<u8> {
        let prefix = r.peek(LUT_BITS) as usize;
        let (sym, len) = self.lut[prefix];
        if len != 0 {
            // bounds-checked advance (errors on exhaustion) without
            // re-extracting the bits we already peeked
            r.consume(len as u32)?;
            return Ok(sym);
        }
        // slow path: codes longer than LUT_BITS bits (every length
        // <= LUT_BITS would have hit the table, so the walk only
        // terminates at a longer length or errors)
        let mut acc: u32 = 0;
        for l in 1..=MAX_LEN {
            acc = (acc << 1) | r.get(1)? as u32;
            if self.max_code[l] >= 0 && (acc as i64) <= self.max_code[l] {
                let off = (acc - self.min_code[l]) as usize;
                return Ok(self.symbols[self.val_ptr[l] + off]);
            }
        }
        bail!("invalid Huffman code (>{MAX_LEN} bits)");
    }
}

/// Heap-based Huffman length assignment (no cap yet).
fn assign_lengths(freq: &[u64; 256]) -> Result<[u8; 256]> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    #[derive(PartialEq, Eq, PartialOrd, Ord)]
    struct Node {
        weight: u64,
        id: usize,
    }

    let mut lens = [0u8; 256];
    let present: Vec<usize> =
        (0..256).filter(|&s| freq[s] > 0).collect();
    match present.len() {
        0 => bail!("cannot build Huffman code over empty alphabet"),
        1 => {
            lens[present[0]] = 1;
            return Ok(lens);
        }
        _ => {}
    }
    // nodes: 0..256 leaves, then internal
    let mut parent = vec![usize::MAX; 512];
    let mut heap: BinaryHeap<Reverse<Node>> = present
        .iter()
        .map(|&s| {
            Reverse(Node {
                weight: freq[s],
                id: s,
            })
        })
        .collect();
    let mut next_id = 256usize;
    while heap.len() > 1 {
        let a = heap.pop().unwrap().0;
        let b = heap.pop().unwrap().0;
        parent[a.id] = next_id;
        parent[b.id] = next_id;
        heap.push(Reverse(Node {
            weight: a.weight + b.weight,
            id: next_id,
        }));
        next_id += 1;
    }
    for &s in &present {
        let mut l = 0u32;
        let mut n = s;
        while parent[n] != usize::MAX {
            n = parent[n];
            l += 1;
        }
        lens[s] = l.min(255) as u8;
    }
    Ok(lens)
}

/// Enforce the 16-bit length cap by shortening overlong codes and
/// rebalancing (the classic JPEG adjust_bits procedure operating on
/// per-symbol lengths).
fn cap_lengths(lens: &mut [u8; 256], freq: &[u64; 256]) {
    let too_long = lens.iter().any(|&l| l as usize > MAX_LEN);
    if !too_long {
        return;
    }
    // Work on a multiset of lengths; classic algorithm on counts.
    let mut counts = [0usize; 64];
    for &l in lens.iter() {
        if l > 0 {
            counts[l as usize] += 1;
        }
    }
    let mut i = counts.len() - 1;
    while i > MAX_LEN {
        while counts[i] > 0 {
            // find j < i-1 with codes to pair with
            let mut j = i - 2;
            while counts[j] == 0 {
                j -= 1;
            }
            counts[i] -= 2;
            counts[i - 1] += 1;
            counts[j + 1] += 2;
            counts[j] -= 1;
        }
        i -= 1;
    }
    // reassign lengths canonically: sort present symbols by frequency
    // (desc) and hand out the shortest lengths first.
    let mut present: Vec<usize> =
        (0..256).filter(|&s| lens[s] > 0).collect();
    present.sort_by_key(|&s| std::cmp::Reverse(freq[s]));
    let mut new_lens = [0u8; 256];
    let mut li = 1usize;
    for &s in &present {
        while li <= MAX_LEN && counts[li] == 0 {
            li += 1;
        }
        debug_assert!(li <= MAX_LEN, "length redistribution failed");
        new_lens[s] = li as u8;
        counts[li] -= 1;
    }
    *lens = new_lens;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn roundtrip_symbols(freq: &[u64; 256], stream: &[u8]) {
        let code = HuffmanCode::build(freq).unwrap();
        // table serialization roundtrip
        let mut tbl = Vec::new();
        code.write_table(&mut tbl);
        let (code2, used) = HuffmanCode::read_table(&tbl).unwrap();
        assert_eq!(used, tbl.len());
        let mut w = BitWriter::new();
        for &s in stream {
            code2.put(&mut w, s);
        }
        let bytes = w.finish();
        let dec = HuffmanDecoder::new(&code2);
        let mut r = BitReader::new(&bytes);
        for &s in stream {
            assert_eq!(dec.get(&mut r).unwrap(), s);
        }
    }

    #[test]
    fn two_symbol_alphabet() {
        let mut freq = [0u64; 256];
        freq[7] = 100;
        freq[42] = 1;
        roundtrip_symbols(&freq, &[7, 42, 7, 7, 42, 7]);
    }

    #[test]
    fn single_symbol_alphabet() {
        let mut freq = [0u64; 256];
        freq[9] = 55;
        roundtrip_symbols(&freq, &[9, 9, 9]);
    }

    #[test]
    fn random_alphabet_roundtrip() {
        let mut rng = Rng::new(21);
        let mut freq = [0u64; 256];
        let mut stream = Vec::new();
        for _ in 0..5_000 {
            // zipf-ish distribution
            let s = (rng.next_f64().powi(3) * 80.0) as usize;
            freq[s] += 1;
            stream.push(s as u8);
        }
        roundtrip_symbols(&freq, &stream);
    }

    #[test]
    fn skewed_frequencies_shorter_codes() {
        let mut freq = [0u64; 256];
        freq[0] = 10_000;
        for s in 1..40 {
            freq[s] = 1 + s as u64 % 3;
        }
        let code = HuffmanCode::build(&freq).unwrap();
        let common = code.code_len(0);
        let rare = code.code_len(20);
        assert!(common < rare, "{common} vs {rare}");
    }

    #[test]
    fn near_entropy_on_uniform() {
        let mut freq = [0u64; 256];
        for (s, f) in freq.iter_mut().enumerate().take(64) {
            *f = 100;
            let _ = s;
        }
        let code = HuffmanCode::build(&freq).unwrap();
        // uniform over 64 symbols -> exactly 6 bits each
        for s in 0..64u8 {
            assert_eq!(code.code_len(s), 6);
        }
    }

    #[test]
    fn length_cap_respected_on_pathological_input() {
        // fibonacci-like frequencies force long codes without the cap
        let mut freq = [0u64; 256];
        let mut a = 1u64;
        let mut b = 1u64;
        for s in 0..40 {
            freq[s] = a;
            let c = a + b;
            a = b;
            b = c;
        }
        let code = HuffmanCode::build(&freq).unwrap();
        for s in 0..40u8 {
            assert!(code.code_len(s) as usize <= MAX_LEN);
            assert!(code.code_len(s) > 0);
        }
        // capped code must still decode
        let stream: Vec<u8> = (0..40u8).cycle().take(500).collect();
        let mut w = BitWriter::new();
        for &s in &stream {
            code.put(&mut w, s);
        }
        let bytes = w.finish();
        let dec = HuffmanDecoder::new(&code);
        let mut r = BitReader::new(&bytes);
        for &s in &stream {
            assert_eq!(dec.get(&mut r).unwrap(), s);
        }
    }

    #[test]
    fn short_code_at_stream_end_decodes() {
        // the LUT peeks 8 bits even when fewer remain; a 1-bit code in
        // the final partial byte must still decode (zero padding is never
        // consumed)
        let mut freq = [0u64; 256];
        freq[3] = 100;
        freq[9] = 1;
        let code = HuffmanCode::build(&freq).unwrap();
        let stream = [3u8, 9, 3, 3, 3, 3, 3, 3, 3];
        let mut w = BitWriter::new();
        for &s in &stream {
            code.put(&mut w, s);
        }
        let bytes = w.finish();
        let dec = HuffmanDecoder::new(&code);
        let mut r = BitReader::new(&bytes);
        for &s in &stream {
            assert_eq!(dec.get(&mut r).unwrap(), s);
        }
    }

    #[test]
    fn codes_longer_than_lut_take_slow_path() {
        // wide alphabet with extreme skew: rare symbols get codes longer
        // than the 8-bit LUT and must decode via the canonical walk
        let mut freq = [0u64; 256];
        freq[0] = 1 << 20;
        for s in 1..200usize {
            freq[s] = 1;
        }
        let code = HuffmanCode::build(&freq).unwrap();
        let max_len = (0..200).map(|s| code.code_len(s as u8)).max();
        assert!(max_len.unwrap() > 8, "alphabet too tame: {max_len:?}");
        let stream: Vec<u8> =
            (0..200u8).chain([0, 0, 199, 0, 150]).collect();
        let mut w = BitWriter::new();
        for &s in &stream {
            code.put(&mut w, s);
        }
        let bytes = w.finish();
        let dec = HuffmanDecoder::new(&code);
        let mut r = BitReader::new(&bytes);
        for &s in &stream {
            assert_eq!(dec.get(&mut r).unwrap(), s);
        }
    }

    #[test]
    fn empty_alphabet_errors() {
        let freq = [0u64; 256];
        assert!(HuffmanCode::build(&freq).is_err());
    }

    #[test]
    fn corrupt_table_errors() {
        assert!(HuffmanCode::read_table(&[0u8; 5]).is_err());
        // counts claim 3 symbols but none follow
        let mut bad = vec![0u8; MAX_LEN];
        bad[0] = 3;
        assert!(HuffmanCode::read_table(&bad).is_err());
        // duplicate symbol
        let mut dup = vec![0u8; MAX_LEN];
        dup[1] = 2; // two codes of length 2
        dup.extend_from_slice(&[5, 5]);
        assert!(HuffmanCode::read_table(&dup).is_err());
    }

    #[test]
    fn invalid_bitstream_errors_not_panics() {
        let mut freq = [0u64; 256];
        freq[1] = 5;
        freq[2] = 5;
        freq[3] = 5;
        freq[4] = 5;
        let code = HuffmanCode::build(&freq).unwrap();
        let dec = HuffmanDecoder::new(&code);
        // all-ones bitstream eventually walks off the code table or
        // exhausts the reader — must be an Err either way
        let bytes = [0xFFu8; 1];
        let mut r = BitReader::new(&bytes);
        let mut saw_err = false;
        for _ in 0..10 {
            if dec.get(&mut r).is_err() {
                saw_err = true;
                break;
            }
        }
        assert!(saw_err);
    }
}
