//! The entropy codec: what turns quantized DCT coefficients into an actual
//! compressed file ("image compression", not just a transform demo).
//!
//! Format (`.cdc`, for "cordic-dct codec"):
//!
//! ```text
//! magic "CDC1" | header (JSON-free fixed fields) |
//! Huffman table descriptors (canonical code lengths) |
//! entropy-coded segment: per 8x8 block in raster order,
//!   DC as DPCM category+bits, AC as JPEG-style (run, size) + bits,
//!   EOB after the last nonzero coefficient
//! ```
//!
//! The Huffman tables are built *per image* from symbol statistics (a
//! two-pass encoder), stored canonically (16 length counts + symbol list,
//! like JPEG's DHT), so the decoder rebuilds the exact code.
//!
//! Pipeline position: [`encoder`] consumes the planar quantized
//! coefficients that either lane (CPU serial or PJRT) produces;
//! [`decoder`] reverses to coefficients, which the standard IDCT then
//! reconstructs. Round-trip is exact (lossless over the quantized data).
//!
//! Color images use the [`color`] container (`CDC3`): a color header
//! followed by three of these grayscale streams, one per YCbCr plane.

pub mod color;
pub mod decoder;
pub mod encoder;
pub mod huffman;
pub mod rle;
pub mod zigzag;

use anyhow::{bail, Result};

pub const MAGIC: &[u8; 4] = b"CDC1";

/// Compressed-image container header.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Header {
    /// Original (pre-padding) image size.
    pub width: u32,
    pub height: u32,
    /// Padded size the coefficient grid uses (multiples of 8).
    pub padded_width: u32,
    pub padded_height: u32,
    /// IJG quality the quantizer used.
    pub quality: u8,
    /// Transform variant tag (dct / loeffler / cordic / naive).
    pub variant: u8,
}

impl Header {
    pub const BYTES: usize = 4 + 4 * 4 + 2;

    pub fn write(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&self.width.to_le_bytes());
        out.extend_from_slice(&self.height.to_le_bytes());
        out.extend_from_slice(&self.padded_width.to_le_bytes());
        out.extend_from_slice(&self.padded_height.to_le_bytes());
        out.push(self.quality);
        out.push(self.variant);
    }

    pub fn read(bytes: &[u8]) -> Result<(Header, usize)> {
        if bytes.len() < Self::BYTES {
            bail!("file too short for CDC header");
        }
        if &bytes[0..4] != MAGIC {
            bail!("bad magic: not a CDC file");
        }
        let rd = |o: usize| {
            u32::from_le_bytes([
                bytes[o],
                bytes[o + 1],
                bytes[o + 2],
                bytes[o + 3],
            ])
        };
        let h = Header {
            width: rd(4),
            height: rd(8),
            padded_width: rd(12),
            padded_height: rd(16),
            quality: bytes[20],
            variant: bytes[21],
        };
        if h.width == 0
            || h.height == 0
            || h.padded_width % 8 != 0
            || h.padded_height % 8 != 0
            || h.padded_width < h.width
            || h.padded_height < h.height
        {
            bail!("inconsistent CDC header {h:?}");
        }
        Ok((h, Self::BYTES))
    }
}

/// Variant <-> tag mapping for the header byte.
pub fn variant_tag(v: crate::dct::Variant) -> u8 {
    match v {
        crate::dct::Variant::Dct => 0,
        crate::dct::Variant::Loeffler => 1,
        crate::dct::Variant::Cordic => 2,
        crate::dct::Variant::Naive => 3,
    }
}

pub fn tag_variant(t: u8) -> Result<crate::dct::Variant> {
    Ok(match t {
        0 => crate::dct::Variant::Dct,
        1 => crate::dct::Variant::Loeffler,
        2 => crate::dct::Variant::Cordic,
        3 => crate::dct::Variant::Naive,
        _ => bail!("unknown variant tag {t}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        let h = Header {
            width: 200,
            height: 200,
            padded_width: 200,
            padded_height: 200,
            quality: 50,
            variant: 2,
        };
        let mut buf = Vec::new();
        h.write(&mut buf);
        let (back, used) = Header::read(&buf).unwrap();
        assert_eq!(h, back);
        assert_eq!(used, Header::BYTES);
    }

    #[test]
    fn header_rejects_bad_magic() {
        let mut buf = Vec::new();
        Header {
            width: 8,
            height: 8,
            padded_width: 8,
            padded_height: 8,
            quality: 50,
            variant: 0,
        }
        .write(&mut buf);
        buf[0] = b'X';
        assert!(Header::read(&buf).is_err());
    }

    #[test]
    fn header_rejects_inconsistent() {
        let mut buf = Vec::new();
        Header {
            width: 100,
            height: 8,
            padded_width: 96, // < width
            padded_height: 8,
            quality: 50,
            variant: 0,
        }
        .write(&mut buf);
        assert!(Header::read(&buf).is_err());
    }

    #[test]
    fn variant_tags_roundtrip() {
        use crate::dct::Variant;
        for v in [Variant::Dct, Variant::Loeffler, Variant::Cordic,
                  Variant::Naive] {
            assert_eq!(tag_variant(variant_tag(v)).unwrap(), v);
        }
        assert!(tag_variant(9).is_err());
    }
}
