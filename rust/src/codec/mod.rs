//! The entropy codec: what turns quantized DCT coefficients into an actual
//! compressed file ("image compression", not just a transform demo).
//!
//! Format (`.cdc`, for "cordic-dct codec"):
//!
//! ```text
//! magic "CDC1" | header (JSON-free fixed fields) |
//! Huffman table descriptors (canonical code lengths) |
//! entropy-coded segment: per 8x8 block in raster order,
//!   DC as DPCM category+bits, AC as JPEG-style (run, size) + bits,
//!   EOB after the last nonzero coefficient
//! ```
//!
//! The Huffman tables are built *per image* from symbol statistics (a
//! two-pass encoder), stored canonically (16 length counts + symbol list,
//! like JPEG's DHT), so the decoder rebuilds the exact code.
//!
//! Pipeline position: [`encoder`] consumes the planar quantized
//! coefficients that either lane (CPU serial or PJRT) produces;
//! [`decoder`] reverses to coefficients, which the standard IDCT then
//! reconstructs. Round-trip is exact (lossless over the quantized data).
//!
//! Color images use the [`color`] container (`CDC3`): a color header
//! followed by three of these grayscale streams, one per YCbCr plane.

pub mod color;
pub mod decoder;
pub mod encoder;
pub mod huffman;
pub mod rle;
pub mod zigzag;

use std::fmt;

use anyhow::{bail, Result};

pub const MAGIC: &[u8; 4] = b"CDC1";

/// Maximum pixel count a decoder will allocate for (DoS guard on corrupt
/// headers): 64 MPixel covers the paper's 3072x3072 with a wide margin.
pub const MAX_PIXELS: u64 = 64 * 1024 * 1024;

/// Per-dimension cap. Anything larger than this is hostile or corrupt:
/// even a 1-pixel-tall image this wide would exceed sane workloads.
pub const MAX_DIM: u32 = 1 << 15;

/// Why a container failed to decode. Carried as a machine-readable tag in
/// the error chain so the serve layer can map failures to protocol error
/// frames. (The vendored `anyhow` stand-in flattens errors to strings, so
/// classification goes through [`classify_decode_error`] rather than
/// downcasting.)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeErrorKind {
    /// Input ended before the declared structure did.
    Truncated,
    /// Not a CDC1/CDC3 container at all.
    BadMagic,
    /// Header fields are internally inconsistent (padding/dimensions).
    BadHeader,
    /// Header asks for more memory than the decoder will allocate.
    TooLarge,
    /// Entropy stream or table data is damaged.
    Corrupt,
}

impl DecodeErrorKind {
    pub const ALL: [DecodeErrorKind; 5] = [
        DecodeErrorKind::Truncated,
        DecodeErrorKind::BadMagic,
        DecodeErrorKind::BadHeader,
        DecodeErrorKind::TooLarge,
        DecodeErrorKind::Corrupt,
    ];

    /// Stable wire/chain tag for this kind.
    pub fn tag(self) -> &'static str {
        match self {
            DecodeErrorKind::Truncated => "truncated",
            DecodeErrorKind::BadMagic => "bad-magic",
            DecodeErrorKind::BadHeader => "bad-header",
            DecodeErrorKind::TooLarge => "too-large",
            DecodeErrorKind::Corrupt => "corrupt",
        }
    }

    pub fn from_tag(tag: &str) -> Option<DecodeErrorKind> {
        Self::ALL.iter().copied().find(|k| k.tag() == tag)
    }
}

/// Structured decode failure: a kind plus a human-readable message.
/// Implements `std::error::Error` so `?` converts it into `anyhow::Error`
/// while keeping the `[decode:<tag>]` marker in the message chain.
#[derive(Debug)]
pub struct DecodeError {
    pub kind: DecodeErrorKind,
    msg: String,
}

impl DecodeError {
    pub fn new(kind: DecodeErrorKind, msg: impl Into<String>) -> Self {
        DecodeError {
            kind,
            msg: msg.into(),
        }
    }
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[decode:{}] {}", self.kind.tag(), self.msg)
    }
}

impl std::error::Error for DecodeError {}

/// Recover the [`DecodeErrorKind`] from an error chain, if any entry
/// carries a `[decode:<tag>]` marker. Outermost marker wins.
pub fn classify_decode_error(err: &anyhow::Error) -> Option<DecodeErrorKind> {
    err.chain().find_map(|m| {
        let rest = m.strip_prefix("[decode:")?;
        let end = rest.find(']')?;
        DecodeErrorKind::from_tag(&rest[..end])
    })
}

/// Bail out of a decode path with a tagged [`DecodeError`].
macro_rules! decode_bail {
    ($kind:expr, $($arg:tt)*) => {
        return Err(crate::codec::DecodeError::new($kind, format!($($arg)*))
            .into())
    };
}
pub(crate) use decode_bail;

/// Compressed-image container header.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Header {
    /// Original (pre-padding) image size.
    pub width: u32,
    pub height: u32,
    /// Padded size the coefficient grid uses (multiples of 8).
    pub padded_width: u32,
    pub padded_height: u32,
    /// IJG quality the quantizer used.
    pub quality: u8,
    /// Transform variant tag (dct / loeffler / cordic / naive /
    /// cordic-fxp).
    pub variant: u8,
}

impl Header {
    pub const BYTES: usize = 4 + 4 * 4 + 2;

    pub fn write(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&self.width.to_le_bytes());
        out.extend_from_slice(&self.height.to_le_bytes());
        out.extend_from_slice(&self.padded_width.to_le_bytes());
        out.extend_from_slice(&self.padded_height.to_le_bytes());
        out.push(self.quality);
        out.push(self.variant);
    }

    pub fn read(bytes: &[u8]) -> Result<(Header, usize)> {
        if bytes.len() < Self::BYTES {
            decode_bail!(
                DecodeErrorKind::Truncated,
                "file too short for CDC header: {} bytes",
                bytes.len()
            );
        }
        if &bytes[0..4] != MAGIC {
            decode_bail!(
                DecodeErrorKind::BadMagic,
                "bad magic: not a CDC file"
            );
        }
        let rd = |o: usize| {
            u32::from_le_bytes([
                bytes[o],
                bytes[o + 1],
                bytes[o + 2],
                bytes[o + 3],
            ])
        };
        let h = Header {
            width: rd(4),
            height: rd(8),
            padded_width: rd(12),
            padded_height: rd(16),
            quality: bytes[20],
            variant: bytes[21],
        };
        if h.width > MAX_DIM || h.height > MAX_DIM {
            decode_bail!(
                DecodeErrorKind::TooLarge,
                "image dimensions {}x{} exceed cap {MAX_DIM}",
                h.width,
                h.height
            );
        }
        if h.padded_width as u64 * h.padded_height as u64 > MAX_PIXELS {
            decode_bail!(
                DecodeErrorKind::TooLarge,
                "padded grid {}x{} exceeds {MAX_PIXELS} pixels",
                h.padded_width,
                h.padded_height
            );
        }
        // The padded grid must be exactly the 8-alignment of the image
        // size: anything else (including a huge padded grid over a tiny
        // image) means the coefficient payload disagrees with the header.
        if h.width == 0
            || h.height == 0
            || h.padded_width % 8 != 0
            || h.padded_height % 8 != 0
            || h.padded_width < h.width
            || h.padded_height < h.height
            || h.padded_width - h.width >= 8
            || h.padded_height - h.height >= 8
        {
            decode_bail!(
                DecodeErrorKind::BadHeader,
                "inconsistent CDC header {h:?}"
            );
        }
        Ok((h, Self::BYTES))
    }
}

/// Variant <-> tag mapping for the header byte.
pub fn variant_tag(v: crate::dct::Variant) -> u8 {
    match v {
        crate::dct::Variant::Dct => 0,
        crate::dct::Variant::Loeffler => 1,
        crate::dct::Variant::Cordic => 2,
        crate::dct::Variant::Naive => 3,
        crate::dct::Variant::CordicFxp => 4,
    }
}

pub fn tag_variant(t: u8) -> Result<crate::dct::Variant> {
    Ok(match t {
        0 => crate::dct::Variant::Dct,
        1 => crate::dct::Variant::Loeffler,
        2 => crate::dct::Variant::Cordic,
        3 => crate::dct::Variant::Naive,
        4 => crate::dct::Variant::CordicFxp,
        _ => bail!("unknown variant tag {t}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        let h = Header {
            width: 200,
            height: 200,
            padded_width: 200,
            padded_height: 200,
            quality: 50,
            variant: 2,
        };
        let mut buf = Vec::new();
        h.write(&mut buf);
        let (back, used) = Header::read(&buf).unwrap();
        assert_eq!(h, back);
        assert_eq!(used, Header::BYTES);
    }

    #[test]
    fn header_rejects_bad_magic() {
        let mut buf = Vec::new();
        Header {
            width: 8,
            height: 8,
            padded_width: 8,
            padded_height: 8,
            quality: 50,
            variant: 0,
        }
        .write(&mut buf);
        buf[0] = b'X';
        assert!(Header::read(&buf).is_err());
    }

    #[test]
    fn header_rejects_inconsistent() {
        let mut buf = Vec::new();
        Header {
            width: 100,
            height: 8,
            padded_width: 96, // < width
            padded_height: 8,
            quality: 50,
            variant: 0,
        }
        .write(&mut buf);
        assert!(Header::read(&buf).is_err());
    }

    #[test]
    fn header_rejects_padded_dims_disagreeing_with_image() {
        // hostile shape: tiny image, huge (but individually legal) padded
        // grid — must be rejected before any decoder allocation happens
        let mut buf = Vec::new();
        Header {
            width: 1,
            height: 1,
            padded_width: 4096,
            padded_height: 4096,
            quality: 50,
            variant: 0,
        }
        .write(&mut buf);
        let err = Header::read(&buf).unwrap_err();
        assert_eq!(
            classify_decode_error(&err),
            Some(DecodeErrorKind::BadHeader),
            "{err:#}"
        );
    }

    #[test]
    fn header_rejects_giant_dims_as_too_large() {
        let mut buf = Vec::new();
        Header {
            width: u32::MAX - 7,
            height: 8,
            padded_width: u32::MAX - 7,
            padded_height: 8,
            quality: 50,
            variant: 0,
        }
        .write(&mut buf);
        let err = Header::read(&buf).unwrap_err();
        assert_eq!(
            classify_decode_error(&err),
            Some(DecodeErrorKind::TooLarge),
            "{err:#}"
        );
    }

    #[test]
    fn decode_errors_classify_through_anyhow_chain() {
        use anyhow::Context;
        for kind in DecodeErrorKind::ALL {
            let err: anyhow::Error =
                DecodeError::new(kind, "synthetic").into();
            assert_eq!(classify_decode_error(&err), Some(kind));
            // context layering must not hide the tag
            let wrapped = Err::<(), _>(err)
                .context("outer layer")
                .unwrap_err();
            assert_eq!(classify_decode_error(&wrapped), Some(kind));
            assert_eq!(DecodeErrorKind::from_tag(kind.tag()), Some(kind));
        }
        let plain = anyhow::anyhow!("no tag here");
        assert_eq!(classify_decode_error(&plain), None);
    }

    #[test]
    fn truncated_and_bad_magic_classified() {
        let err = Header::read(&[0u8; 3]).unwrap_err();
        assert_eq!(
            classify_decode_error(&err),
            Some(DecodeErrorKind::Truncated)
        );
        let err = Header::read(&[b'X'; Header::BYTES]).unwrap_err();
        assert_eq!(
            classify_decode_error(&err),
            Some(DecodeErrorKind::BadMagic)
        );
    }

    #[test]
    fn variant_tags_roundtrip() {
        use crate::dct::Variant;
        for v in [Variant::Dct, Variant::Loeffler, Variant::Cordic,
                  Variant::Naive, Variant::CordicFxp] {
            assert_eq!(tag_variant(variant_tag(v)).unwrap(), v);
        }
        assert!(tag_variant(9).is_err());
    }
}
