//! The entropy codec: what turns quantized DCT coefficients into an actual
//! compressed file ("image compression", not just a transform demo).
//!
//! Format (`.cdc`, for "cordic-dct codec"):
//!
//! ```text
//! magic "CDC1" | header (JSON-free fixed fields) |
//! Huffman table descriptors (canonical code lengths) |
//! entropy-coded segment: per 8x8 block in raster order,
//!   DC as DPCM category+bits, AC as JPEG-style (run, size) + bits,
//!   EOB after the last nonzero coefficient
//! ```
//!
//! The Huffman tables are built *per image* from symbol statistics (a
//! two-pass encoder), stored canonically (16 length counts + symbol list,
//! like JPEG's DHT), so the decoder rebuilds the exact code.
//!
//! Pipeline position: [`encoder`] consumes the planar quantized
//! coefficients that either lane (CPU serial or PJRT) produces;
//! [`decoder`] reverses to coefficients, which the standard IDCT then
//! reconstructs. Round-trip is exact (lossless over the quantized data).
//!
//! Color images use the [`color`] container (`CDC3`): a color header
//! followed by three of these grayscale streams, one per YCbCr plane.
//!
//! The v2 container (`CDC2`, [`MAGIC_V2`]) splits the entropy-coded
//! payload into independently decodable *restart segments* of
//! `restart_interval` block rows: each segment is byte-aligned, resets
//! the DC predictor, and carries a `FF D0+(i&7)` marker, its coded
//! length, and a crc32 of its payload; a crc32-protected head holds the
//! shared Huffman tables and a segment-length index. Strict decode
//! ([`decoder::decode`]) stays fail-fast on either version;
//! [`decoder::decode_salvage`] re-syncs past damaged v2 segments and
//! conceals them (DC-midpoint fill + nearest-intact-row replication),
//! returning a [`SalvageReport`].

pub mod color;
pub mod decoder;
pub mod encoder;
pub mod huffman;
pub mod rle;
pub mod zigzag;

use std::fmt;

use anyhow::{bail, Result};

pub const MAGIC: &[u8; 4] = b"CDC1";

/// Magic of the v2 (restart-segment) grayscale container. The fourth
/// byte is the format version: v2 streams carry independently decodable
/// segments with per-segment CRCs so a damaged region costs a few block
/// rows, not the image.
pub const MAGIC_V2: &[u8; 4] = b"CDC2";

/// First byte of a v2 restart-segment marker.
pub const SEG_MARKER: u8 = 0xFF;

/// Second marker byte base: segment `i` is tagged `SEG_MARKER_BASE +
/// (i & 7)` (JPEG RSTn convention), which lets a salvage decoder
/// re-anchor mid-stream without confusing adjacent segments.
pub const SEG_MARKER_BASE: u8 = 0xD0;

/// Default v2 restart interval: block rows per segment. Four block rows
/// (a 32-pixel band) keeps the per-segment header + index overhead
/// under the 3% budget on the fixture images while still confining a
/// bit-flip to a narrow band.
pub const DEFAULT_RESTART_INTERVAL: u16 = 4;

/// Is this byte stream a v2 (`CDC2`) grayscale container?
pub fn is_v2_container(bytes: &[u8]) -> bool {
    bytes.len() >= 4 && &bytes[0..4] == MAGIC_V2
}

/// Maximum pixel count a decoder will allocate for (DoS guard on corrupt
/// headers): 64 MPixel covers the paper's 3072x3072 with a wide margin.
pub const MAX_PIXELS: u64 = 64 * 1024 * 1024;

/// Per-dimension cap. Anything larger than this is hostile or corrupt:
/// even a 1-pixel-tall image this wide would exceed sane workloads.
pub const MAX_DIM: u32 = 1 << 15;

/// Why a container failed to decode. Carried as a machine-readable tag in
/// the error chain so the serve layer can map failures to protocol error
/// frames. (The vendored `anyhow` stand-in flattens errors to strings, so
/// classification goes through [`classify_decode_error`] rather than
/// downcasting.)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeErrorKind {
    /// Input ended before the declared structure did.
    Truncated,
    /// Not a CDC1/CDC3 container at all.
    BadMagic,
    /// Header fields are internally inconsistent (padding/dimensions).
    BadHeader,
    /// Header asks for more memory than the decoder will allocate.
    TooLarge,
    /// Entropy stream or table data is damaged.
    Corrupt,
}

impl DecodeErrorKind {
    pub const ALL: [DecodeErrorKind; 5] = [
        DecodeErrorKind::Truncated,
        DecodeErrorKind::BadMagic,
        DecodeErrorKind::BadHeader,
        DecodeErrorKind::TooLarge,
        DecodeErrorKind::Corrupt,
    ];

    /// Stable wire/chain tag for this kind.
    pub fn tag(self) -> &'static str {
        match self {
            DecodeErrorKind::Truncated => "truncated",
            DecodeErrorKind::BadMagic => "bad-magic",
            DecodeErrorKind::BadHeader => "bad-header",
            DecodeErrorKind::TooLarge => "too-large",
            DecodeErrorKind::Corrupt => "corrupt",
        }
    }

    pub fn from_tag(tag: &str) -> Option<DecodeErrorKind> {
        Self::ALL.iter().copied().find(|k| k.tag() == tag)
    }
}

/// Structured decode failure: a kind plus a human-readable message.
/// Implements `std::error::Error` so `?` converts it into `anyhow::Error`
/// while keeping the `[decode:<tag>]` marker in the message chain.
#[derive(Debug)]
pub struct DecodeError {
    pub kind: DecodeErrorKind,
    msg: String,
}

impl DecodeError {
    pub fn new(kind: DecodeErrorKind, msg: impl Into<String>) -> Self {
        DecodeError {
            kind,
            msg: msg.into(),
        }
    }
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[decode:{}] {}", self.kind.tag(), self.msg)
    }
}

impl std::error::Error for DecodeError {}

/// Recover the [`DecodeErrorKind`] from an error chain, if any entry
/// carries a `[decode:<tag>]` marker. Outermost marker wins.
pub fn classify_decode_error(err: &anyhow::Error) -> Option<DecodeErrorKind> {
    err.chain().find_map(|m| {
        let rest = m.strip_prefix("[decode:")?;
        let end = rest.find(']')?;
        DecodeErrorKind::from_tag(&rest[..end])
    })
}

/// Bail out of a decode path with a tagged [`DecodeError`].
macro_rules! decode_bail {
    ($kind:expr, $($arg:tt)*) => {
        return Err(crate::codec::DecodeError::new($kind, format!($($arg)*))
            .into())
    };
}
pub(crate) use decode_bail;

/// Salvage accounting for one plane's v2 stream.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlaneSalvage {
    /// Restart segments the head declared (1 for a v1 stream).
    pub segments_total: u32,
    /// Segments that failed CRC/entropy validation.
    pub segments_damaged: u32,
    /// Damaged segments patched by replicating the nearest intact block
    /// row (always <= `segments_damaged`; the rest stay DC-midpoint).
    pub segments_concealed: u32,
    /// Bytes of damaged or unparseable stream skipped over.
    pub bytes_skipped: u64,
}

impl PlaneSalvage {
    /// No damage was found in this plane.
    pub fn is_clean(&self) -> bool {
        self.segments_damaged == 0
    }
}

/// What [`decoder::decode_salvage`] / [`color::decode_salvage`]
/// recovered: aggregate counts plus the per-plane breakdown (one entry
/// for gray, three for color).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SalvageReport {
    pub segments_total: u32,
    pub segments_damaged: u32,
    pub segments_concealed: u32,
    pub bytes_skipped: u64,
    pub per_plane: Vec<PlaneSalvage>,
}

impl SalvageReport {
    /// Aggregate per-plane accounts into one report.
    pub fn from_planes(per_plane: Vec<PlaneSalvage>) -> SalvageReport {
        let mut r = SalvageReport {
            per_plane,
            ..SalvageReport::default()
        };
        for p in &r.per_plane {
            r.segments_total += p.segments_total;
            r.segments_damaged += p.segments_damaged;
            r.segments_concealed += p.segments_concealed;
            r.bytes_skipped += p.bytes_skipped;
        }
        r
    }

    /// The whole container decoded without damage.
    pub fn is_clean(&self) -> bool {
        self.segments_damaged == 0
    }
}

/// Compressed-image container header.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Header {
    /// Original (pre-padding) image size.
    pub width: u32,
    pub height: u32,
    /// Padded size the coefficient grid uses (multiples of 8).
    pub padded_width: u32,
    pub padded_height: u32,
    /// IJG quality the quantizer used.
    pub quality: u8,
    /// Transform variant tag (dct / loeffler / cordic / naive /
    /// cordic-fxp).
    pub variant: u8,
}

impl Header {
    pub const BYTES: usize = 4 + 4 * 4 + 2;

    fn write_fields(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.width.to_le_bytes());
        out.extend_from_slice(&self.height.to_le_bytes());
        out.extend_from_slice(&self.padded_width.to_le_bytes());
        out.extend_from_slice(&self.padded_height.to_le_bytes());
        out.push(self.quality);
        out.push(self.variant);
    }

    pub fn write(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(MAGIC);
        self.write_fields(out);
    }

    /// Write the header under the v2 (`CDC2`) magic. The caller appends
    /// the v2-only fields (restart interval, segment count) after it.
    pub fn write_v2(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(MAGIC_V2);
        self.write_fields(out);
    }

    pub fn read(bytes: &[u8]) -> Result<(Header, usize)> {
        Self::read_with_magic(bytes, MAGIC)
    }

    /// Parse a v2 (`CDC2`) header. Same fixed fields and validation as
    /// [`Header::read`]; only the magic differs.
    pub fn read_v2(bytes: &[u8]) -> Result<(Header, usize)> {
        Self::read_with_magic(bytes, MAGIC_V2)
    }

    fn read_with_magic(
        bytes: &[u8],
        magic: &[u8; 4],
    ) -> Result<(Header, usize)> {
        if bytes.len() < Self::BYTES {
            decode_bail!(
                DecodeErrorKind::Truncated,
                "file too short for CDC header: {} bytes",
                bytes.len()
            );
        }
        if &bytes[0..4] != magic {
            decode_bail!(
                DecodeErrorKind::BadMagic,
                "bad magic: not a CDC file"
            );
        }
        let rd = |o: usize| {
            u32::from_le_bytes([
                bytes[o],
                bytes[o + 1],
                bytes[o + 2],
                bytes[o + 3],
            ])
        };
        let h = Header {
            width: rd(4),
            height: rd(8),
            padded_width: rd(12),
            padded_height: rd(16),
            quality: bytes[20],
            variant: bytes[21],
        };
        if h.width > MAX_DIM || h.height > MAX_DIM {
            decode_bail!(
                DecodeErrorKind::TooLarge,
                "image dimensions {}x{} exceed cap {MAX_DIM}",
                h.width,
                h.height
            );
        }
        if h.padded_width as u64 * h.padded_height as u64 > MAX_PIXELS {
            decode_bail!(
                DecodeErrorKind::TooLarge,
                "padded grid {}x{} exceeds {MAX_PIXELS} pixels",
                h.padded_width,
                h.padded_height
            );
        }
        // The padded grid must be exactly the 8-alignment of the image
        // size: anything else (including a huge padded grid over a tiny
        // image) means the coefficient payload disagrees with the header.
        if h.width == 0
            || h.height == 0
            || h.padded_width % 8 != 0
            || h.padded_height % 8 != 0
            || h.padded_width < h.width
            || h.padded_height < h.height
            || h.padded_width - h.width >= 8
            || h.padded_height - h.height >= 8
        {
            decode_bail!(
                DecodeErrorKind::BadHeader,
                "inconsistent CDC header {h:?}"
            );
        }
        Ok((h, Self::BYTES))
    }
}

/// Variant <-> tag mapping for the header byte.
pub fn variant_tag(v: crate::dct::Variant) -> u8 {
    match v {
        crate::dct::Variant::Dct => 0,
        crate::dct::Variant::Loeffler => 1,
        crate::dct::Variant::Cordic => 2,
        crate::dct::Variant::Naive => 3,
        crate::dct::Variant::CordicFxp => 4,
    }
}

pub fn tag_variant(t: u8) -> Result<crate::dct::Variant> {
    Ok(match t {
        0 => crate::dct::Variant::Dct,
        1 => crate::dct::Variant::Loeffler,
        2 => crate::dct::Variant::Cordic,
        3 => crate::dct::Variant::Naive,
        4 => crate::dct::Variant::CordicFxp,
        _ => bail!("unknown variant tag {t}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        let h = Header {
            width: 200,
            height: 200,
            padded_width: 200,
            padded_height: 200,
            quality: 50,
            variant: 2,
        };
        let mut buf = Vec::new();
        h.write(&mut buf);
        let (back, used) = Header::read(&buf).unwrap();
        assert_eq!(h, back);
        assert_eq!(used, Header::BYTES);
    }

    #[test]
    fn header_rejects_bad_magic() {
        let mut buf = Vec::new();
        Header {
            width: 8,
            height: 8,
            padded_width: 8,
            padded_height: 8,
            quality: 50,
            variant: 0,
        }
        .write(&mut buf);
        buf[0] = b'X';
        assert!(Header::read(&buf).is_err());
    }

    #[test]
    fn header_rejects_inconsistent() {
        let mut buf = Vec::new();
        Header {
            width: 100,
            height: 8,
            padded_width: 96, // < width
            padded_height: 8,
            quality: 50,
            variant: 0,
        }
        .write(&mut buf);
        assert!(Header::read(&buf).is_err());
    }

    #[test]
    fn header_rejects_padded_dims_disagreeing_with_image() {
        // hostile shape: tiny image, huge (but individually legal) padded
        // grid — must be rejected before any decoder allocation happens
        let mut buf = Vec::new();
        Header {
            width: 1,
            height: 1,
            padded_width: 4096,
            padded_height: 4096,
            quality: 50,
            variant: 0,
        }
        .write(&mut buf);
        let err = Header::read(&buf).unwrap_err();
        assert_eq!(
            classify_decode_error(&err),
            Some(DecodeErrorKind::BadHeader),
            "{err:#}"
        );
    }

    #[test]
    fn header_rejects_giant_dims_as_too_large() {
        let mut buf = Vec::new();
        Header {
            width: u32::MAX - 7,
            height: 8,
            padded_width: u32::MAX - 7,
            padded_height: 8,
            quality: 50,
            variant: 0,
        }
        .write(&mut buf);
        let err = Header::read(&buf).unwrap_err();
        assert_eq!(
            classify_decode_error(&err),
            Some(DecodeErrorKind::TooLarge),
            "{err:#}"
        );
    }

    #[test]
    fn decode_errors_classify_through_anyhow_chain() {
        use anyhow::Context;
        for kind in DecodeErrorKind::ALL {
            let err: anyhow::Error =
                DecodeError::new(kind, "synthetic").into();
            assert_eq!(classify_decode_error(&err), Some(kind));
            // context layering must not hide the tag
            let wrapped = Err::<(), _>(err)
                .context("outer layer")
                .unwrap_err();
            assert_eq!(classify_decode_error(&wrapped), Some(kind));
            assert_eq!(DecodeErrorKind::from_tag(kind.tag()), Some(kind));
        }
        let plain = anyhow::anyhow!("no tag here");
        assert_eq!(classify_decode_error(&plain), None);
    }

    #[test]
    fn truncated_and_bad_magic_classified() {
        let err = Header::read(&[0u8; 3]).unwrap_err();
        assert_eq!(
            classify_decode_error(&err),
            Some(DecodeErrorKind::Truncated)
        );
        let err = Header::read(&[b'X'; Header::BYTES]).unwrap_err();
        assert_eq!(
            classify_decode_error(&err),
            Some(DecodeErrorKind::BadMagic)
        );
    }

    #[test]
    fn variant_tags_roundtrip() {
        use crate::dct::Variant;
        for v in [Variant::Dct, Variant::Loeffler, Variant::Cordic,
                  Variant::Naive, Variant::CordicFxp] {
            assert_eq!(tag_variant(variant_tag(v)).unwrap(), v);
        }
        assert!(tag_variant(9).is_err());
    }
}
